"""linalg — the paper's application domain: distributed dense matrix
factorization schedules (§V) expressed as simmpi virtual-rank programs.

Four state-of-the-art implementations, with the paper's exact configuration
space structure:

- ``capital_cholesky`` — Capital's recursive bulk-synchronous Cholesky on a
  3D processor grid (block size x 3 base-case strategies);
- ``slate_cholesky``   — SLATE's task-based tile Cholesky on a 2D grid
  (tile size x lookahead depth), nonblocking p2p;
- ``candmc_qr``        — CANDMC's pipelined bulk-synchronous 2D Householder
  QR (block size x processor grid);
- ``slate_qr``         — SLATE's task-based 2D QR with internally-blocked
  panels (inner width x panel width x grid).

``blas`` provides real local jnp BLAS/LAPACK execution + timing for the
measured mode (the modeled mode uses simmpi.costmodel).
``studies`` builds the tuning studies at 'paper' and 'ci' scales.
"""

from .studies import (capital_cholesky_study, slate_cholesky_study,
                      candmc_qr_study, slate_qr_study, STUDIES)

__all__ = ["capital_cholesky_study", "slate_cholesky_study",
           "candmc_qr_study", "slate_qr_study", "STUDIES"]
