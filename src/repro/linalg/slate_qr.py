"""SLATE's task-based 2D Householder QR with internally-blocked panels.

Paper §V.B: block-cyclic tiles on a 2D grid, task-based scheduling via
nonblocking p2p (isend/send/recv).  The panel factorization is internally
blocked with parameter w (< tile size) "to increase thread concurrency" —
each panel-tile task issues tile/w internally-blocked geqrf/tpqrt calls.
Trailing updates apply the block reflectors tile-by-tile (trmm + tpmqrt +
gemm; the BLAS-2 work inside the panel is NOT executed selectively, per
§V.D, and is emitted here as non-interceptable overhead baked into the
geqrf kernels).

Configuration space: inner width w x panel (tile) width x processor grid —
63 configurations in the paper.
"""

from __future__ import annotations

from repro.simmpi import Comp, Isend, Recv
from repro.simmpi.comm import World


def make_program(world: World, *, m: int, n: int, tile: int, inner: int,
                 pr: int, pc: int):
    assert pr * pc == world.size
    mt, nt = m // tile, n // tile
    w = max(min(inner, tile), 1)
    chunks = max(tile // w, 1)
    tb = 8 * tile * tile

    def owner(i, j):
        return (i % pr) + pr * (j % pc)

    def program(rank: int, world: World):
        TAG_CHAIN, TAG_V, TAG_T = 0, 1, 2

        for k in range(nt):
            # ---- panel factorization: triangle-reduction chain down the
            # tile column, internally blocked by w ----
            prev = None
            col_owners = []
            for i in range(k, mt):
                o = owner(i, k)
                if not col_owners or col_owners[-1] != o:
                    col_owners.append(o)
            if owner(k, k) == rank:
                for _ in range(chunks):
                    yield Comp("geqrf", (tile, w))
            # chain: each distinct owner folds its tiles into the triangle
            # received from the previous owner in the column
            for ci, o in enumerate(col_owners):
                if o != rank:
                    continue
                if ci > 0:
                    yield Recv(col_owners[ci - 1], 8 * tile * tile // 2,
                               (TAG_CHAIN, k, ci))
                my_tiles = [i for i in range(k, mt)
                            if owner(i, k) == rank and (i > k or ci > 0)]
                for _ in my_tiles:
                    for _ in range(chunks):
                        yield Comp("tpqrt", (tile, w))
                if ci + 1 < len(col_owners):
                    yield Isend(col_owners[ci + 1], 8 * tile * tile // 2,
                                (TAG_CHAIN, k, ci + 1))

            # ---- broadcast reflectors row-wise: each panel-tile owner
            # sends (V_i, T_i) to the ranks of its grid row that own
            # trailing tiles ----
            for i in range(k, mt):
                if owner(i, k) != rank:
                    continue
                sent = set()
                for j in range(k + 1, nt):
                    o = owner(i, j)
                    if o != rank and o not in sent:
                        sent.add(o)
                        yield Isend(o, tb, (TAG_V, k, i))

            # ---- trailing update: row k tiles get trmm+gemm, lower tiles
            # get the internally-blocked tpmqrt ----
            got = set()   # per-panel: each (V_i, T_i) is received once
            for j in range(k + 1, nt):
                for i in range(k, mt):
                    if owner(i, j) != rank:
                        continue
                    src = owner(i, k)
                    if src != rank and (k, i) not in got:
                        got.add((k, i))
                        yield Recv(src, tb, (TAG_V, k, i))
                    if i == k:
                        yield Comp("trmm", (tile, tile))
                        yield Comp("gemm", (tile, tile, tile))
                    else:
                        for _ in range(chunks):
                            yield Comp("tpmqrt", (tile, tile, w))

    return program
