"""CANDMC's pipelined bulk-synchronous 2D Householder QR.

Paper §V.B: panels of width b are factorized with TSQR (local geqrf + a
binary-tree reduction of stacked triangles via tpqrt), the compact
Householder representation Y,T is reconstructed (LU-based, emitted here as
the trtri/gemm/ormqr mix CANDMC invokes), Y is broadcast row-wise, and the
trailing matrix update W = (TY)^T A / A -= Y W runs with a column all-reduce.

BSP cost: Theta(alpha * n/b + beta * (mn/p_r + n^2/p_c + nb)
                + gamma * (mn^2/p + nb^2 + mnb/p_r + n^2 b/p_c)),
making performance highly sensitive to BOTH the block size b and the grid
(p_r x p_c) — the paper's configuration space sweeps both.

Lookahead pipelining: the grid column that owns the next panel performs its
slice of the trailing update first and proceeds into the next panel's TSQR
while the other columns finish the wide update (§V.B).

The trailing matrix shrinks every panel, so gemm/ormqr signatures take many
DISTINCT input sizes — the regime where per-signature modeling pays off
least (paper: overall speedup limited to 1.2x) and the beyond-paper
extrapolation model pays off most.
"""

from __future__ import annotations

from repro.simmpi import Coll, Comp, Recv, Send
from repro.simmpi.comm import World


def make_program(world: World, *, m: int, n: int, block: int,
                 pr: int, pc: int):
    assert pr * pc == world.size
    npan = n // block
    b = block

    def program(rank: int, world: World):
        grids = world.grid_comms((pr, pc))
        myrow, mycol = grids.coords(rank)
        rowc = grids.fiber(rank, 1)   # ranks sharing my grid row (size pc)
        colc = grids.fiber(rank, 0)   # ranks sharing my grid column (size pr)

        def tsqr(m_loc):
            """TSQR over the grid column: local geqrf, then a binary
            exchange tree of stacked-triangle factorizations."""
            yield Comp("geqrf", (max(m_loc, b), b))
            step = 1
            while step < pr:
                partner_row = myrow ^ step
                if partner_row < pr:
                    partner = grids.rank_of((partner_row, mycol))
                    nbytes = 8 * b * b // 2
                    if myrow < partner_row:
                        yield Send(partner, nbytes, ("tsqr", step))
                        yield Recv(partner, nbytes, ("tsqr", step))
                    else:
                        yield Recv(partner, nbytes, ("tsqr", step))
                        yield Send(partner, nbytes, ("tsqr", step))
                    yield Comp("tpqrt", (2 * b, b))
                step *= 2

        def reconstruct(m_loc):
            """Householder reconstruction: Y1 via LU of a Q1-derived matrix
            (ormqr to apply Q, trtri + small gemms for the T factor)."""
            yield Comp("ormqr", (max(m_loc, b), b, b))
            yield Comp("trtri", (b,))
            yield Comp("gemm", (b, b, b))
            yield Coll("bcast", colc, 8 * b * b)

        for j in range(npan):
            m_loc = max((m - j * b) // pr, b)
            n_loc = max((n - (j + 1) * b) // pc, 0)
            panel_col = j % pc

            if mycol == panel_col:
                yield from tsqr(m_loc)
                yield from reconstruct(m_loc)

            if n_loc > 0:
                # broadcast Y panel row-wise from the factorizing column
                yield Coll("bcast", rowc, 8 * m_loc * b)
                # W = (T Y)^T A_loc, reduced over the grid column
                yield Comp("gemm", (b, n_loc, m_loc))
                yield Coll("allreduce", colc, 8 * b * n_loc)
                yield Comp("trmm", (b, n_loc))
                # A_loc -= Y W
                yield Comp("gemm", (m_loc, n_loc, b))

    return program
