"""The paper's four tuning studies (§V.C), at two scales.

- ``paper`` scale reproduces the exact configuration spaces of §V.C
  (matrix sizes, block/tile grids, processor counts 256-4096).  Running
  them is possible but slow on this container (hundreds of millions of
  simulated events); the benchmarks default to
- ``ci`` scale: the SAME configuration-space *structure* (same number of
  configurations, same n/b and grid-aspect progressions, same base-case /
  lookahead / inner-blocking alternatives) on a 64-rank machine with
  proportionally reduced matrices.  EXPERIMENTS.md records the mapping.

Capital's study does NOT reset kernel statistics between configurations
(its kernels recur across configurations; eager propagation exploits this —
paper §VI.A/§VI.B); SLATE's and CANDMC's studies reset (§VI.A).
"""

from __future__ import annotations

from typing import Dict, List, Optional

from repro.api.space import SearchSpace
from repro.core.tuner import Configuration, Study, space_of_study
from repro.simmpi.costmodel import KNL_STAMPEDE2

from . import capital_cholesky, slate_cholesky, candmc_qr, slate_qr


def capital_cholesky_study(scale: str = "ci") -> Study:
    if scale == "paper":
        p, c, n, b0 = 512, 8, 16384, 128
    else:
        p, c, n, b0 = 64, 4, 1024, 16
    configs: List[Configuration] = []
    for v in range(15):
        b = b0 * 2 ** (v % 5)
        strategy = (v + 1 + 4) // 5   # ceil((v+1)/5): 1,1,1,1,1,2,...,3
        configs.append(Configuration(
            name=f"capital-b{b}-s{strategy}",
            params={"block": b, "strategy": strategy, "n": n},
            make_program=lambda w, n=n, b=b, s=strategy, c=c:
                capital_cholesky.make_program(w, n=n, block=b, strategy=s,
                                              grid_c=c)))
    return Study(name=f"capital-cholesky-{scale}", world_size=p,
                 configs=configs, reset_between_configs=False,
                 machine=KNL_STAMPEDE2)


def slate_cholesky_study(scale: str = "ci") -> Study:
    if scale == "paper":
        p, pr, pc, n, t0, dt = 1024, 32, 32, 65536, 256, 64
    elif scale == "mid":
        # beyond-Capital paper-scale stepping stone: the §V.C configuration
        # structure on 256 real ranks (the SLATE QR paper geometry) with
        # the matrix scaled so a sweep stays hours-not-days on this
        # container — the artifact recorded by ``bench_paper --scale mid``
        p, pr, pc, n, t0, dt = 256, 16, 16, 16384, 256, 64
    else:
        p, pr, pc, n, t0, dt = 64, 8, 8, 8192, 256, 64
    configs: List[Configuration] = []
    for v in range(20):
        tile = t0 + dt * (v // 2)
        la = v % 2
        configs.append(Configuration(
            name=f"slate-chol-t{tile}-la{la}",
            params={"tile": tile, "lookahead": la, "n": n},
            make_program=lambda w, n=n, t=tile, la=la, pr=pr, pc=pc:
                slate_cholesky.make_program(w, n=n, tile=t, lookahead=la,
                                            pr=pr, pc=pc)))
    return Study(name=f"slate-cholesky-{scale}", world_size=p,
                 configs=configs, reset_between_configs=True,
                 machine=KNL_STAMPEDE2)


def candmc_qr_study(scale: str = "ci") -> Study:
    if scale == "paper":
        p, m, n, b0, g0 = 4096, 131072, 8192, 8, 64
    else:
        p, m, n, b0, g0 = 64, 4096, 512, 8, 8
    configs: List[Configuration] = []
    for v in range(15):
        b = b0 * 2 ** (v % 5)
        pr = g0 * 2 ** (v // 5)
        pc = p // pr
        configs.append(Configuration(
            name=f"candmc-qr-b{b}-g{pr}x{pc}",
            params={"block": b, "pr": pr, "pc": pc, "m": m, "n": n},
            make_program=lambda w, m=m, n=n, b=b, pr=pr, pc=pc:
                candmc_qr.make_program(w, m=m, n=n, block=b, pr=pr, pc=pc)))
    return Study(name=f"candmc-qr-{scale}", world_size=p,
                 configs=configs, reset_between_configs=True,
                 machine=KNL_STAMPEDE2)


def slate_qr_study(scale: str = "ci") -> Study:
    if scale == "paper":
        p, m, n, t0, dt, w0, g0 = 256, 65536, 4096, 256, 64, 8, 64
    else:
        p, m, n, t0, dt, w0, g0 = 64, 4096, 512, 64, 32, 8, 16
    configs: List[Configuration] = []
    for v in range(63):
        w_ = w0 * 2 ** (v % 3)
        tile = t0 + dt * ((v // 3) % 7)
        pr = g0 // 2 ** (v // 21)
        pc = p // pr
        configs.append(Configuration(
            name=f"slate-qr-w{w_}-t{tile}-g{pr}x{pc}",
            params={"inner": w_, "tile": tile, "pr": pr, "pc": pc,
                    "m": m, "n": n},
            make_program=lambda wld, m=m, n=n, t=tile, iw=w_, pr=pr, pc=pc:
                slate_qr.make_program(wld, m=m, n=n, tile=t, inner=iw,
                                      pr=pr, pc=pc)))
    return Study(name=f"slate-qr-{scale}", world_size=p,
                 configs=configs, reset_between_configs=True,
                 machine=KNL_STAMPEDE2)


STUDIES: Dict[str, callable] = {
    "capital-cholesky": capital_cholesky_study,
    "slate-cholesky": slate_cholesky_study,
    "candmc-qr": candmc_qr_study,
    "slate-qr": slate_qr_study,
}


def search_space(name: str, scale: str = "ci", *,
                 max_configs: Optional[int] = None) -> SearchSpace:
    """The session-API view of a paper study: ``search_space
    ("slate-cholesky")`` feeds ``repro.api.AutotuneSession`` with a
    ``SimBackend``.  ``max_configs`` truncates for fast CI passes."""
    return space_of_study(STUDIES[name](scale)).subset(max_configs)
