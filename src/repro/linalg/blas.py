"""Real local BLAS/LAPACK execution + timing: the 'measured' mode.

The paper times real kernels on Stampede2.  Here, the same role is played by
jnp kernels executed on the container's CPU and timed with perf_counter —
real computation with real OS/cache noise, at laptop scale.  A MeasuredTimer
plugs into the simmpi Runtime in place of the stochastic cost model: compute
signatures are executed for real; communication signatures (which have no
local realization) fall back to the cost model.

Inputs are preallocated and cached per signature so that timing measures the
kernel, not allocation; each invocation blocks until ready.  Matrices are
re-randomized cheaply between calls only at the level the paper requires
("each dense input matrix is reset prior to executing a LAPACK routine").
"""

from __future__ import annotations

import time
from typing import Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.scipy.linalg import cholesky as jsp_cholesky
from jax.scipy.linalg import solve_triangular

from repro.core.signatures import Signature
from repro.simmpi.costmodel import CostModel


# jit'd kernel implementations, cached by shape automatically by jax
@jax.jit
def _gemm(a, b):
    return a @ b


@jax.jit
def _syrk(a):
    return a @ a.T


@jax.jit
def _trmm(l, b):
    return jnp.tril(l) @ b


@jax.jit
def _trsm(l, b):
    return solve_triangular(l, b, lower=True)


@jax.jit
def _potrf(a):
    return jsp_cholesky(a, lower=True)


@jax.jit
def _trtri(l):
    return solve_triangular(l, jnp.eye(l.shape[0], dtype=l.dtype), lower=True)


@jax.jit
def _geqrf(a):
    return jnp.linalg.qr(a, mode="r")


def _spd(rng, n):
    a = rng.standard_normal((n, n))
    return np.asarray(a @ a.T + n * np.eye(n), dtype=np.float64)


def _tri(rng, n):
    return np.asarray(np.tril(rng.standard_normal((n, n))) + n * np.eye(n),
                      dtype=np.float64)


class MeasuredTimer:
    """timer(sig, rng) -> seconds; executes compute kernels for real."""

    def __init__(self, comm_model: Optional[CostModel] = None, seed: int = 0):
        self.comm_model = comm_model
        self._cache: Dict[Signature, tuple] = {}
        self._warmed: set = set()
        self._rng = np.random.default_rng(seed)
        self.calls = 0

    def _operands(self, sig: Signature):
        ops = self._cache.get(sig)
        if ops is not None:
            return ops
        rng = self._rng
        n, p = sig.name, sig.params
        if n == "gemm":
            m, nn, k = int(p[0]), int(p[1]), int(p[2])
            ops = (_gemm, (jnp.asarray(rng.standard_normal((m, k))),
                           jnp.asarray(rng.standard_normal((k, nn)))))
        elif n == "syrk":
            ops = (_syrk, (jnp.asarray(
                rng.standard_normal((int(p[0]), int(p[1])))),))
        elif n == "trmm":
            ops = (_trmm, (jnp.asarray(_tri(rng, int(p[0]))),
                           jnp.asarray(rng.standard_normal(
                               (int(p[0]), int(p[1]))))))
        elif n == "trsm":
            ops = (_trsm, (jnp.asarray(_tri(rng, int(p[0]))),
                           jnp.asarray(rng.standard_normal(
                               (int(p[0]), int(p[1]))))))
        elif n == "potrf":
            ops = (_potrf, (jnp.asarray(_spd(rng, int(p[0]))),))
        elif n == "trtri":
            ops = (_trtri, (jnp.asarray(_tri(rng, int(p[0]))),))
        elif n in ("geqrf", "tpqrt"):
            m = int(p[0]) if n == "geqrf" else 2 * int(p[1])
            ops = (_geqrf, (jnp.asarray(
                rng.standard_normal((max(m, int(p[1])), int(p[1])))),))
        elif n in ("ormqr", "tpmqrt"):
            m, k = int(p[0]), int(p[-1])
            ops = (_gemm, (jnp.asarray(rng.standard_normal((m, k))),
                           jnp.asarray(rng.standard_normal((k, m)))))
        elif n == "blk2cyc":
            nb = max(int(p[0]) // 8, 1)
            ops = ("copy", (jnp.asarray(rng.standard_normal(nb)),))
        else:
            raise KeyError(f"no measured realization for {sig}")
        self._cache[sig] = ops
        return ops

    def __call__(self, sig: Signature, rng: np.random.Generator) -> float:
        if sig.kind == "comm":
            if self.comm_model is None:
                raise RuntimeError("measured mode needs a comm cost model")
            return self.comm_model.sample(sig, rng)
        fn, args = self._operands(sig)
        self.calls += 1
        if fn == "copy":
            t0 = time.perf_counter()
            jnp.array(args[0]).block_until_ready()
            return time.perf_counter() - t0
        if sig not in self._warmed:
            # compile outside the timed region on first use
            fn(*args).block_until_ready()
            self._warmed.add(sig)
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        return time.perf_counter() - t0
