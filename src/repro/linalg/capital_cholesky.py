"""Capital's recursive bulk-synchronous Cholesky on a 3D processor grid.

Paper §V.A: recursive application of

    [A11 A21^T; A21 A22] = [L11; L21 L22][L11^T L21^T; L22^T]

with the base case solved by sequential potrf/trtri once the subproblem
dimension falls below block size b.  Matrix products (L21 <- A21 L11^{-T},
S21, and the symmetric update A22 - L21 L21^T) execute as 3D-grid matmuls:
broadcasts along two grid dimensions, a reduction along the third, plus the
block-to-cyclic redistribution kernels the paper intercepts explicitly.

BSP cost (paper): Theta(alpha * n/b + beta * (n^2/p^{2/3} + n b)
                        + gamma * (n^3/p + n b^2)),
so latency wants a LARGE block size while bandwidth/compute want a SMALL
one — the non-trivial trade-off the autotuner must resolve.

Base-case strategies (paper's three):
  1. gather the base-case matrix onto one rank of one grid layer, factor,
     scatter across the layer, broadcast along the grid depth;
  2. all-gather within EVERY layer, factor redundantly everywhere;
  3. all-gather within ONE layer, factor redundantly across that layer,
     broadcast along the depth fiber.
"""

from __future__ import annotations

from repro.simmpi import Coll, Comp
from repro.simmpi.comm import World


def make_program(world: World, *, n: int, block: int, strategy: int,
                 grid_c: int):
    """Program factory for one (block size, base-case strategy) config.

    grid_c: cube edge — the processor grid is grid_c^3 = world.size.
    """
    assert grid_c ** 3 == world.size, (grid_c, world.size)
    assert strategy in (1, 2, 3)
    grids = world.grid_comms((grid_c, grid_c, grid_c))

    def program(rank: int, world: World):
        c = grid_c
        x, y, z = grids.coords(rank)
        row = grids.fiber(rank, 0)       # vary x: bcast dim
        col = grids.fiber(rank, 1)       # vary y: bcast dim
        depth = grids.fiber(rank, 2)     # vary z: reduce / replication dim
        layer = grids.slice(rank, (0, 1))  # the rank's c*c grid layer

        def matmul3d(m, nn, k, kind="gemm"):
            """3D matmul: bcast A along y, B along x, local product over the
            k/c slice owned by this layer, reduce C along z.  Local block
            dims are m/c x k/c etc. (cyclic layout keeps blocks square)."""
            mb, nb, kb = max(m // c, 1), max(nn // c, 1), max(k // c, 1)
            yield Comp("blk2cyc", (8 * mb * kb,))
            yield Coll("bcast", col, 8 * mb * kb)
            yield Coll("bcast", row, 8 * kb * nb)
            if kind == "gemm":
                yield Comp("gemm", (mb, nb, kb))
            elif kind == "trmm":
                yield Comp("trmm", (mb, nb))
            else:  # syrk-flavored update
                yield Comp("syrk", (mb, kb))
            yield Coll("reduce", depth, 8 * mb * nb)

        def base_case(b):
            """Factor the b x b base-case block: potrf + trtri (Capital
            tracks L^{-1} for its inverse-based recursion)."""
            blk = 8 * b * b
            if strategy == 1:
                if z == 0:
                    yield Coll("gather", layer, blk // layer.size)
                    if x == 0 and y == 0:
                        yield Comp("potrf", (b,))
                        yield Comp("trtri", (b,))
                    yield Coll("scatter", layer, blk // layer.size)
                yield Coll("bcast", depth, blk // layer.size)
            elif strategy == 2:
                yield Coll("allgather", layer, blk // layer.size)
                yield Comp("potrf", (b,))
                yield Comp("trtri", (b,))
            else:  # strategy 3
                if z == 0:
                    yield Coll("allgather", layer, blk // layer.size)
                    yield Comp("potrf", (b,))
                    yield Comp("trtri", (b,))
                yield Coll("bcast", depth, blk // layer.size)

        def chol(m):
            if m <= block:
                yield from base_case(m)
                return
            h = m // 2
            # A11 = L11 L11^T
            yield from chol(h)
            # L21 <- A21 L11^{-T}   (triangular product, 3D)
            yield from matmul3d(h, h, h, kind="trmm")
            # A22 <- A22 - L21 L21^T (symmetric rank-h update, 3D)
            yield from matmul3d(h, h, h, kind="syrk")
            # A22 = L22 L22^T
            yield from chol(h)
            # S21 <- -L22^{-1} L21 L11^{-1}  (two triangular products, 3D)
            yield from matmul3d(h, h, h, kind="trmm")
            yield from matmul3d(h, h, h, kind="trmm")

        yield from chol(n)

    return program
