"""SLATE's task-based tile Cholesky on a 2D processor grid.

Paper §V.A: the matrix is partitioned into tiles of tunable size on a 2D
block-cyclic grid; each tile maintains a predecessor list (trsm and syrk/gemm
updates) and tasks execute as dependencies resolve, with *lookahead
pipelining* of tunable depth prioritizing the tasks the next panel
factorization depends on.  Scheduling uses nonblocking point-to-point
communication (isend/recv), which is how SLATE reduces synchronization
overhead — and why the paper's nonblocking interception path (Figure 2
MPI_Isend / MPI_Wait) is exercised by this study.

Kernel mix: potrf(t), trsm(t), syrk(t), gemm(t) at a FIXED tile size per
configuration — the frequently-recurring same-input-size kernels for which
the paper observes up to 75x reduction in kernel execution time.
"""

from __future__ import annotations

from repro.simmpi import Comp, Isend, Recv, Wait
from repro.simmpi.comm import World


def make_program(world: World, *, n: int, tile: int, lookahead: int,
                 pr: int, pc: int):
    assert pr * pc == world.size
    nt = n // tile
    tb = 8 * tile * tile  # bytes per tile

    def owner(i, j):
        return (i % pr) + pr * (j % pc)

    def program(rank: int, world: World):
        myrow, mycol = rank % pr, rank // pr
        TAG_LKK, TAG_ROW, TAG_COL = 0, 1, 2

        # Ownership is block-cyclic, so "the tiles of column j this rank
        # owns" is the arithmetic progression i ≡ myrow (mod pr), and "the
        # distinct owners of a tile range, in first-touch order" is just
        # the first min(pr, len) (resp. pc) elements of the range: the
        # generators below enumerate these directly instead of scanning
        # every tile and filtering by owner(), which dominated the cold
        # (recording) run's generator cost.  The yielded op stream is
        # bit-identical to the scan-and-filter form (pinned by
        # tests/test_cold_path.py against a reference implementation).

        def my_rows(lo):
            """Rows i >= lo with i ≡ myrow (mod pr), ascending."""
            return range(lo + ((myrow - lo) % pr), nt, pr)

        def panel(k):
            """potrf(k,k) + column-k trsms, with the factored tiles
            broadcast row-wise (for row-i updates) and the transposed
            panel broadcast column-wise (for the L_jk^T operands)."""
            kcol = pr * (k % pc)
            if k % pr == myrow and k % pc == mycol:   # owner(k, k) == rank
                yield Comp("potrf", (tile,))
                # send L_kk down grid column (k % pc) to the trsm owners:
                # distinct owners appear within the first pr rows below k
                for i in range(k + 1, min(k + 1 + pr, nt)):
                    o = (i % pr) + kcol
                    if o != rank:
                        yield Isend(o, tb, (TAG_LKK, k))
            # trsm for owned tiles (i, k), i > k
            if k % pc != mycol:
                return
            my_tiles = my_rows(k + 1)
            if my_tiles and k % pr != myrow:
                yield Recv((k % pr) + kcol, tb, (TAG_LKK, k))
            for i in my_tiles:
                yield Comp("trsm", (tile, tile))
                # row-wise: L_ik to ranks in my grid row owning (i, j>k)
                for j in range(k + 1, min(k + 1 + pc, i + 1)):
                    o = myrow + pr * (j % pc)
                    if o != rank:
                        yield Isend(o, tb, (TAG_ROW, k, i))
                # column-wise: L_ik^T to ranks owning (i' > i, i)
                icol = pr * (i % pc)
                for i2 in range(i, min(i + pr, nt)):
                    o = (i2 % pr) + icol
                    if o != rank:
                        yield Isend(o, tb, (TAG_COL, k, i))

        def recv_for_update(k, i, j, got):
            """Receive the L_ik (row operand) and L_jk (col operand) this
            rank needs for tile (i, j), once per source tile."""
            src_row = owner(i, k)
            if ("r", i) not in got:
                got.add(("r", i))
                if src_row != rank:
                    yield Recv(src_row, tb, (TAG_ROW, k, i))
            src_col = owner(j, k)
            if ("c", j) not in got:
                got.add(("c", j))
                if src_col != rank:
                    yield Recv(src_col, tb, (TAG_COL, k, j))

        def updates(k, js, got):
            """Trailing updates from panel k for tile-columns js."""
            for j in js:
                if j % pc != mycol:
                    continue
                for i in my_rows(j):
                    yield from recv_for_update(k, i, j, got)
                    if i == j:
                        yield Comp("syrk", (tile, tile))
                    else:
                        yield Comp("gemm", (tile, tile, tile))

        # main loop with lookahead: after panel k, the updates feeding the
        # next `lookahead` panels run first so panel k+1 can start before
        # the rest of panel k's trailing matrix is updated.
        deferred = []   # (k, far_columns, got-set)
        for k in range(nt):
            # flush deferred far updates whose lookahead window has passed
            while deferred and deferred[0][0] < k - lookahead:
                dk, djs, dgot = deferred.pop(0)
                yield from updates(dk, djs, dgot)
            yield from panel(k)
            got = set()
            if lookahead > 0:
                near = [j for j in range(k + 1, min(k + 1 + lookahead, nt))]
                far = [j for j in range(k + 1 + lookahead, nt)]
                yield from updates(k, near, got)
                if far:
                    deferred.append((k, far, got))
            else:
                yield from updates(k, list(range(k + 1, nt)), got)
        for dk, djs, dgot in deferred:
            yield from updates(dk, djs, dgot)

    return program
