"""Post-SPMD HLO analysis: collective operand bytes + op census.

``cost_analysis()`` has no collective term, so the roofline's third term is
parsed from the compiled module text.  In scheduled HLO the operand types
are not inlined at the call site, so per-device injected bytes are derived
from the RESULT type and the replica group size g:

    all-reduce          operand = result              (R)
    all-gather          operand = result / g          (each device injects R/g)
    reduce-scatter      operand = result * g          (input is g x output)
    all-to-all          operand = result              (R leaves the device)
    collective-permute  operand = result              (R forwarded)

Async pairs (-start/-done) are counted once at the start op (whose LHS tuple
carries the true operand type — used directly).  Numbers are per-device —
matching cost_analysis()'s per-device flops/bytes, so

    collective_s = per-device collective bytes / link_bw

is algebraically the spec's global-bytes / (chips x link_bw).

NOTE: ops inside while bodies are counted ONCE here; use
``hlo_graph.collective_stats_trip_aware`` for scan-aware totals (the number
the roofline uses).
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.  bf16[32,2048,8,128]   or   f32[]
_TYPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:e[0-9]+m[0-9]+(?:fn)?)?|pred)"
                      r"\[([0-9,]*)\]")
# op name at the assignment site:  %foo.1 = <type(s)> op-name(...)
_OP_RE = re.compile(r"=\s*[^=]*?\s([a-z][a-z0-9-]*)\(")
_GROUPS_BRACKET = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPLICIT = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _nbytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def group_size(line: str) -> int:
    m = _GROUPS_BRACKET.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPLICIT.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def parse_collective_line(line: str) -> Optional[Tuple[str, int]]:
    """(base op kind, per-device operand bytes) or None."""
    m = _OP_RE.search(line)
    if not m:
        return None
    op = m.group(1)
    base = op[:-6] if op.endswith("-start") else op
    if base not in COLLECTIVES or op.endswith("-done"):
        return None
    lhs = line[:m.end() - len(base) - (6 if op.endswith("-start") else 0) - 1]
    types = _TYPE_RE.findall(lhs)
    if not types:
        return base, 0
    g = group_size(line)
    if op.endswith("-start") and len(types) >= 2:
        nbytes = _nbytes(*types[0])          # explicit operand in the tuple
    else:
        result = _nbytes(*types[0])
        if base == "all-gather":
            nbytes = result // max(g, 1)
        elif base == "reduce-scatter":
            nbytes = result * g
        else:
            nbytes = result
    return base, nbytes


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def rows(self) -> List[Tuple[str, int, int]]:
        return sorted(
            ((k, self.count_by_kind[k], self.bytes_by_kind[k])
             for k in self.bytes_by_kind),
            key=lambda r: -r[2])


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Flat (trip-unaware) per-device operand-bytes census."""
    by = defaultdict(int)
    cnt = defaultdict(int)
    for line in hlo_text.splitlines():
        parsed = parse_collective_line(line)
        if parsed:
            base, nbytes = parsed
            by[base] += nbytes
            cnt[base] += 1
    return CollectiveStats(dict(by), dict(cnt))


def op_census(hlo_text: str, ops=("dot", "fusion", "custom-call",
                                  "dynamic-slice", "dynamic-update-slice",
                                  "transpose", "reshape", "while")) -> Dict[str, int]:
    cnt = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m and m.group(1) in ops:
            cnt[m.group(1)] += 1
    return dict(cnt)


_UPCAST_RE = re.compile(
    r"^(?:ROOT\s+)?%(\S+)\s+= f32\[([0-9,]+)\]\S*\s+"
    r"(?:fusion|convert|copy)\(%param(?:\.|\d)")


def cpu_upcast_bytes(hlo_text: str) -> int:
    """Bytes of f32 copies of bf16 ENTRY parameters.

    The XLA CPU backend emulates bf16 by upconverting operands to f32; these
    buffers would not exist on a TPU (native bf16 compute).  Subtracting
    them from temp_size gives the TPU-honest memory estimate the dry-run's
    fits-in-HBM check uses.  Only converts of entry parameters inside the
    ENTRY computation are counted (the unambiguous backend artifacts),
    deduplicated by result name.
    """
    from .hlo_graph import split_computations  # local import, no cycle
    comps, entry = split_computations(hlo_text)
    if entry is None:
        return 0
    seen = set()
    total = 0
    for line in comps[entry]:
        m = _UPCAST_RE.match(line)
        if m and m.group(1) not in seen:
            seen.add(m.group(1))
            total += _nbytes("f32", m.group(2))
    return total


# hardware constants (TPU v5e target)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> dict:
    """The three per-step roofline terms in seconds (per-device numbers)."""
    compute_s = flops_per_dev / PEAK_FLOPS
    memory_s = bytes_per_dev / HBM_BW
    collective_s = coll_bytes_per_dev / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s,
             "hlo_flops_per_dev": flops_per_dev,
             "hlo_bytes_per_dev": bytes_per_dev,
             "collective_bytes_per_dev": coll_bytes_per_dev}
    terms["bound"] = max(
        ("compute", compute_s), ("memory", memory_s),
        ("collective", collective_s), key=lambda kv: kv[1])[0]
    terms["step_s"] = max(compute_s, memory_s, collective_s)
    return terms
