"""Trip-count-aware collective accounting over compiled HLO text.

``hlo_analysis.collective_stats`` counts each collective op once; ops inside
a ``while`` body (every lax.scan) execute trip-count times.  This module
splits the module text into computations, walks the call graph from ENTRY,
multiplies by while trip counts — taken from XLA's
``backend_config={"known_trip_count":{"n":"N"}}`` when present, else from
the loop condition's compare constant — and sums collective operand bytes
with multiplicity.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from .hlo_analysis import CollectiveStats, parse_collective_line

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_CALLED = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)")
_WHILE_REFS = re.compile(r"(body|condition)=%?([\w.\-]+)")
_TRIP = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CONST_INT = re.compile(r"constant\((\d+)\)")


def split_computations(hlo: str) -> Tuple[Dict[str, List[str]], Optional[str]]:
    comps: Dict[str, List[str]] = {}
    entry = None
    cur: Optional[str] = None
    depth = 0
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and "->" in stripped:
                m = _COMP_HDR.match(stripped)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
                    depth = 1
                    if stripped.startswith("ENTRY"):
                        entry = cur
            continue
        depth += stripped.count("{") - stripped.count("}")
        if depth <= 0:
            cur = None
            continue
        comps[cur].append(stripped)
    return comps, entry


def _trip_count(line: str, cond_lines: List[str]) -> int:
    m = _TRIP.search(line)
    if m:
        return int(m.group(1))
    best = 1
    for ln in cond_lines:
        for m in _CONST_INT.finditer(ln):
            best = max(best, int(m.group(1)))
    return best


def collective_stats_trip_aware(hlo: str) -> CollectiveStats:
    comps, entry = split_computations(hlo)
    if entry is None:
        return CollectiveStats({}, {})
    by = defaultdict(float)
    cnt = defaultdict(float)

    def walk(name: str, mult: float, seen: tuple):
        if name not in comps or name in seen:
            return
        seen = seen + (name,)
        for ln in comps[name]:
            parsed = parse_collective_line(ln)
            if parsed:
                base, nbytes = parsed
                by[base] += nbytes * mult
                cnt[base] += mult
            if " while(" in ln:
                refs = dict(_WHILE_REFS.findall(ln))
                body, cond = refs.get("body"), refs.get("condition")
                trip = _trip_count(ln, comps.get(cond, []))
                if body:
                    walk(body, mult * trip, seen)
                continue
            for ref in _CALLED.findall(ln):
                if ref in comps:
                    walk(ref, mult, seen)

    walk(entry, 1.0, ())
    return CollectiveStats({k: int(v) for k, v in by.items()},
                          {k: int(v) for k, v in cnt.items()})


def while_census(hlo: str) -> List[Tuple[str, int]]:
    """(body name, trip count) of every while op — remat/unroll debugging."""
    comps, _ = split_computations(hlo)
    out = []
    for name, lines in comps.items():
        for ln in lines:
            if " while(" in ln:
                refs = dict(_WHILE_REFS.findall(ln))
                trip = _trip_count(ln, comps.get(refs.get("condition"), []))
                out.append((refs.get("body", "?"), trip))
    return out
