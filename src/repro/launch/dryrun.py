import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

Lowers + compiles every runnable (architecture x input shape) cell on the
single-pod 16x16 mesh AND the 2x16x16 multi-pod mesh, prints
memory_analysis()/cost_analysis(), and writes one JSON artifact per cell
under --out (consumed by benchmarks/roofline.py and EXPERIMENTS.md).

The two os.environ lines above MUST run before any other import — jax locks
the device count at first init.

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
  python -m repro.launch.dryrun --all --variant tp --suffix _tp
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, all_cells, get_config
from repro.launch.cells import build_cell, model_flops, DRYRUN_KNOBS
from repro.launch.hlo_analysis import (collective_stats, cpu_upcast_bytes,
                                       op_census, roofline_terms)
from repro.launch.hlo_graph import collective_stats_trip_aware, while_census
from repro.launch.jaxpr_cost import cost_of
from repro.launch.mesh import make_production_mesh

DEFAULT_OUT = os.path.join(os.path.dirname(__file__),
                           "..", "..", "..", "benchmarks", "artifacts")


def run_cell(arch: str, shape: str, *, multi_pod: bool, variant: str = "cp",
             knobs=None, verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = build_cell(arch, shape, mesh, variant=variant, knobs=knobs)
    t0 = time.time()
    lowered = cell.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    n_chips = mesh.devices.size

    # scan-aware program cost (global) from the jaxpr — XLA's cost analysis
    # visits while bodies once and is kept only as a reference lower bound
    t0 = time.time()
    jc = cost_of(cell.fn, *cell.args)
    t_jaxpr = time.time() - t0
    coll = collective_stats_trip_aware(hlo)
    coll_flat = collective_stats(hlo)
    flops_per_dev = jc.flops / n_chips
    bytes_per_dev = jc.bytes / n_chips
    terms = roofline_terms(flops_per_dev, bytes_per_dev, coll.total_bytes)

    mf = model_flops(cell.cfg, SHAPES[shape])
    _upc = cpu_upcast_bytes(hlo)
    _live = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
             - mem.alias_size_in_bytes - _upc)
    rec = {
        "arch": arch, "shape": shape, "variant": variant,
        "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
        "chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "jaxpr_cost_s": round(t_jaxpr, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": mem.peak_memory_in_bytes,
            # f32 copies of bf16 entry params: CPU-backend bf16 emulation,
            # absent on TPU — subtracted for the fits-in-HBM estimate
            "cpu_upcast_bytes": _upc,
            "live_tpu_est_bytes": _live,
            "fits_16g": _live <= 16 * (1 << 30),
        },
        "jaxpr_cost": {"flops_global": jc.flops, "bytes_global": jc.bytes,
                       "dot_flops_global": jc.dot_flops},
        "xla_cost_raw": {k: cost[k] for k in ("flops", "bytes accessed")
                         if k in cost},
        "collectives": {
            "total_bytes_per_dev": coll.total_bytes,
            "by_kind": coll.bytes_by_kind,
            "counts": coll.count_by_kind,
            "flat_bytes_per_dev": coll_flat.total_bytes,
        },
        "ops": op_census(hlo),
        "whiles": while_census(hlo),
        "roofline": terms,
        "model_flops_global": mf,
        "useful_flops_ratio": (mf / jc.flops if jc.flops else 0.0),
    }
    if verbose:
        gb = 1 << 30
        upc, live = _upc, _live
        print(f"[{arch} x {shape} x {variant} @ "
              f"{'x'.join(map(str, mesh.devices.shape))}] "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  mem/dev: args {mem.argument_size_in_bytes / gb:.2f} GiB"
              f" temps {mem.temp_size_in_bytes / gb:.2f} GiB"
              f" cpu-upcast {upc / gb:.2f} GiB"
              f" -> live(TPU est) {live / gb:.2f} GiB"
              f" (fits 16 GiB: {live / gb <= 16.0})")
        print(f"  flops/dev {terms['hlo_flops_per_dev']:.3e}"
              f"  bytes/dev {terms['hlo_bytes_per_dev']:.3e}"
              f"  coll bytes/dev {terms['collective_bytes_per_dev']:.3e}")
        print(f"  roofline s: compute {terms['compute_s']:.4f}"
              f" memory {terms['memory_s']:.4f}"
              f" collective {terms['collective_s']:.4f}"
              f"  -> {terms['bound']}-bound;"
              f" useful-flops ratio {rec['useful_flops_ratio']:.3f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--variant", default="cp")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--suffix", default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.all:
        cells = all_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}_{shape}_{'multi' if mp else 'single'}" \
                  f"{args.suffix}"
            path = os.path.join(args.out, tag + ".json")
            if args.skip_existing and os.path.exists(path):
                print(f"[skip] {tag}")
                continue
            try:
                rec = run_cell(arch, shape, multi_pod=mp,
                               variant=args.variant)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
            except Exception as e:  # a failure here is a bug in the system
                failures.append((tag, repr(e)))
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(" ", tag, err[:200])
        raise SystemExit(1)
    print("\ndry-run complete: all cells lowered + compiled.")


if __name__ == "__main__":
    main()
