"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \\
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Runs the full stack: config -> model -> sharded train step (when a mesh is
requested) -> synthetic data pipeline -> checkpoint/restart.  Auto-resumes
from the latest checkpoint in --ckpt-dir (fault tolerance: kill it at any
step and rerun the same command).
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config
from repro.configs.base import Shape
from repro.models.model import Model, ModelKnobs
from repro.parallel.sharding import make_rules
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, batch_iterator, make_global_batch
from repro.train.optim import AdamWConfig, adamw_init
from repro.train.step import (TrainConfig, batch_shardings, make_train_step,
                              param_shardings, opt_shardings)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-axis", type=int, default=0,
                    help="use a (data, model) host mesh with this model size")
    ap.add_argument("--variant", default="cp")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    shape = Shape("cli", args.seq, args.batch, "train")
    knobs = ModelKnobs(kv_chunk=min(64, args.seq),
                       ssm_chunk=min(32, args.seq))
    model = Model(cfg, knobs)
    rules = None
    if args.model_axis:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(model=args.model_axis)
        rules = make_rules(args.variant).with_mesh(mesh)

    tc = TrainConfig(grad_accum=args.grad_accum,
                     optimizer=AdamWConfig(lr=args.lr, warmup=10,
                                           decay_steps=args.steps))
    step_fn = make_train_step(model, rules, tc)
    key = jax.random.PRNGKey(args.seed)

    start = 0
    if args.ckpt_dir and (latest := ckpt.latest_step(args.ckpt_dir)) is not None:
        params_like = jax.eval_shape(model.init, key)
        like = {"params": params_like,
                "opt": jax.eval_shape(adamw_init, params_like)}
        sh = None
        if rules is not None:
            ps = param_shardings(model, rules)
            sh = {"params": ps, "opt": opt_shardings(model, rules)}
        tree, man = ckpt.restore(args.ckpt_dir, latest, like, shardings=sh)
        params, opt_state = tree["params"], tree["opt"]
        start = man["step"]
        print(f"resumed from step {start}")
    else:
        params = model.init(key)
        opt_state = adamw_init(params)
        if rules is not None:
            from repro.train.step import shard_params
            params = shard_params(model, params, rules)

    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    it = batch_iterator(cfg, shape, DataConfig(seed=args.seed),
                        start_step=start)
    t0 = time.time()
    for i in range(start, args.steps):
        host_batch = next(it)
        batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (i + 1) % args.log_every == 0 or i == start:
            loss = float(metrics["loss"])
            dt = (time.time() - t0) / max(i + 1 - start, 1)
            print(f"step {i + 1:5d}  loss {loss:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"{dt * 1e3:.0f} ms/step")
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, i + 1,
                      {"params": params, "opt": opt_state}, keep=3)
    print("done:", args.steps, "steps")
    return params


if __name__ == "__main__":
    main()
