"""Scan-aware analytic cost model over jaxprs.

XLA's HloCostAnalysis (what ``compiled.cost_analysis()`` reports) visits a
``while`` body ONCE, so any scan-over-layers program under-reports flops and
bytes by ~the trip count (verified empirically on this container: a 30-layer
smollm train step reports only the unscanned head matmul).  This module
counts costs from the *jaxpr*, where scan lengths are static and explicit:

- flops: dot_general/conv exact (2·prod(out)·prod(contracted)), elementwise
  counted at 1 flop/element, scans multiply their body by the trip count,
  remat'd recomputation appears explicitly in grad jaxprs and is counted;
- bytes: a "materialization points" model of post-fusion HBM traffic —
  operands+results of dot_general, gather/scatter, dynamic slices, reduces,
  sorts, concatenates, and per-iteration scan carries/slices are counted;
  elementwise/broadcast/convert/transpose are assumed fused (0 bytes).
  Top-level arguments and outputs (params, optimizer state, batch) are
  counted once each.

Numbers are GLOBAL (whole-step); divide by chip count for per-device terms
under an even-sharding assumption (the dry-run's input shardings make that
assumption true for the dominant tensors).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict

import jax
import numpy as np
from jax import core
from jax._src import core as jcore

ELEMENTWISE_FREE = {
    "broadcast_in_dim", "convert_element_type", "transpose", "reshape",
    "squeeze", "rev", "iota", "constant", "copy", "stop_gradient",
    "slice", "pad", "select_n", "bitcast_convert_type",
}

MATERIALIZING = {
    "gather", "scatter", "scatter-add", "scatter_add", "dynamic_slice",
    "dynamic_update_slice", "concatenate", "sort", "argsort", "cumsum",
    "cumlogsumexp", "reduce_sum", "reduce_max", "reduce_min", "reduce_and",
    "reduce_or", "reduce_prod", "top_k",
}


def _size(v) -> int:
    aval = v.aval if hasattr(v, "aval") else v
    if not hasattr(aval, "shape"):
        return 0
    n = int(np.prod(aval.shape)) if aval.shape else 1
    return n * getattr(aval.dtype, "itemsize", 4)


def _numel(v) -> int:
    aval = v.aval if hasattr(v, "aval") else v
    if not hasattr(aval, "shape"):
        return 0
    return int(np.prod(aval.shape)) if aval.shape else 1


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    dot_flops: float = 0.0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.dot_flops += other.dot_flops * mult


def _dot_flops(eqn) -> float:
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    lhs = eqn.invars[0].aval
    out = eqn.outvars[0].aval
    contracted = 1
    for d in lc:
        contracted *= lhs.shape[d]
    return 2.0 * float(np.prod(out.shape) if out.shape else 1) * contracted


def _conv_flops(eqn) -> float:
    rhs = eqn.invars[1].aval           # kernel
    out = eqn.outvars[0].aval
    dn = eqn.params["dimension_numbers"]
    k_spatial = [rhs.shape[d] for d in dn.rhs_spec[2:]]
    in_ch = rhs.shape[dn.rhs_spec[1]]
    return 2.0 * float(np.prod(out.shape)) * float(np.prod(k_spatial)) * in_ch


def jaxpr_cost(jaxpr: core.Jaxpr, *, _top: bool = True) -> Cost:
    total = Cost()
    if _top:
        io = sum(_size(v) for v in jaxpr.invars) + \
            sum(_size(v) for v in jaxpr.outvars)
        total.bytes += io
    for eqn in jaxpr.eqns:
        total.add(_eqn_cost(eqn))
    return total


def _sub(jaxpr_like) -> Cost:
    j = jaxpr_like.jaxpr if hasattr(jaxpr_like, "jaxpr") else jaxpr_like
    return jaxpr_cost(j, _top=False)


def _eqn_cost(eqn) -> Cost:
    prim = eqn.primitive.name
    c = Cost()
    if prim == "dot_general":
        f = _dot_flops(eqn)
        c.flops += f
        c.dot_flops += f
        c.bytes += sum(_size(v) for v in eqn.invars) + \
            sum(_size(v) for v in eqn.outvars)
        return c
    if prim == "conv_general_dilated":
        f = _conv_flops(eqn)
        c.flops += f
        c.dot_flops += f
        c.bytes += sum(_size(v) for v in eqn.invars) + \
            sum(_size(v) for v in eqn.outvars)
        return c
    if prim == "scan":
        length = eqn.params["length"]
        body = _sub(eqn.params["jaxpr"])
        c.add(body, mult=length)
        # per-iteration carry + xs/ys slice traffic
        n_carry = eqn.params["num_carry"]
        n_consts = eqn.params["num_consts"]
        carry_bytes = sum(_size(v) for v in eqn.invars[n_consts:
                                                       n_consts + n_carry])
        xs_bytes = sum(_size(v) for v in eqn.invars[n_consts + n_carry:])
        ys_bytes = sum(_size(v) for v in eqn.outvars[n_carry:])
        c.bytes += length * 2.0 * carry_bytes + xs_bytes + ys_bytes
        return c
    if prim == "while":
        # not statically bounded; count once (our programs use scan)
        c.add(_sub(eqn.params["body_jaxpr"]))
        c.add(_sub(eqn.params["cond_jaxpr"]))
        return c
    if prim == "cond":
        branches = eqn.params["branches"]
        costs = [_sub(b) for b in branches]
        worst = max(costs, key=lambda x: x.flops + x.bytes)
        c.add(worst)
        return c
    # generic recursion: any primitive carrying sub-jaxprs (pjit, remat/
    # checkpoint, custom_vjp, shard_map, ...) is charged its body's cost.
    # shard_map bodies are PER-SHARD programs: multiply by the number of
    # mapped shards so totals stay global.
    mult = 1.0
    if prim == "shard_map" and "mesh" in eqn.params:
        msh = eqn.params["mesh"]
        try:
            mult = float(np.prod(list(msh.shape.values())))
        except Exception:
            mult = float(getattr(msh, "size", 1))
    subs = []
    for v in eqn.params.values():
        if isinstance(v, (jcore.Jaxpr, jcore.ClosedJaxpr)):
            subs.append(v)
        elif isinstance(v, (tuple, list)):
            subs.extend(e for e in v
                        if isinstance(e, (jcore.Jaxpr, jcore.ClosedJaxpr)))
    if subs:
        for s in subs:
            c.add(_sub(s), mult=mult)
        return c
    if prim in ELEMENTWISE_FREE:
        return c
    # reductions / gathers / scatters / sorts: materialize
    base = prim.split("[")[0]
    out_elems = sum(_numel(v) for v in eqn.outvars)
    c.flops += out_elems            # 1 flop/element elementwise model
    if base in MATERIALIZING or prim.startswith(("reduce", "scatter",
                                                 "gather", "cum", "sort")):
        c.bytes += sum(_size(v) for v in eqn.invars) + \
            sum(_size(v) for v in eqn.outvars)
    return c


def cost_of(fn, *args) -> Cost:
    """Trace fn(*args) (ShapeDtypeStructs fine) and return its Cost."""
    closed = jax.make_jaxpr(fn)(*args)
    return jaxpr_cost(closed.jaxpr)
