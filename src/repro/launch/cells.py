"""Cell builder: (arch x shape x mesh x sharding-variant x knobs) -> a
lowerable step function with fully-specified input shardings and
ShapeDtypeStruct arguments.  Shared by the dry-run, the roofline benchmarks
and the LM autotuner (tune/), so every consumer lowers the SAME programs.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ArchConfig, Shape
from repro.models.model import Model, ModelKnobs
from repro.parallel.sharding import (ShardingRules, axis_rules, make_rules,
                                     map_axes)
from repro.train.optim import adamw_init
from repro.train.step import TrainConfig, make_train_step

DRYRUN_KNOBS = ModelKnobs(kv_chunk=512, ssm_chunk=256, remat="full",
                          param_dtype=jnp.bfloat16,
                          compute_dtype=jnp.bfloat16)

# baseline microbatching: 4 grad-accumulation slices (a tuning knob; the
# paper-faithful baseline just needs to FIT — see EXPERIMENTS.md §Perf)
DRYRUN_TRAIN = TrainConfig(grad_accum=4)


@dataclass
class Cell:
    arch: str
    shape: str
    variant: str
    fn: Callable
    args: Tuple            # ShapeDtypeStructs
    in_shardings: Tuple
    donate: Tuple[int, ...]
    cfg: ArchConfig
    model: Model
    rules: ShardingRules

    def lower(self):
        jfn = jax.jit(self.fn, in_shardings=self.in_shardings,
                      donate_argnums=self.donate)
        return jfn.lower(*self.args)


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _ns(rules: ShardingRules, axes_tree, sds_tree):
    def one(ax, sds):
        return NamedSharding(rules.mesh, rules.spec(*ax, dims=sds.shape))
    return map_axes(one, axes_tree, sds_tree)


def batch_sds(cfg: ArchConfig, shape: Shape, knobs: ModelKnobs):
    """(ShapeDtypeStructs, logical axes) for a train/prefill batch."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.n_patches:
        S_text = S - cfg.n_patches
        sds = {"tokens": jax.ShapeDtypeStruct((B, S_text), i32),
               "labels": jax.ShapeDtypeStruct((B, S_text), i32),
               "patches": jax.ShapeDtypeStruct(
                   (B, cfg.n_patches, cfg.d_model), knobs.compute_dtype)}
        axes = {"tokens": ("batch", "seq"), "labels": ("batch", "seq"),
                "patches": ("batch", None, None)}
    elif cfg.n_codebooks:
        sds = {"tokens": jax.ShapeDtypeStruct((B, S, cfg.n_codebooks), i32),
               "labels": jax.ShapeDtypeStruct((B, S, cfg.n_codebooks), i32)}
        axes = {"tokens": ("batch", "seq", None),
                "labels": ("batch", "seq", None)}
    else:
        sds = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
               "labels": jax.ShapeDtypeStruct((B, S), i32)}
        axes = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    if shape.kind == "prefill":
        sds.pop("labels")
        axes.pop("labels")
    return sds, axes


def build_cell(arch: str, shape_name: str, mesh: Mesh, *,
               variant: str = "cp", knobs: Optional[ModelKnobs] = None,
               tc: Optional[TrainConfig] = None) -> Cell:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    knobs = knobs or DRYRUN_KNOBS
    if tc is None:
        # >50B-param archs need deeper accumulation to fit activations;
        # bf16 grad accumulation adopted as their default after §Perf H3
        big = cfg.param_counts()["total"] > 50e9
        tc = TrainConfig(grad_accum=8, accum_dtype=jnp.bfloat16) if big \
            else DRYRUN_TRAIN
    rules = make_rules(variant).with_mesh(mesh)
    model = Model(cfg, knobs)

    params_sds = _sds(jax.eval_shape(model.init, jax.random.PRNGKey(0)))
    p_sh = _ns(rules, model.param_axes(), params_sds)

    if shape.kind == "train":
        opt_sds = jax.eval_shape(adamw_init, params_sds)
        o_sh = {"m": p_sh, "v": p_sh,
                "step": NamedSharding(mesh, P())}
        b_sds, b_axes = batch_sds(cfg, shape, knobs)
        b_sh = _ns(rules, b_axes, b_sds)
        fn = make_train_step(model, rules, tc)
        return Cell(arch, shape_name, variant, fn,
                    (params_sds, opt_sds, b_sds), (p_sh, o_sh, b_sh),
                    (0, 1), cfg, model, rules)

    if shape.kind == "prefill":
        b_sds, b_axes = batch_sds(cfg, shape, knobs)
        b_sh = _ns(rules, b_axes, b_sds)

        def prefill_fn(params, batch):
            with axis_rules(rules):
                return model.prefill(params, batch, shape.seq_len)

        return Cell(arch, shape_name, variant, prefill_fn,
                    (params_sds, b_sds), (p_sh, b_sh), (), cfg, model, rules)

    # decode: one new token against a seq_len cache
    B, S = shape.global_batch, shape.seq_len
    cache_sds = _sds(jax.eval_shape(partial(model.init_cache, B, S)))
    c_sh = _ns(rules, model.cache_axes(), cache_sds)
    t_sds = jax.ShapeDtypeStruct((B,), jnp.int32)
    t_sh = NamedSharding(mesh, rules.spec("batch", dims=(B,)))
    trail = (cfg.n_codebooks,) if cfg.n_codebooks else ()
    tok_sds = {"tokens": jax.ShapeDtypeStruct((B, 1) + trail, jnp.int32)}
    tok_ax = {"tokens": ("batch", None) + ((None,) if trail else ())}
    tok_sh = _ns(rules, tok_ax, tok_sds)

    def serve_step(params, cache, t, batch):
        with axis_rules(rules):
            return model.decode_step(params, cache, t, batch)

    return Cell(arch, shape_name, variant, serve_step,
                (params_sds, cache_sds, t_sds, tok_sds),
                (p_sh, c_sh, t_sh, tok_sh), (1,), cfg, model, rules)


def model_flops(cfg: ArchConfig, shape: Shape) -> float:
    """MODEL_FLOPS: 6·N·D (train) / 2·N·D (inference), N = active body
    params + embed/head matmul params, D = tokens processed per step."""
    pc = cfg.param_counts()
    n_active = pc["body_active"] + pc["embed"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch
