"""End-to-end serving driver.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \\
        --reduced --requests 16 --max-new 24

Spins up the slot-based engine on a (reduced) model with random weights and
replays a batch of synthetic prompts, reporting aggregate decode throughput.

With ``--daemon``, instead drives simulated traffic through the always-on
tuning daemon (``repro.serve.tuner.run_daemon_demo``): shape misses open
background studies, later shapes warm-start from the fleet store, and an
injected kernel-cost shift exercises the drift -> re-tune path without
serving ever stopping.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import Model, ModelKnobs
from repro.serve.engine import Engine, Request, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--daemon", action="store_true",
                    help="run the always-on tuning daemon demo instead")
    ap.add_argument("--rounds", type=int, default=4,
                    help="steady-state serving rounds (daemon demo)")
    ap.add_argument("--bank", default=None,
                    help="save the fleet statistics bank here (daemon demo)")
    ap.add_argument("--checkpoint", default=None,
                    help="daemon checkpoint path (daemon demo)")
    args = ap.parse_args(argv)

    if args.daemon:
        return _daemon_demo(args)

    cfg = get_config(args.arch, reduced=args.reduced)
    model = Model(cfg, ModelKnobs(kv_chunk=32, ssm_chunk=16))
    params = model.init(jax.random.PRNGKey(args.seed))
    eng = Engine(model, params, ServeConfig(
        batch_size=args.batch, s_max=args.s_max,
        max_new_tokens=args.max_new, temperature=args.temperature,
        seed=args.seed))
    rng = np.random.default_rng(args.seed)
    for uid in range(args.requests):
        n = int(rng.integers(4, 32))
        shape = (n, cfg.n_codebooks) if cfg.n_codebooks else (n,)
        eng.submit(Request(uid, rng.integers(0, cfg.vocab, size=shape)
                           .astype(np.int32)))
    t0 = time.time()
    steps = 0
    while eng.queue or eng.active.any():
        eng.step()
        steps += 1
    dt = time.time() - t0
    toks = sum(len(r.tokens) for r in eng.results.values())
    print(f"{args.requests} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, {steps} engine steps)")
    for uid in sorted(eng.results)[:4]:
        print(f"  req {uid}: {eng.results[uid].tokens[:12]} ...")
    return eng.results


def _daemon_demo(args) -> dict:
    from repro.serve.tuner import run_daemon_demo

    summary = run_daemon_demo(
        args.arch, rounds=args.rounds, checkpoint=args.checkpoint,
        bank_path=args.bank, log=print)
    r = summary["ratios"]
    print(f"hit ratio {r['hit_ratio']:.2f}, warm-start ratio "
          f"{r['warm_start_ratio']:.2f}, drift detected: "
          f"{summary['drift_detected']}, re-tunes: {summary['retunes']}, "
          f"served while re-tuning: {summary['served_while_retuning']}")
    for key, info in summary["second_tuned_serves"].items():
        print(f"  2nd tuned serve {key}: {info}")
    return summary


if __name__ == "__main__":
    main()
