"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, and smoke tests must keep seeing 1 device.

Single pod: 16 x 16 = 256 chips (axes data, model).
Multi-pod:  2 x 16 x 16 = 512 chips (axes pod, data, model) — the 'pod'
axis carries pure data parallelism (optionally pipeline stages) whose
collectives cross the inter-pod links.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes,
                     axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1, *, pod: int = 0) -> Mesh:
    """Small mesh over however many (fake) devices exist — tests use 8."""
    n = len(jax.devices())
    if pod:
        assert n % (pod * model) == 0
        shape = (pod, n // (pod * model), model)
        axes = ("pod", "data", "model")
    else:
        assert n % model == 0
        shape = (n // model, model)
        axes = ("data", "model")
    return make_mesh(shape, axes,
                     axis_types=(AxisType.Auto,) * len(axes))
