"""configs — the 10 assigned architectures + the 4 input shapes.

``get_config(name)`` returns the exact published configuration;
``get_config(name, reduced=True)`` the small same-family smoke variant.
``input_specs(cfg, shape, mesh)`` builds sharded ShapeDtypeStruct stand-ins
for every model input (no device allocation) for the dry-run.
"""

from __future__ import annotations

from typing import Dict, List

from .base import ArchConfig, MLAConfig, MoEConfig, SHAPES, Shape, \
    supported_shapes

from . import (musicgen_large, smollm_135m, yi_34b, llama32_3b, granite_3_8b,
               xlstm_125m, internvl2_2b, phi35_moe, deepseek_v2, jamba_52b)

ARCHS: Dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (musicgen_large, smollm_135m, yi_34b, llama32_3b, granite_3_8b,
              xlstm_125m, internvl2_2b, phi35_moe, deepseek_v2, jamba_52b)
}

# short aliases for --arch flags
ALIASES = {
    "musicgen-large": "musicgen-large",
    "smollm-135m": "smollm-135m",
    "yi-34b": "yi-34b",
    "llama3.2-3b": "llama3.2-3b",
    "granite-3-8b": "granite-3-8b",
    "xlstm-125m": "xlstm-125m",
    "internvl2-2b": "internvl2-2b",
    "phi3.5-moe": "phi3.5-moe-42b-a6.6b",
    "phi3.5-moe-42b-a6.6b": "phi3.5-moe-42b-a6.6b",
    "deepseek-v2": "deepseek-v2-236b",
    "deepseek-v2-236b": "deepseek-v2-236b",
    "jamba-v0.1-52b": "jamba-v0.1-52b",
    "jamba": "jamba-v0.1-52b",
}


def get_config(name: str, reduced: bool = False) -> ArchConfig:
    cfg = ARCHS[ALIASES.get(name, name)]
    return cfg.reduced() if reduced else cfg


def all_cells() -> List[tuple]:
    """Every runnable (arch, shape) cell (32 cells; 8 documented skips)."""
    cells = []
    for name, cfg in ARCHS.items():
        for shape in supported_shapes(cfg):
            cells.append((name, shape))
    return cells


__all__ = ["ArchConfig", "MLAConfig", "MoEConfig", "SHAPES", "Shape",
           "ARCHS", "ALIASES", "get_config", "all_cells", "supported_shapes"]
