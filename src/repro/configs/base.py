"""Architecture configuration schema + the four assigned input shapes.

Every assigned architecture is expressed as an ArchConfig; the model code
(models/model.py) consumes only this schema.  ``reduced()`` produces the
small same-family variant used by the per-arch CPU smoke tests; the full
configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, Shape] = {
    "train_4k":    Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k":  Shape("decode_32k", 32768, 128, "decode"),
    "long_500k":   Shape("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    q_lora: int = 1536
    d_rope: int = 64
    d_nope: int = 128
    d_v: int = 128


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | ssm | vlm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # layer pattern: kinds within one scan period, cycled over n_layers.
    # kinds: 'attn', 'mla', 'mamba', 'mlstm', 'slstm'
    pattern: Tuple[str, ...] = ("attn",)
    # ffn kind per pattern position: 'dense' | 'moe' | 'none'
    ffn_pattern: Tuple[str, ...] = ("dense",)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    d_head: int = 0           # 0 => d_model // n_heads
    # ssm
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    # frontend stubs
    n_codebooks: int = 0      # musicgen: EnCodec codebooks
    n_patches: int = 0        # internvl2: ViT patch embeddings (stubbed)
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    # whether attention is full/quadratic (drives the long_500k skip)
    subquadratic: bool = False
    tie_embeddings: bool = False

    def __post_init__(self):
        assert self.n_layers % len(self.pattern) == 0, \
            f"{self.name}: n_layers {self.n_layers} % period {len(self.pattern)}"
        assert len(self.pattern) == len(self.ffn_pattern)

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    # -- parameter counting (used for MODEL_FLOPS and roofline) -------------

    def param_counts(self) -> Dict[str, float]:
        D, F, V = self.d_model, self.d_ff, self.vocab
        H, KV, dh = self.n_heads, self.n_kv_heads, self.head_dim
        per_kind: Dict[str, float] = {}
        mixer = {}
        mixer["attn"] = D * (H * dh) + 2 * D * (KV * dh) + (H * dh) * D
        if self.mla:
            m = self.mla
            mixer["mla"] = (D * m.q_lora + m.q_lora * H * (m.d_nope + m.d_rope)
                            + D * (m.kv_lora + m.d_rope)
                            + m.kv_lora * H * (m.d_nope + m.d_v)
                            + H * m.d_v * D)
        di = self.d_inner
        mixer["mamba"] = (D * 2 * di + di * self.d_conv
                          + di * (di // 16 + 2 * self.d_state)
                          + (di // 16) * di + 2 * di + di * D)
        mixer["mlstm"] = D * 3 * di + 3 * di + di * D + D * 2 * di + di * D
        mixer["slstm"] = 4 * D * D + 4 * D + D * 2 * di + di * D
        ffn = {"dense": 3 * D * F, "none": 0.0}
        if self.moe:
            e = self.moe
            ffn["moe"] = ((e.n_experts + e.n_shared) * 3 * D * e.d_ff_expert
                          + D * e.n_experts)
            ffn["moe_active"] = ((e.top_k + e.n_shared) * 3 * D * e.d_ff_expert
                                 + D * e.n_experts)
        total = 0.0
        active = 0.0
        for kind, fk in zip(self.pattern, self.ffn_pattern):
            total += mixer[kind] + ffn[fk]
            active += mixer[kind] + ffn.get(
                fk + "_active", ffn[fk]) if fk == "moe" else mixer[kind] + ffn[fk]
        total *= self.n_periods
        active *= self.n_periods
        n_embed_tables = max(self.n_codebooks, 1)
        embed = n_embed_tables * V * D
        head = D * V * n_embed_tables if not self.tie_embeddings else 0.0
        return {"total": total + embed + head,
                "active": active + embed + head,
                "body": total, "body_active": active,
                "embed": embed + head}

    def reduced(self) -> "ArchConfig":
        """Small same-family variant for CPU smoke tests: fewer/narrower
        layers, few experts, tiny vocab — same structure."""
        period = self.period
        moe = None
        if self.moe:
            moe = replace(self.moe, n_experts=4,
                          top_k=min(self.moe.top_k, 2), d_ff_expert=64,
                          n_shared=min(self.moe.n_shared, 1))
        mla = None
        if self.mla:
            mla = MLAConfig(kv_lora=32, q_lora=48, d_rope=8, d_nope=16, d_v=16)
        dh = 8
        return replace(
            self, n_layers=period * 2, d_model=64,
            n_heads=min(self.n_heads, 4), n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=128 if self.d_ff else 0, vocab=256,
            moe=moe, mla=mla, d_head=dh, d_state=4, d_conv=4,
            n_patches=8 if self.n_patches else 0)


def supported_shapes(cfg: ArchConfig) -> List[str]:
    """The runnable (arch x shape) cells.  long_500k requires sub-quadratic
    attention (skip for pure full-attention archs, DESIGN.md §4)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        out.append("long_500k")
    return out
