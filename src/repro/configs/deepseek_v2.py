"""deepseek-v2-236b [moe]: Multi-head Latent Attention + fine-grained MoE.

60L d_model=5120 128H (MLA kv_lora=512) d_ff_expert=1536 vocab=102400,
2 shared + 160 routed experts, top-6.  MLA caches the compressed latent
(c_kv 512 + shared rope key 64) — the serve path uses the absorbed-matmul
decode form.  [arXiv:2405.04434; hf]
"""

from .base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=1536, vocab=102400,
    pattern=("mla",), ffn_pattern=("moe",),
    moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2),
    mla=MLAConfig(kv_lora=512, q_lora=1536, d_rope=64, d_nope=128, d_v=128),
    d_head=192,   # d_nope + d_rope
)
