"""xlstm-125m [ssm]: alternating sLSTM + mLSTM blocks.

12L d_model=768 4H (kv=4) d_ff=0 vocab=50304.  d_ff=0 means the blocks
carry their own up/down projections (expand factor 2) instead of a separate
FFN.  mLSTM is the chunkwise-parallel matrix-memory (linear-attention form)
block; sLSTM is the sequential scalar-memory block (lax.scan over sequence).
Recurrent state => sub-quadratic => runs the long_500k cell.
[arXiv:2405.04517; unverified]
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    pattern=("mlstm", "slstm"), ffn_pattern=("none", "none"),
    expand=2, subquadratic=True,
)
