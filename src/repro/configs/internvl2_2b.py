"""internvl2-2b [vlm]: InternViT + InternLM2 decoder backbone.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.  The InternViT
frontend is a STUB: input_specs() provides precomputed, already-projected
patch embeddings which are concatenated in front of the token embeddings.
[arXiv:2404.16821; hf]
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92553, n_patches=1024,
)
