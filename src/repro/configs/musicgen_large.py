"""musicgen-large [audio]: decoder-only transformer over EnCodec tokens.

48L d_model=2048 32H (MHA: kv=32) d_ff=8192, 4 codebooks x vocab 2048.
The EnCodec frontend is a STUB: input_specs() provides the 4-codebook token
frame ids; frame embeddings are the sum of the 4 codebook embeddings and the
head predicts all 4 codebooks per frame.  [arXiv:2306.05284; hf]
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=2048, n_codebooks=4,
)
