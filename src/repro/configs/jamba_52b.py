"""jamba-v0.1-52b [hybrid]: Mamba + attention 1:7 interleave, MoE.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16 experts
top-2 on every other layer.  One scan period = 8 layers with attention at
position 4 (1 attn : 7 mamba) and MoE at odd positions.  Mamba state =>
sub-quadratic => runs the long_500k cell (its 4 attention layers decode
against a seq-sharded KV cache).  [arXiv:2403.19887; hf]
"""

from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65536,
    pattern=("mamba", "mamba", "mamba", "mamba",
             "attn", "mamba", "mamba", "mamba"),
    ffn_pattern=("dense", "moe", "dense", "moe",
                 "dense", "moe", "dense", "moe"),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336),
    expand=2, d_state=16, subquadratic=True,
)
