"""Communicators over virtual ranks.

A communicator is a subset of world ranks.  On creation it is factored into
a strided-cartesian *channel* (offset + (stride, size) dims) exactly the way
Critter's MPI_Comm_split interception does (allgather world ranks, sort,
factor) — the channel identity (stride/size only, offset-independent) is
what kernel signatures and the aggregate-channel machinery key on.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.channels import Channel, ChannelRegistry, ranks_to_channel
from repro.core.signatures import SignatureInterner


class Comm:
    __slots__ = ("id", "ranks", "ranks_np", "world", "channel", "_index",
                 "_arrivals", "stride", "size")

    _next_id = 0

    def __init__(self, world: "World", ranks: Sequence[int]):
        self.id = Comm._next_id
        Comm._next_id += 1
        self.world = world
        self.ranks: Tuple[int, ...] = tuple(sorted(int(r) for r in ranks))
        # participant index array for the engine's vectorized reductions
        self.ranks_np = np.array(self.ranks, dtype=np.intp)
        self.size = len(self.ranks)
        self._index: Dict[int, int] = {r: i for i, r in enumerate(self.ranks)}
        # channel factorization (None for non-cartesian rank sets)
        self.channel: Optional[Channel] = world.registry.register_ranks(self.ranks)
        # representative stride for signatures: innermost dim stride, 0 if
        # non-cartesian (paper: comm kernels parameterized on size + stride)
        self.stride = self.channel.dims[0][0] if self.channel else 0
        # per-collective-site arrival bookkeeping (runtime internal)
        self._arrivals = {}

    def rank_index(self, world_rank: int) -> int:
        return self._index[world_rank]

    def translate(self, comm_rank: int) -> int:
        """comm-local rank -> world rank."""
        return self.ranks[comm_rank]

    def __contains__(self, world_rank: int) -> bool:
        return world_rank in self._index

    def __repr__(self):
        return f"Comm(id={self.id}, size={self.size}, stride={self.stride})"


class World:
    """The world communicator plus a registry of sub-communicators.

    Sub-communicator creation mirrors MPI_Comm_split: the caller provides
    the rank sets; the channel registry builds aggregate channels from their
    cartesian factorizations (Figure 2, MPI_Comm_split interception).
    """

    def __init__(self, size: int):
        self.size = size
        self.registry = ChannelRegistry(size)
        # world-scoped signature id space: ids stay dense per study, so the
        # engine's per-(rank, sid) tables are sized by THIS world's kernel
        # count rather than every signature ever interned in the process
        self.interner = SignatureInterner()
        self.world_comm = Comm(self, range(size))
        self._comms: Dict[Tuple[int, ...], Comm] = {
            self.world_comm.ranks: self.world_comm}

    def comm(self, ranks: Sequence[int]) -> Comm:
        """Get-or-create the communicator over the given world ranks."""
        key = tuple(sorted(int(r) for r in ranks))
        c = self._comms.get(key)
        if c is None:
            c = Comm(self, key)
            self._comms[key] = c
        return c

    # -- cartesian-grid helpers (what the linalg schedules use) -------------

    def grid_comms(self, dims: Sequence[int]) -> "GridComms":
        return GridComms(self, dims)


class GridComms:
    """Row/column/fiber communicators of a cartesian processor grid.

    Ranks are mapped to grid coordinates in row-major order with dim 0
    innermost (fastest-varying), so a fiber along dim 0 is a stride-1
    communicator, along dim 1 a stride-dims[0] communicator, etc. — the
    strided channels the paper's aggregate machinery is built for.
    """

    def __init__(self, world: World, dims: Sequence[int]):
        self.world = world
        self.dims = tuple(int(d) for d in dims)
        n = 1
        for d in self.dims:
            n *= d
        if n != world.size:
            raise ValueError(f"grid {self.dims} != world size {world.size}")
        self.strides = []
        s = 1
        for d in self.dims:
            self.strides.append(s)
            s *= d

    def coords(self, rank: int) -> Tuple[int, ...]:
        out = []
        for d, s in zip(self.dims, self.strides):
            out.append((rank // s) % d)
        return tuple(out)

    def rank_of(self, coords: Sequence[int]) -> int:
        r = 0
        for c, s in zip(coords, self.strides):
            r += c * s
        return r

    def fiber(self, rank: int, dim: int) -> Comm:
        """Communicator of all ranks sharing every coordinate of ``rank``
        except along ``dim`` (an MPI_Comm_split by the other coords)."""
        base = self.coords(rank)
        ranks = []
        for i in range(self.dims[dim]):
            c = list(base)
            c[dim] = i
            ranks.append(self.rank_of(c))
        return self.world.comm(ranks)

    def slice(self, rank: int, dims: Sequence[int]) -> Comm:
        """Communicator of all ranks sharing the coordinates of ``rank``
        along every dimension NOT in ``dims`` (a multi-dim slab)."""
        base = self.coords(rank)
        free = list(dims)
        ranks = []

        def rec(i, cur):
            if i == len(free):
                ranks.append(self.rank_of(cur))
                return
            d = free[i]
            for v in range(self.dims[d]):
                nxt = list(cur)
                nxt[d] = v
                rec(i + 1, nxt)

        rec(0, list(base))
        return self.world.comm(ranks)
