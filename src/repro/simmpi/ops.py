"""Operations yielded by virtual-rank programs.

Programs are Python generators: ``def program(rank, world): yield <op>``.
Plain __slots__ classes (not dataclasses) — these sit on the hot path of the
event loop (hundreds of thousands of instances per simulated configuration).
"""

from __future__ import annotations

COLL_OPS = (
    "bcast", "reduce", "allreduce", "allgather", "gather", "scatter",
    "alltoall", "barrier",
)


class Comp:
    """A local computation kernel: a routine with a particular input size.

    ``name``/``params`` identify the signature; ``flops`` may be provided
    explicitly, else it is derived analytically from the signature.

    ``sig_id`` is the runtime's interned-signature cache slot: ops live in
    replayable per-rank traces, so the dense id is resolved once per op
    instance and reused on every subsequent iteration (see
    ``simmpi.runtime``).
    """

    __slots__ = ("name", "params", "flops", "sig_id")

    def __init__(self, name, params=(), flops=None):
        self.name = name
        self.params = tuple(params)
        self.flops = flops
        self.sig_id = None

    def __repr__(self):
        return f"Comp({self.name}{self.params})"


class Coll:
    """A blocking collective on a communicator."""

    __slots__ = ("op", "comm", "nbytes", "root", "sig_id")

    def __init__(self, op, comm, nbytes, root=0):
        self.op = op
        self.comm = comm
        self.nbytes = int(nbytes)
        self.root = root
        self.sig_id = None

    def __repr__(self):
        return f"Coll({self.op}, p={self.comm.size}, {self.nbytes}B)"


def Barrier(comm):
    return Coll("barrier", comm, 0)


class Send:
    """Blocking (rendezvous) point-to-point send."""

    __slots__ = ("dst", "nbytes", "tag", "sig_id")

    def __init__(self, dst, nbytes, tag=0):
        self.dst = int(dst)
        self.nbytes = int(nbytes)
        self.tag = tag
        self.sig_id = None

    def __repr__(self):
        return f"Send(->{self.dst}, {self.nbytes}B, tag={self.tag})"


class Recv:
    """Blocking point-to-point receive (matches Send or Isend)."""

    __slots__ = ("src", "nbytes", "tag")

    def __init__(self, src, nbytes, tag=0):
        self.src = int(src)
        self.nbytes = int(nbytes)
        self.tag = tag

    def __repr__(self):
        return f"Recv(<-{self.src}, {self.nbytes}B, tag={self.tag})"


class Isend:
    """Nonblocking buffered send: deposits the message (with the sender's
    path profile snapshot) and completes locally.  Yields a request handle.

    Mirrors Figure 2's MPI_Isend interception: the internal message is sent
    with PMPI_Bsend so the sender never blocks; the execution decision is
    made from the sender's local state and travels with the message.
    """

    __slots__ = ("dst", "nbytes", "tag", "sig_id")

    def __init__(self, dst, nbytes, tag=0):
        self.dst = int(dst)
        self.nbytes = int(nbytes)
        self.tag = tag
        self.sig_id = None

    def __repr__(self):
        return f"Isend(->{self.dst}, {self.nbytes}B, tag={self.tag})"


class Wait:
    """Wait on a request handle returned by Isend (buffered => no-op cost,
    but the interception point exists, matching Figure 2's MPI_Wait)."""

    __slots__ = ("handle",)

    def __init__(self, handle):
        self.handle = handle

    def __repr__(self):
        return f"Wait({self.handle})"
