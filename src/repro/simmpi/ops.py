"""Operations yielded by virtual-rank programs.

Programs are Python generators: ``def program(rank, world): yield <op>``.
Plain __slots__ classes (not dataclasses) — these sit on the hot path of the
event loop (hundreds of thousands of instances per simulated configuration).
"""

from __future__ import annotations

COLL_OPS = (
    "bcast", "reduce", "allreduce", "allgather", "gather", "scatter",
    "alltoall", "barrier",
)

# engine dispatch codes, one per op family.  The runtime dispatches on
# ``op.KIND`` (a small-int class attribute) rather than ``type(op) is X``
# identity, so user subclasses of the op classes (e.g. a Comp carrying
# extra bookkeeping) flow through the engine unchanged.
KIND_COMP, KIND_COLL, KIND_SEND, KIND_RECV, KIND_ISEND, KIND_WAIT = range(6)

# Compiled-program dispatch codes.  The recording pass lowers the op stream
# into three progressively specialized programs, each dispatching on the
# first element of its entry tuples (all defined here, next to the op-kind
# codes they descend from):
#
# EV_* — the flat event program emitted by the structural recording pass
#        (one entry per interception; comp runs fused into EV_BLOCKs);
# CS_* — the cold program: the event program re-sliced for batched forced
#        execution (static draw sequence, force-specialized interceptions);
# W_*  — the warm program (see core.critter): the event program segmented
#        at skip-decision and communication boundaries for the compiled
#        selective interpreter (per-rank comp segments batch-charge when
#        fully in the skip regime).  W_* codes live in core.critter next
#        to their interpreter — core must not import simmpi.
EV_COMP, EV_BLOCK, EV_COLL, EV_P2P, EV_IPOST, EV_IMATCH = range(6)
CS_COMP, CS_BLOCK, CS_IPOST, CS_COLL, CS_P2P, CS_IMATCH = range(6)


class Comp:
    """A local computation kernel: a routine with a particular input size.

    ``name``/``params`` identify the signature; ``flops`` may be provided
    explicitly, else it is derived analytically from the signature.

    ``sig_id`` is the runtime's interned-signature cache slot: ops live in
    replayable per-rank traces, so the dense id is resolved once per op
    instance and reused on every subsequent iteration (see
    ``simmpi.runtime``).
    """

    KIND = KIND_COMP
    __slots__ = ("name", "params", "flops", "sig_id")

    def __init__(self, name, params=(), flops=None):
        self.name = name
        self.params = tuple(params)
        self.flops = flops
        self.sig_id = None

    def __repr__(self):
        return f"Comp({self.name}{self.params})"


class Coll:
    """A blocking collective on a communicator."""

    KIND = KIND_COLL
    __slots__ = ("op", "comm", "nbytes", "root", "sig_id")

    def __init__(self, op, comm, nbytes, root=0):
        self.op = op
        self.comm = comm
        self.nbytes = int(nbytes)
        self.root = root
        self.sig_id = None

    def __repr__(self):
        return f"Coll({self.op}, p={self.comm.size}, {self.nbytes}B)"


def Barrier(comm):
    return Coll("barrier", comm, 0)


class Send:
    """Blocking (rendezvous) point-to-point send."""

    KIND = KIND_SEND
    __slots__ = ("dst", "nbytes", "tag", "sig_id")

    def __init__(self, dst, nbytes, tag=0):
        self.dst = int(dst)
        self.nbytes = int(nbytes)
        self.tag = tag
        self.sig_id = None

    def __repr__(self):
        return f"Send(->{self.dst}, {self.nbytes}B, tag={self.tag})"


class Recv:
    """Blocking point-to-point receive (matches Send or Isend)."""

    KIND = KIND_RECV
    __slots__ = ("src", "nbytes", "tag")

    def __init__(self, src, nbytes, tag=0):
        self.src = int(src)
        self.nbytes = int(nbytes)
        self.tag = tag

    def __repr__(self):
        return f"Recv(<-{self.src}, {self.nbytes}B, tag={self.tag})"


class Isend:
    """Nonblocking buffered send: deposits the message (with the sender's
    path profile snapshot) and completes locally.  Yields a request handle.

    Mirrors Figure 2's MPI_Isend interception: the internal message is sent
    with PMPI_Bsend so the sender never blocks; the execution decision is
    made from the sender's local state and travels with the message.
    """

    KIND = KIND_ISEND
    __slots__ = ("dst", "nbytes", "tag", "sig_id")

    def __init__(self, dst, nbytes, tag=0):
        self.dst = int(dst)
        self.nbytes = int(nbytes)
        self.tag = tag
        self.sig_id = None

    def __repr__(self):
        return f"Isend(->{self.dst}, {self.nbytes}B, tag={self.tag})"


class Wait:
    """Wait on a request handle returned by Isend (buffered => no-op cost,
    but the interception point exists, matching Figure 2's MPI_Wait)."""

    KIND = KIND_WAIT
    __slots__ = ("handle",)

    def __init__(self, handle):
        self.handle = handle

    def __repr__(self):
        return f"Wait({self.handle})"
