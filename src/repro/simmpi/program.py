"""Event programs as first-class, serializable artifacts.

The structural recording pass (``Runtime._record``) is RNG-free and
depends only on study geometry — not on policy, tolerance, or cost-model
sampling — so its product can be recorded once per unique geometry and
replayed everywhere: across configurations of one study, across the tasks
of a policy x tolerance sweep, across worker processes, and across runs
(via the on-disk store).  This module holds

- the compiled program containers, promoted out of ``Runtime``:
  ``EventProgram`` (the flat interception sequence + isend slot layout)
  with its lazily-derived ``ColdProgram`` (batched forced execution) and
  ``WarmProgram`` (segmented vectorized selective replay) segmentations,
  plus ``CompBlock`` fusion and the ``compile_events`` /
  ``build_cold`` / ``build_warm`` lowering passes;
- a versioned JSON serialization (``program_to_payload`` /
  ``program_from_payload``) that replaces live engine objects with stable
  keys and remaps interned signature ids across Worlds;
- ``structural_fingerprint``: the content address over
  (study key, world size, geometry params);
- ``ProgramCache``: in-process LRU + crash-atomic, crc32-validated
  on-disk store, with a LOUD fallback to re-recording on any version /
  fingerprint / checksum mismatch (a stale artifact must never be
  silently replayed as current).

Bit-identity across the cache boundary
--------------------------------------

A cache-hit run must be byte-identical to a cache-miss run: same reports,
same rank state, same sampler RNG stream.  Signature ids are dense
per-World intern-order integers and several float accumulations iterate
tables in sid order, so the payload stores the referenced signatures
sorted by their record-time sid and the loader re-interns them in that
order — a destination World that processes the same configurations in the
same order (the sweep/driver contract) therefore assigns the exact same
ids the recording World did.  Communicator *creation order* feeds the
channel registry's aggregate discovery (consumed by the eager policy's
``covers_world``), and generators may create communicators no event
references, so the payload also carries every communicator the recording
created, in creation order, and the loader replays those creations before
materializing events.  ``Comm.id`` (a process-global counter) is never
consumed by the interpreters and is allowed to differ.

The fingerprint is an identity over (study key, point name, geometry
params, world size) — the caller's contract is that those determine the
program structure, which holds for every study space in this repo (the
params dict carries the full geometry).  The on-disk artifact additionally
carries a crc32 over its canonical payload, so torn or corrupted files are
detected and re-recorded rather than trusted.
"""

from __future__ import annotations

import json
import os
import sys
import tempfile
import zlib
from collections import OrderedDict
from typing import Dict, List, Optional

import numpy as np

from repro.core.critter import (W_BHEAD, W_BLOCK, W_CHEAD, W_COLL, W_COMP,
                                W_IMATCH, W_IPOST, W_P2P)
from repro.core.signatures import Signature
from .ops import (CS_BLOCK, CS_COLL, CS_COMP, CS_IMATCH, CS_IPOST, CS_P2P,
                  EV_BLOCK, EV_COLL, EV_COMP, EV_IMATCH, EV_IPOST, EV_P2P)

#: artifact format version — bump on ANY change to the payload shape,
#: the EV_* opcode numbering, or the signature-table ordering contract;
#: a loader refuses (loudly) every other version and re-records
PROGRAM_VERSION = 1


class CompBlock:
    """A run of consecutive computation events of one rank, fused at event
    compilation: interned signature ids plus the unique-id/count arrays the
    profiler's vectorized skip path charges in one step."""

    __slots__ = ("sids", "sids_np", "uniq", "counts", "n", "max_sid",
                 "groups")

    def __init__(self, sids: List[int]):
        self.sids = sids
        self.sids_np = np.array(sids, dtype=np.intp)
        self.uniq, self.counts = np.unique(self.sids_np, return_counts=True)
        self.n = len(sids)
        self.max_sid = int(self.sids_np.max())
        # lazy per-unique-sid position lists (cold batched charging)
        self.groups: Optional[List[List[int]]] = None

    def group_indices(self) -> List[List[int]]:
        """Positions of each unique sid's samples within the block, in
        block order (so per-sid Welford updates see samples in the same
        order as per-event updates)."""
        g = self.groups
        if g is None:
            if len(self.uniq) == 1:
                g = [list(range(self.n))]
            else:
                g = [np.nonzero(self.sids_np == u)[0].tolist()
                     for u in self.uniq.tolist()]
            self.groups = g
        return g


# minimum run length worth a vectorized block (below this the fancy-index
# overhead exceeds the per-op savings)
MIN_BLOCK = 4


class EventProgram:
    """The flat interception sequence of one configuration run.

    events -- list of opcode tuples (see the EV_*/CS_* constants in .ops)
    n_slots -- number of isend post->match payload slots
    cold -- lazily-built batched cold-run program (ColdProgram)
    warm -- lazily-built compiled warm program (WarmProgram)
    """

    __slots__ = ("events", "n_slots", "cold", "warm")

    def __init__(self, events, n_slots):
        self.events = events
        self.n_slots = n_slots
        self.cold: Optional[ColdProgram] = None
        self.warm: Optional[WarmProgram] = None


class WarmProgram:
    """The event program segmented for the compiled selective interpreter
    (``Critter.run_warm``).

    entries -- list of W_* opcode tuples (see core.critter): one entry per
             interception, with each maximal per-rank run of computation
             events between that rank's skip-decision / communication
             boundaries marked by a W_CHEAD / W_BHEAD head entry carrying
             the segment metadata ``(sids, uniq, counts, n_events,
             n_member_entries)``
    n_slots -- isend post->match payload slots (same as the event program)
    max_sid -- highest signature id any entry touches (pre-grow capacity)
    meta -- segmentation statistics for the bench harness / CI gate:
             segment count, fused event count, batch-size distribution
    """

    __slots__ = ("entries", "n_slots", "max_sid", "meta")

    def __init__(self, entries, n_slots, max_sid, meta):
        self.entries = entries
        self.n_slots = n_slots
        self.max_sid = max_sid
        self.meta = meta


class ColdProgram:
    """The event program re-sliced for batched forced (cold) execution.

    A forced run samples EVERY kernel — computation and communication — in
    step order, so the whole run's draw sequence is known statically:
    ``draw_sigs`` lists the sampled signatures in consumption order (one
    per CS_COMP / CS_COLL / CS_P2P / CS_IMATCH step, ``block.n`` per
    CS_BLOCK step), and the interpreter walks ``steps`` with a running
    cursor into the draw buffer.  When the cost model can batch
    (``batch_info``: lognormal noise, straggler branch off), all draws
    come from ONE vectorized ``standard_normal`` call — bit-equal to the
    scalar stream because ``Generator.normal(0, s)`` is exactly
    ``standard_normal() * s`` and vectorized fills consume the bit stream
    identically to repeated scalar draws; otherwise each step draws through
    the scalar timer at its cursor position, the same calls in the same
    order as the interleaved seed engine.

    steps -- (CS_COMP, rank, sid, sig) | (CS_BLOCK, rank, block, sigs)
             | (CS_IPOST, rank, slot) | (CS_COLL, sid, comm, sig)
             | (CS_P2P, src, dst, sid, sig)
             | (CS_IMATCH, src, dst, sid, slot, sig)
    exec_rows/exec_cols -- the statically-known (rank, sid) pairs executed
             by every sampling step (collectives included), for
             Critter.finish_cold's deferred iter_exec/mean_arr bulk pass
    batch -- lazy cost-model batch support: None until probed, False when
             the timer cannot batch, else (det, sigma) draw-order arrays
    """

    __slots__ = ("steps", "draw_sigs", "n_slots", "max_sid", "exec_rows",
                 "exec_cols", "batch")

    def __init__(self, steps, draw_sigs, n_slots, max_sid, exec_pairs):
        self.steps = steps
        self.draw_sigs = draw_sigs
        self.n_slots = n_slots
        self.max_sid = max_sid
        pairs = sorted(exec_pairs)
        self.exec_rows = np.array([p[0] for p in pairs], dtype=np.intp)
        self.exec_cols = np.array([p[1] for p in pairs], dtype=np.intp)
        self.batch = None


# ------------------------------------------------------------- lowering

def compile_events(events) -> EventProgram:
    """Fuse runs of consecutive comp events of one rank into blocks.

    Only *globally* consecutive runs are fused — the interleaved order
    of interceptions across ranks (and therefore sampler RNG
    consumption) is preserved exactly."""
    out = []
    run_rank = -1
    run: List[int] = []
    n_slots = 0

    def flush():
        nonlocal run
        if len(run) >= MIN_BLOCK:
            out.append((EV_BLOCK, run_rank, CompBlock(run)))
        else:
            out.extend((EV_COMP, run_rank, sid) for sid in run)
        run = []

    for ev in events:
        if ev[0] == EV_COMP:
            if ev[1] != run_rank:
                if run:
                    flush()
                run_rank = ev[1]
            run.append(ev[2])
            continue
        if run:
            flush()
            run_rank = -1
        if ev[0] == EV_IPOST:
            n_slots = ev[3] + 1
        out.append(ev)
    if run:
        flush()
    return EventProgram(out, n_slots)


def build_cold(prog: EventProgram, sigs) -> ColdProgram:
    """Flatten the event program into cold steps plus the forced run's
    static draw sequence (see ColdProgram).  ``sigs`` is the owning
    World's interner table (``world.interner.sigs``)."""
    steps: list = []
    draw_sigs: list = []
    exec_pairs: set = set()
    max_sid = 0
    for ev in prog.events:
        k = ev[0]
        if k == EV_COMP:
            sid = ev[2]
            steps.append((CS_COMP, ev[1], sid, sigs[sid]))
            draw_sigs.append(sigs[sid])
            exec_pairs.add((ev[1], sid))
        elif k == EV_BLOCK:
            block = ev[2]
            bsigs = [sigs[s] for s in block.sids]
            steps.append((CS_BLOCK, ev[1], block, bsigs))
            draw_sigs.extend(bsigs)
            exec_pairs.update((ev[1], s) for s in block.uniq.tolist())
            sid = block.max_sid
        elif k == EV_IPOST:
            sid = ev[2]
            steps.append((CS_IPOST, ev[1], ev[3]))
        elif k == EV_COLL:
            sid = ev[1]
            steps.append((CS_COLL, sid, ev[2], sigs[sid]))
            draw_sigs.append(sigs[sid])
            exec_pairs.update((r, sid) for r in ev[2].ranks)
        elif k == EV_P2P:
            sid = ev[3]
            steps.append((CS_P2P, ev[1], ev[2], sid, sigs[sid]))
            draw_sigs.append(sigs[sid])
            exec_pairs.add((ev[1], sid))
            exec_pairs.add((ev[2], sid))
        else:
            sid = ev[3]
            steps.append((CS_IMATCH, ev[1], ev[2], sid, ev[4],
                          sigs[sid]))
            draw_sigs.append(sigs[sid])
            exec_pairs.add((ev[1], sid))
            exec_pairs.add((ev[2], sid))
        if sid > max_sid:
            max_sid = sid
    return ColdProgram(steps, draw_sigs, prog.n_slots, max_sid,
                       exec_pairs)


def build_warm(prog: EventProgram, sigs) -> WarmProgram:
    """Segment the event program for the compiled selective interpreter.

    Every maximal run of one rank's computation events (plain comps AND
    fused blocks) between two of that rank's *boundaries* — any event
    that touches the rank: a collective it participates in, a p2p it
    sends or receives, an isend post or match — becomes one segment.
    Within a segment no event of any other rank can observe the rank's
    comp-charged state (only boundary events read it), so when every
    kernel in the segment holds a memoized skip verdict the interpreter
    charges the whole segment at the head entry and consumes the member
    entries with a pending counter — the steady-state path that turns
    per-event interpretation into one accumulation loop per segment.
    A guard miss replays the members individually at their original
    positions, so decisions and RNG consumption never reorder."""
    entries: list = []
    # rank -> [entry indices, sids] of its currently-open comp run
    open_runs: Dict[int, list] = {}
    max_sid = 0
    run_sizes: List[int] = []
    n_comp = n_block = n_coll = n_p2p = n_ipost = n_imatch = 0

    def close(r):
        run = open_runs.pop(r, None)
        if run is None:
            return
        idxs, rsids = run
        if len(idxs) < 2:
            return           # single-entry segment: no head needed
        uniq: Dict[int, int] = {}
        for s in rsids:
            uniq[s] = uniq.get(s, 0) + 1
        meta = (rsids, list(uniq), list(uniq.values()), len(rsids),
                len(idxs) - 1)
        head = entries[idxs[0]]
        if head[0] == W_COMP:
            entries[idxs[0]] = (W_CHEAD, head[1], head[2], meta)
        else:
            entries[idxs[0]] = (W_BHEAD, head[1], head[2], head[3],
                                head[4], head[5], meta)
        run_sizes.append(len(rsids))

    for ev in prog.events:
        k = ev[0]
        if k == EV_COMP:
            r = ev[1]
            sid = ev[2]
            if sid > max_sid:
                max_sid = sid
            run = open_runs.get(r)
            if run is None:
                run = open_runs[r] = [[], []]
            run[0].append(len(entries))
            run[1].append(sid)
            entries.append((W_COMP, r, sid))
            n_comp += 1
        elif k == EV_BLOCK:
            r = ev[1]
            block = ev[2]
            if block.max_sid > max_sid:
                max_sid = block.max_sid
            run = open_runs.get(r)
            if run is None:
                run = open_runs[r] = [[], []]
            run[0].append(len(entries))
            run[1].extend(block.sids)
            entries.append((W_BLOCK, r, block.sids, block.uniq.tolist(),
                            block.counts.tolist(), block.n))
            n_block += 1
        elif k == EV_IPOST:
            r = ev[1]
            sid = ev[2]
            if sid > max_sid:
                max_sid = sid
            close(r)
            entries.append((W_IPOST, r, sid, ev[3]))
            n_ipost += 1
        elif k == EV_COLL:
            sid = ev[1]
            comm = ev[2]
            if sid > max_sid:
                max_sid = sid
            for r in comm.ranks:
                close(r)
            entries.append((W_COLL, sid, comm, comm.ranks, sigs[sid]))
            n_coll += 1
        elif k == EV_P2P:
            sid = ev[3]
            if sid > max_sid:
                max_sid = sid
            close(ev[1])
            close(ev[2])
            entries.append((W_P2P, ev[1], ev[2], sid, sigs[sid]))
            n_p2p += 1
        else:                               # EV_IMATCH
            sid = ev[3]
            if sid > max_sid:
                max_sid = sid
            close(ev[1])
            close(ev[2])
            entries.append((W_IMATCH, ev[1], ev[2], sid, ev[4],
                            sigs[sid]))
            n_imatch += 1
    for r in list(open_runs):
        close(r)

    fused = sum(run_sizes)
    meta = {
        "entries": len(entries),
        "segments": len(run_sizes),
        "fused_events": fused,
        "max_batch": max(run_sizes) if run_sizes else 0,
        "mean_batch": round(fused / len(run_sizes), 2)
        if run_sizes else 0.0,
        "comp_entries": n_comp,
        "block_entries": n_block,
        "coll_entries": n_coll,
        "p2p_entries": n_p2p,
        "ipost_entries": n_ipost,
        "imatch_entries": n_imatch,
    }
    return WarmProgram(entries, prog.n_slots, max_sid, meta)


# --------------------------------------------------------- serialization

class ProgramCacheError(ValueError):
    """A cache artifact failed validation (version, fingerprint, checksum,
    or structure).  Raised by the payload codec; the cache itself converts
    it into a loud re-record."""


def _canon(value) -> str:
    """Canonical JSON for fingerprint material: sorted keys, tuples as
    lists, compact separators — deterministic across processes."""
    def default(o):
        if isinstance(o, (np.integer,)):
            return int(o)
        if isinstance(o, (np.floating,)):
            return float(o)
        raise TypeError(f"unfingerprintable value {o!r}")
    return json.dumps(value, sort_keys=True, separators=(",", ":"),
                      default=default)


def structural_fingerprint(space_name: str, point_name: str, params: dict,
                           world_size: int) -> str:
    """The program's content address: crc32 over the canonical JSON of
    (study key, point name, geometry params, world size, format version).

    The caller's contract is that these determine the recorded structure —
    true for every study space in this repo, whose point params carry the
    full geometry.  Two spaces that reuse a name/params pair for different
    program factories must not share a cache."""
    material = {"space": space_name, "point": point_name,
                "params": params, "world": world_size,
                "version": PROGRAM_VERSION}
    return "prog%d:%08x" % (PROGRAM_VERSION,
                            zlib.crc32(_canon(material).encode()))


def _tupled(x):
    """JSON list -> tuple, recursively (signature params round-trip)."""
    if isinstance(x, list):
        return tuple(_tupled(v) for v in x)
    return x


def program_to_payload(prog: EventProgram, sigs,
                       comms: Optional[List] = None) -> dict:
    """Serialize a compiled event program into a JSON-able payload.

    ``sigs`` is the recording World's interner table; the payload stores
    only the signatures this program references, ordered by their
    record-time sid (the loader re-interns them in that order — see the
    module docstring's bit-identity contract).  ``comms`` is the ordered
    list of communicator rank-tuples the recording pass *created* (the
    ``World._comms`` delta), replayed on load so the channel registry
    evolves identically."""
    ref: set = set()
    for ev in prog.events:
        k = ev[0]
        if k == EV_COMP:
            ref.add(ev[2])
        elif k == EV_BLOCK:
            ref.update(ev[2].sids)
        elif k == EV_COLL:
            ref.add(ev[1])
        elif k == EV_IPOST:
            ref.add(ev[2])
        else:                       # EV_P2P, EV_IMATCH
            ref.add(ev[3])
    order = sorted(ref)
    local = {sid: i for i, sid in enumerate(order)}
    table = [[sigs[sid].kind, sigs[sid].name, list(sigs[sid].params)]
             for sid in order]
    events = []
    for ev in prog.events:
        k = ev[0]
        if k == EV_COMP:
            events.append([k, ev[1], local[ev[2]]])
        elif k == EV_BLOCK:
            events.append([k, ev[1], [local[s] for s in ev[2].sids]])
        elif k == EV_COLL:
            events.append([k, local[ev[1]], list(ev[2].ranks)])
        elif k == EV_P2P:
            events.append([k, ev[1], ev[2], local[ev[3]]])
        elif k == EV_IPOST:
            events.append([k, ev[1], local[ev[2]], ev[3]])
        else:                       # EV_IMATCH
            events.append([k, ev[1], ev[2], local[ev[3]], ev[4]])
    return {"version": PROGRAM_VERSION, "n_slots": prog.n_slots,
            "sigs": table,
            "comms": [list(c) for c in (comms or [])],
            "events": events}


def program_from_payload(payload: dict, world) -> EventProgram:
    """Materialize an ``EventProgram`` from a payload into ``world``.

    Replays the recorded communicator creations (in order), re-interns the
    signature table (in record-sid order), and rebuilds the compiled event
    tuples — ``CompBlock``s from their sid lists, collectives bound to
    ``world.comm(ranks)``.  Raises ``ProgramCacheError`` on any structural
    problem; never partially mutates engine statistics (interning and comm
    creation are idempotent and profile-free)."""
    try:
        if payload["version"] != PROGRAM_VERSION:
            raise ProgramCacheError(
                f"program artifact version {payload['version']!r} != "
                f"supported {PROGRAM_VERSION}")
        for ranks in payload["comms"]:
            world.comm(ranks)
        intern = world.interner.intern
        sid_map = [intern(Signature(kind, name, _tupled(params)))
                   for kind, name, params in payload["sigs"]]
        events: list = []
        append = events.append
        for ev in payload["events"]:
            k = ev[0]
            if k == EV_COMP:
                append((EV_COMP, ev[1], sid_map[ev[2]]))
            elif k == EV_BLOCK:
                append((EV_BLOCK, ev[1],
                        CompBlock([sid_map[s] for s in ev[2]])))
            elif k == EV_COLL:
                append((EV_COLL, sid_map[ev[1]], world.comm(ev[2])))
            elif k == EV_P2P:
                append((EV_P2P, ev[1], ev[2], sid_map[ev[3]]))
            elif k == EV_IPOST:
                append((EV_IPOST, ev[1], sid_map[ev[2]], ev[3]))
            elif k == EV_IMATCH:
                append((EV_IMATCH, ev[1], ev[2], sid_map[ev[3]], ev[4]))
            else:
                raise ProgramCacheError(f"unknown event opcode {k!r}")
        return EventProgram(events, payload["n_slots"])
    except ProgramCacheError:
        raise
    except (KeyError, IndexError, TypeError, ValueError) as e:
        raise ProgramCacheError(
            f"malformed program payload: {type(e).__name__}: {e}") from e


# ---------------------------------------------------------------- cache

class ProgramCache:
    """Content-addressed cache of recorded event programs.

    In-process LRU over serialized payloads (world-independent, so one
    cache serves many Worlds/Runtimes), optionally backed by a directory
    of crash-atomically written, crc32-validated JSON artifacts — the
    sweep-scoped store remote workers keep across tasks and the on-disk
    store that survives processes.  Every disk read validates version,
    fingerprint, and payload checksum; any mismatch is reported LOUDLY on
    stderr (and counted in ``rejects``) and treated as a miss, so a stale
    or torn artifact triggers a re-record, never a silent replay.

    Not thread-safe for concurrent mutation within one process (the engine
    is single-threaded per Runtime); concurrent *processes* sharing one
    cache directory are safe — writes go through mkstemp + fsync +
    ``os.replace``, so readers see either the old artifact or the new one,
    never a torn file."""

    def __init__(self, path: Optional[str] = None, capacity: int = 64):
        self.path = path
        self.capacity = capacity
        self._mem: "OrderedDict[str, dict]" = OrderedDict()
        self.hits = 0          # get() calls satisfied (mem or disk)
        self.misses = 0        # get() calls that found nothing valid
        self.disk_hits = 0     # hits that came off disk
        self.stores = 0        # put() calls
        self.rejects = 0       # invalid artifacts refused (loud fallback)
        self.last_reject: Optional[str] = None

    def __len__(self) -> int:
        return len(self._mem)

    # -- internals ---------------------------------------------------------

    def _file(self, fingerprint: str) -> str:
        return os.path.join(self.path, fingerprint.replace(":", "_")
                            + ".json")

    def _reject(self, reason: str) -> None:
        self.rejects += 1
        self.last_reject = reason
        print(f"program cache: {reason}; falling back to re-recording",
              file=sys.stderr, flush=True)

    def _insert(self, fingerprint: str, payload: dict) -> None:
        self._mem[fingerprint] = payload
        self._mem.move_to_end(fingerprint)
        while len(self._mem) > self.capacity:
            self._mem.popitem(last=False)

    def _load_disk(self, fingerprint: str) -> Optional[dict]:
        f = self._file(fingerprint)
        try:
            with open(f) as fh:
                doc = json.load(fh)
        except FileNotFoundError:
            return None
        except (OSError, ValueError) as e:
            self._reject(f"unreadable artifact {f}: {e}")
            return None
        if not isinstance(doc, dict) or "payload" not in doc:
            self._reject(f"artifact {f} is not a program document")
            return None
        if doc.get("version") != PROGRAM_VERSION:
            self._reject(f"artifact {f} has version {doc.get('version')!r}"
                         f" != supported {PROGRAM_VERSION}")
            return None
        if doc.get("fingerprint") != fingerprint:
            self._reject(f"artifact {f} carries fingerprint "
                         f"{doc.get('fingerprint')!r}, expected "
                         f"{fingerprint!r}")
            return None
        payload = doc["payload"]
        crc = zlib.crc32(_canon(payload).encode())
        if doc.get("crc32") != crc:
            self._reject(f"artifact {f} failed checksum validation "
                         f"(stored {doc.get('crc32')!r}, computed {crc})")
            return None
        return payload

    def _store_disk(self, fingerprint: str, payload: dict) -> None:
        os.makedirs(self.path, exist_ok=True)
        doc = {"version": PROGRAM_VERSION, "fingerprint": fingerprint,
               "crc32": zlib.crc32(_canon(payload).encode()),
               "payload": payload}
        fd, tmp = tempfile.mkstemp(
            prefix=os.path.basename(self._file(fingerprint)) + ".",
            suffix=".tmp", dir=self.path)
        try:
            with os.fdopen(fd, "w") as fh:
                json.dump(doc, fh)
                fh.flush()
                os.fsync(fh.fileno())
            os.replace(tmp, self._file(fingerprint))
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # -- public API --------------------------------------------------------

    def lookup(self, fingerprint: str) -> Optional[dict]:
        """The raw payload for ``fingerprint`` (LRU, then disk), or
        ``None``.  Does not touch hit/miss counters."""
        payload = self._mem.get(fingerprint)
        if payload is not None:
            self._mem.move_to_end(fingerprint)
            return payload
        if self.path:
            payload = self._load_disk(fingerprint)
            if payload is not None:
                self.disk_hits += 1
                self._insert(fingerprint, payload)
                return payload
        return None

    def get(self, fingerprint: str, world) -> Optional[EventProgram]:
        """Materialize the cached program for ``fingerprint`` into
        ``world``, or ``None`` on a miss.  A payload that fails
        materialization is rejected loudly and treated as a miss."""
        payload = self.lookup(fingerprint)
        if payload is not None:
            try:
                prog = program_from_payload(payload, world)
            except ProgramCacheError as e:
                self._mem.pop(fingerprint, None)
                self._reject(str(e))
            else:
                self.hits += 1
                return prog
        self.misses += 1
        return None

    def put(self, fingerprint: str, prog: EventProgram, world,
            comms: Optional[List] = None) -> dict:
        """Serialize ``prog`` (recorded in ``world``) under
        ``fingerprint``, into the LRU and — when a directory is configured
        — crash-atomically onto disk.  Returns the payload."""
        payload = program_to_payload(prog, world.interner.sigs, comms)
        self._insert(fingerprint, payload)
        self.stores += 1
        if self.path:
            self._store_disk(fingerprint, payload)
        return payload

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "disk_hits": self.disk_hits, "stores": self.stores,
                "rejects": self.rejects, "entries": len(self._mem)}
