"""The discrete-event engine.

Runs one *tuning iteration* (one benchmark execution of one configuration):
every virtual rank executes its generator program; computation kernels are
handled inline; communications block until matched; each interception point
invokes the Critter protocol (core.critter), which advances per-rank clocks
and path profiles and makes the selective-execution decision.

Matching semantics:

- collectives match by per-communicator arrival index (the k-th collective
  a rank posts on communicator C completes with every other rank's k-th);
  a mismatch in op kind OR byte count across participants is a schedule bug
  and raises;
- blocking Send/Recv are rendezvous; Isend is buffered (deposits a snapshot
  of the sender's path profile, sender proceeds); Recv matches Send/Isend
  in post order per (src, dst, tag);
- Wait on an Isend request is an interception no-op (buffered completion).

If no rank can make progress before all programs finish, DeadlockError
reports the blocked ranks and what they wait on.

Hot-path design (see also core.critter):

- **signature interning**: every op resolves its Signature to a dense
  integer id once, cached on the op instance (ops are reused via trace
  replay), so the per-event cost is an attribute read instead of a
  dataclass hash;
- **event-program compilation**: rank programs are generators whose op
  streams do not depend on engine feedback (the only value sent back is
  the opaque Isend handle, consumed by Wait), and communication matching
  in this engine is purely structural — independent of sampled times.  The
  interleaved sequence of Critter interceptions is therefore identical
  across iterations of one configuration, so the first execution of a
  program factory records it as a flat event program; subsequent
  iterations (the common case — the tuner runs trials-many iterations per
  configuration) execute that program directly, skipping generators,
  matching queues, and the scheduler entirely.  Runs of consecutive
  computation kernels of one rank are fused into blocks that the profiler
  can charge in one vectorized step.  Pass ``trace_cache=False`` for
  programs whose op stream is nondeterministic or feedback-dependent;
- **runnable queue**: first-run scheduling pops a (sweep, rank) heap
  instead of scanning all ranks per pass, preserving the exact round-robin
  order of the seed engine (a rank unblocked by a lower-ranked completer
  runs in the same sweep; one unblocked by a higher-ranked completer runs
  in the next), which keeps sampler RNG consumption — and therefore
  results — bit-identical.
"""

from __future__ import annotations

import weakref
from collections import deque
from heapq import heappop, heappush
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.critter import Critter, IterationReport
from repro.core.signatures import Signature, comm_sig, comp_sig, p2p_sig
from .comm import World
from .ops import Coll, Comp, Isend, Recv, Send, Wait

RUNNABLE, BLOCKED, DONE = 0, 1, 2


class DeadlockError(RuntimeError):
    pass


class RunResult(IterationReport):

    @classmethod
    def from_report(cls, rep: IterationReport) -> "RunResult":
        return cls(rep.predicted_time, rep.wall_time, rep.crit_comp,
                   rep.crit_comm, rep.measured_time, rep.max_measured_comp,
                   rep.executed, rep.skipped, rep.events)


class _CompBlock:
    """A run of consecutive computation events of one rank, fused at event
    compilation: interned signature ids plus the unique-id/count arrays the
    profiler's vectorized skip path charges in one step."""

    __slots__ = ("sids", "sids_np", "uniq", "counts", "n", "max_sid")

    def __init__(self, sids: List[int]):
        self.sids = sids
        self.sids_np = np.array(sids, dtype=np.intp)
        self.uniq, self.counts = np.unique(self.sids_np, return_counts=True)
        self.n = len(sids)
        self.max_sid = int(self.sids_np.max())


# minimum run length worth a vectorized block (below this the fancy-index
# overhead exceeds the per-op savings)
_MIN_BLOCK = 4

# event-program opcodes (first element of each event tuple)
EV_COMP, EV_BLOCK, EV_COLL, EV_P2P, EV_IPOST, EV_IMATCH = range(6)


class _EventProgram:
    """The flat interception sequence of one configuration run.

    events -- list of opcode tuples (see the EV_* constants)
    n_slots -- number of isend post->match payload slots
    """

    __slots__ = ("events", "n_slots")

    def __init__(self, events, n_slots):
        self.events = events
        self.n_slots = n_slots


class _CollSite:
    __slots__ = ("op", "nbytes", "arrived", "needed", "sig_id")

    def __init__(self, op, nbytes, needed, sig_id):
        self.op = op
        self.nbytes = nbytes
        self.arrived: List[int] = []
        self.needed = needed
        self.sig_id = sig_id


class Runtime:
    """One World + one Critter profiler + a timing source."""

    def __init__(self, world: World, critter: Critter,
                 timer: Callable[[Signature, np.random.Generator], float],
                 *, seed: int = 0, overhead: float = 1e-6,
                 trace_cache: bool = True):
        self.world = world
        self.critter = critter
        self.timer = timer
        self.overhead = overhead
        self.trace_cache = trace_cache
        self._rng = np.random.default_rng(seed)
        self._intern = world.interner.intern
        self._sig_cache: Dict[tuple, int] = {}
        # program_factory -> per-rank recorded op traces (weak: traces die
        # with the configuration's program factory)
        self._traces = weakref.WeakKeyDictionary()

    # -- signature interning (hot path) --------------------------------------

    def _comp_sid(self, name, params) -> int:
        key = (0, name, params)
        sid = self._sig_cache.get(key)
        if sid is None:
            sid = self._intern(comp_sig(name, *params))
            self._sig_cache[key] = sid
        return sid

    def _coll_sid(self, op, comm, nbytes) -> int:
        key = (1, op, comm.size, comm.stride, nbytes)
        sid = self._sig_cache.get(key)
        if sid is None:
            sid = self._intern(comm_sig(op, nbytes, comm.size, comm.stride))
            self._sig_cache[key] = sid
        return sid

    def _p2p_sid(self, name, nbytes) -> int:
        key = (2, name, nbytes)
        sid = self._sig_cache.get(key)
        if sid is None:
            sid = self._intern(p2p_sig(name, nbytes))
            self._sig_cache[key] = sid
        return sid

    # -- event-program compilation --------------------------------------------

    @staticmethod
    def _compile_events(events) -> _EventProgram:
        """Fuse runs of consecutive comp events of one rank into blocks.

        Only *globally* consecutive runs are fused — the interleaved order
        of interceptions across ranks (and therefore sampler RNG
        consumption) is preserved exactly."""
        out = []
        run_rank = -1
        run: List[int] = []
        n_slots = 0

        def flush():
            nonlocal run
            if len(run) >= _MIN_BLOCK:
                out.append((EV_BLOCK, run_rank, _CompBlock(run)))
            else:
                out.extend((EV_COMP, run_rank, sid) for sid in run)
            run = []

        for ev in events:
            if ev[0] == EV_COMP:
                if ev[1] != run_rank:
                    if run:
                        flush()
                    run_rank = ev[1]
                run.append(ev[2])
                continue
            if run:
                flush()
                run_rank = -1
            if ev[0] == EV_IPOST:
                n_slots = ev[3] + 1
            out.append(ev)
        if run:
            flush()
        return _EventProgram(out, n_slots)

    def _run_events(self, prog: _EventProgram, sampler) -> None:
        """Execute a compiled event program: the scheduler, matching queues
        and generators are gone; only the interception sequence remains."""
        critter = self.critter
        overhead = self.overhead
        on_comp = critter.on_comp
        on_comp_block = critter.on_comp_block
        on_coll = critter.on_coll
        on_p2p = critter.on_p2p
        on_isend_match = critter.on_isend_match
        p2p_vote = critter.p2p_vote
        isend_snapshot = critter.isend_snapshot
        slots: List[Optional[tuple]] = [None] * prog.n_slots
        for ev in prog.events:
            k = ev[0]
            if k == EV_COMP:
                on_comp(ev[1], ev[2], sampler)
            elif k == EV_IPOST:
                slots[ev[3]] = (p2p_vote(ev[1], ev[2]),
                                isend_snapshot(ev[1]))
            elif k == EV_IMATCH:
                vote, snapshot = slots[ev[4]]
                on_isend_match(ev[1], ev[2], ev[3], sampler, vote, snapshot,
                               overhead)
            elif k == EV_P2P:
                on_p2p(ev[1], ev[2], ev[3], sampler,
                       p2p_vote(ev[1], ev[3]), overhead)
            elif k == EV_BLOCK:
                on_comp_block(ev[1], ev[2], sampler)
            else:
                on_coll(ev[1], ev[2], sampler, overhead)

    # -- main loop ------------------------------------------------------------

    def run(self, program_factory, *, force_execute: bool = False,
            update_stats: bool = True) -> RunResult:
        world = self.world
        critter = self.critter
        critter.begin_iteration(force_execute=force_execute,
                                update_stats=update_stats)
        rng = self._rng
        timer = self.timer
        sampler = lambda sig: timer(sig, rng)  # noqa: E731
        overhead = self.overhead

        n = world.size
        prog = None
        if self.trace_cache:
            try:
                prog = self._traces.get(program_factory)
            except TypeError:            # unhashable/unweakrefable factory
                prog = None
        if prog is not None:
            self._run_events(prog, sampler)
            return RunResult.from_report(critter.report())

        gens = [program_factory(r, world) for r in range(n)]
        recording = self.trace_cache
        events = [] if recording else None
        isend_slots = [0]
        status = [RUNNABLE] * n
        blocked_on: List[Optional[object]] = [None] * n
        # collective sites: (comm.id, site_index) -> _CollSite
        coll_sites: Dict[Tuple[int, int], _CollSite] = {}
        coll_counts: Dict[Tuple[int, int], int] = {}
        # p2p queues: (src, dst, tag) -> deque of entries
        # send entry: (sender_rank, sig_id, vote, snapshot_or_None, slot)
        sends: Dict[tuple, deque] = {}
        recvs: Dict[tuple, deque] = {}
        next_handle = [0]
        # runnable queue: (sweep, rank) min-heap reproducing the seed
        # engine's sorted round-robin sweeps exactly
        heap: List[Tuple[int, int]] = [(0, r) for r in range(n)]

        live = n

        def advance(r, sweep, value=None):
            """Run rank r until it blocks or finishes."""
            nonlocal live
            gen = gens[r]
            while True:
                try:
                    op = gen.send(value)
                except StopIteration:
                    status[r] = DONE
                    live -= 1
                    return
                value = None
                cls = op.__class__
                if cls is Comp:
                    sid = op.sig_id
                    if sid is None:
                        sid = op.sig_id = self._comp_sid(op.name, op.params)
                    if recording:
                        events.append((EV_COMP, r, sid))
                    critter.on_comp(r, sid, sampler)
                    continue
                if cls is Coll:
                    comm = op.comm
                    key = (comm.id, r)
                    idx = coll_counts.get(key, 0)
                    coll_counts[key] = idx + 1
                    skey = (comm.id, idx)
                    site = coll_sites.get(skey)
                    if site is None:
                        sid = op.sig_id
                        if sid is None:
                            sid = op.sig_id = \
                                self._coll_sid(op.op, comm, op.nbytes)
                        site = _CollSite(op.op, op.nbytes, comm.size, sid)
                        coll_sites[skey] = site
                    elif site.op != op.op:
                        raise RuntimeError(
                            f"collective mismatch on comm {comm.id} site {idx}:"
                            f" {site.op} vs {op.op} (rank {r})")
                    elif site.nbytes != op.nbytes:
                        raise RuntimeError(
                            f"collective byte-count mismatch on comm "
                            f"{comm.id} site {idx} ({site.op}): "
                            f"{site.nbytes}B vs {op.nbytes}B (rank {r})")
                    site.arrived.append(r)
                    if len(site.arrived) < site.needed:
                        status[r] = BLOCKED
                        blocked_on[r] = op
                        return
                    # complete the collective
                    del coll_sites[skey]
                    if recording:
                        events.append((EV_COLL, site.sig_id, comm))
                    critter.on_coll(site.sig_id, comm, sampler, overhead)
                    for rr in site.arrived:
                        if rr != r:
                            status[rr] = RUNNABLE
                            blocked_on[rr] = None
                            heappush(heap,
                                     (sweep if rr > r else sweep + 1, rr))
                    continue
                if cls is Send:
                    sid = op.sig_id
                    if sid is None:
                        sid = op.sig_id = self._p2p_sid("send", op.nbytes)
                    pkey = (r, op.dst, op.tag)
                    q = recvs.get(pkey)
                    if q:
                        q.popleft()
                        if recording:
                            events.append((EV_P2P, r, op.dst, sid))
                        vote = critter.p2p_vote(r, sid)
                        critter.on_p2p(r, op.dst, sid, sampler, vote,
                                       overhead)
                        dst = op.dst
                        status[dst] = RUNNABLE
                        blocked_on[dst] = None
                        heappush(heap,
                                 (sweep if dst > r else sweep + 1, dst))
                        continue
                    sends.setdefault(pkey, deque()).append(
                        (r, sid, None, None, 0))
                    status[r] = BLOCKED
                    blocked_on[r] = op
                    return
                if cls is Recv:
                    pkey = (op.src, r, op.tag)
                    q = sends.get(pkey)
                    if q:
                        src, sid, vote, snapshot, slot = q.popleft()
                        if snapshot is None:   # blocking sender, rendezvous
                            if recording:
                                events.append((EV_P2P, src, r, sid))
                            vote = critter.p2p_vote(src, sid)
                            critter.on_p2p(src, r, sid, sampler, vote,
                                           overhead)
                            status[src] = RUNNABLE
                            blocked_on[src] = None
                            heappush(heap,
                                     (sweep if src > r else sweep + 1, src))
                        else:                  # buffered isend
                            if recording:
                                events.append((EV_IMATCH, src, r, sid, slot))
                            critter.on_isend_match(src, r, sid, sampler,
                                                   vote, snapshot, overhead)
                        continue
                    recvs.setdefault(pkey, deque()).append(r)
                    status[r] = BLOCKED
                    blocked_on[r] = op
                    return
                if cls is Isend:
                    sid = op.sig_id
                    if sid is None:
                        sid = op.sig_id = self._p2p_sid("send", op.nbytes)
                    slot = isend_slots[0]
                    isend_slots[0] = slot + 1
                    if recording:
                        events.append((EV_IPOST, r, sid, slot))
                    vote = critter.p2p_vote(r, sid)
                    snapshot = critter.isend_snapshot(r)
                    pkey = (r, op.dst, op.tag)
                    q = recvs.get(pkey)
                    if q:
                        rcv = q.popleft()
                        if recording:
                            events.append((EV_IMATCH, r, rcv, sid, slot))
                        critter.on_isend_match(r, rcv, sid, sampler, vote,
                                               snapshot, overhead)
                        status[rcv] = RUNNABLE
                        blocked_on[rcv] = None
                        heappush(heap,
                                 (sweep if rcv > r else sweep + 1, rcv))
                    else:
                        sends.setdefault(pkey, deque()).append(
                            (r, sid, vote, snapshot, slot))
                    next_handle[0] += 1
                    value = next_handle[0]
                    continue
                if cls is Wait:
                    # buffered isend: completion is free; the interception
                    # point exists but statistics were updated at match time
                    continue
                raise TypeError(f"rank {r} yielded unknown op {op!r}")

        while heap:
            sweep, r = heappop(heap)
            if status[r] == RUNNABLE:
                advance(r, sweep)
        if live > 0:
            blocked = [(r, blocked_on[r]) for r in range(n)
                       if status[r] == BLOCKED]
            if blocked:
                detail = ", ".join(f"rank {r}: {op!r}"
                                   for r, op in blocked[:8])
                raise DeadlockError(
                    f"{len(blocked)} ranks blocked with no progress: "
                    f"{detail}")
        elif recording:
            try:
                self._traces[program_factory] = self._compile_events(events)
            except TypeError:
                pass

        return RunResult.from_report(critter.report())
