"""The discrete-event engine.

Runs one *tuning iteration* (one benchmark execution of one configuration):
every virtual rank executes its generator program; computation kernels are
handled inline; communications block until matched; each interception point
invokes the Critter protocol (core.critter), which advances per-rank clocks
and path profiles and makes the selective-execution decision.

Matching semantics:

- collectives match by per-communicator arrival index (the k-th collective
  a rank posts on communicator C completes with every other rank's k-th);
  a mismatch in op kind OR byte count across participants is a schedule bug
  and raises;
- blocking Send/Recv are rendezvous; Isend is buffered (deposits a snapshot
  of the sender's path profile, sender proceeds); Recv matches Send/Isend
  in post order per (src, dst, tag);
- Wait on an Isend request is an interception no-op (buffered completion).

If no rank can make progress before all programs finish, DeadlockError
reports the blocked ranks and what they wait on.

Hot-path design (see also core.critter):

- **signature interning**: every op resolves its Signature to a dense
  integer id once, cached on the op instance (ops are reused via trace
  replay), so the per-event cost is an attribute read instead of a
  dataclass hash;
- **record/replay split**: rank programs are generators whose op streams do
  not depend on engine feedback (the only value sent back is the opaque
  Isend handle, consumed by Wait), and communication matching in this
  engine is purely structural — independent of sampled times.  The
  interleaved sequence of Critter interceptions is therefore identical
  across iterations of one configuration, so the first execution of a
  program factory runs a *structural recording pass* (generators, matching
  queues, scheduler — no Critter, no RNG) that emits a flat event program;
  every iteration, including the first, then executes that program through
  an interpreter, skipping generators and matching entirely on all
  subsequent iterations (the common case — the tuner runs trials-many
  iterations per configuration).  Runs of consecutive computation kernels
  of one rank are fused into blocks that the profiler can charge in one
  vectorized step.  Pass ``trace_cache=False`` for programs whose op
  stream is nondeterministic or feedback-dependent; that path interleaves
  recording-free matching with scalar interception exactly like the seed
  engine;
- **batched cold runs**: forced (recording/reference) executions sample
  every kernel, so the cold interpreter pre-splits the event program into
  *segments* bounded by RNG-consuming communication events and draws each
  segment's computation-kernel samples in one vectorized call when the
  cost model supports it (``CostModel.batch_info``: lognormal noise with
  the straggler branch off), falling back to per-event scalar draws — the
  same calls in the same order — when it does not.  Charging is batched
  per fused block (``Critter.on_comp_block_cold``) with sequential
  float accumulation, so path metrics, statistics, and the sampler RNG
  stream stay bit-identical to the scalar path;
- **runnable queue**: first-run scheduling pops a (sweep, rank) heap
  instead of scanning all ranks per pass, preserving the exact round-robin
  order of the seed engine (a rank unblocked by a lower-ranked completer
  runs in the same sweep; one unblocked by a higher-ranked completer runs
  in the next), which keeps sampler RNG consumption — and therefore
  results — bit-identical.
"""

from __future__ import annotations

import weakref
from collections import deque
from heapq import heappop, heappush
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.critter import Critter, IterationReport
from repro.core.signatures import Signature, comm_sig, comp_sig, p2p_sig
from .comm import World
from .ops import (CS_BLOCK, CS_COLL, CS_COMP, CS_IMATCH, CS_IPOST, CS_P2P,
                  EV_BLOCK, EV_COLL, EV_COMP, EV_IMATCH, EV_IPOST, EV_P2P,
                  KIND_COLL, KIND_COMP, KIND_ISEND, KIND_RECV, KIND_SEND,
                  KIND_WAIT)
from .program import (CompBlock, ColdProgram, EventProgram, ProgramCache,
                      WarmProgram, build_cold, build_warm, compile_events)

# compiled-program containers and lowering passes live in .program (PR 10:
# programs are serializable, cacheable artifacts); the historical private
# names stay importable for the bench/test harnesses
_CompBlock = CompBlock
_EventProgram = EventProgram
_WarmProgram = WarmProgram
_ColdProgram = ColdProgram

RUNNABLE, BLOCKED, DONE = 0, 1, 2


class DeadlockError(RuntimeError):
    pass


class RunResult(IterationReport):

    @classmethod
    def from_report(cls, rep: IterationReport) -> "RunResult":
        return cls(rep.predicted_time, rep.wall_time, rep.crit_comp,
                   rep.crit_comm, rep.measured_time, rep.max_measured_comp,
                   rep.executed, rep.skipped, rep.events)


class _CollSite:
    __slots__ = ("op", "nbytes", "arrived", "needed", "sig_id")

    def __init__(self, op, nbytes, needed, sig_id):
        self.op = op
        self.nbytes = nbytes
        self.arrived: List[int] = []
        self.needed = needed
        self.sig_id = sig_id


class Runtime:
    """One World + one Critter profiler + a timing source."""

    def __init__(self, world: World, critter: Critter,
                 timer: Callable[[Signature, np.random.Generator], float],
                 *, seed: int = 0, overhead: float = 1e-6,
                 trace_cache: bool = True, compiled: bool = True,
                 program_cache: Optional[ProgramCache] = None):
        self.world = world
        self.critter = critter
        self.timer = timer
        self.overhead = overhead
        self.trace_cache = trace_cache
        # cross-Runtime event-program cache (see .program): consulted for
        # factories that carry a ``program_key`` structural fingerprint;
        # a hit materializes the recorded program into this World and
        # skips ``_record`` entirely
        self.program_cache = program_cache
        # compiled selective replay (Critter.run_warm over the segmented
        # warm program).  Bit-identical to the plain event interpreter;
        # ``compiled=False`` forces the scalar warm path (the bench harness
        # measures the compiled speedup against it)
        self.compiled = compiled
        self._rng = np.random.default_rng(seed)
        self._intern = world.interner.intern
        self._sig_cache: Dict[tuple, int] = {}
        # batched cold-run sampling: available when the timer is a bound
        # method of an object exposing ``batch_info(sigs) -> (det, sigma)
        # | None`` (CostModel); anything else falls back to per-event
        # scalar draws, which preserve the RNG stream by construction
        timer_obj = getattr(timer, "__self__", None)
        self._batch_info = getattr(timer_obj, "batch_info", None)
        # counter-RNG batched sampling (CostModel.sample_block): vectorizes
        # the whole draw sequence even with the straggler branch on — the
        # counter discipline gives every event fixed draw slots, so there
        # is no scalar fallback left to pay
        self._sample_block = getattr(timer_obj, "sample_block", None)
        # program_factory -> compiled event program (weak: dies with the
        # configuration's program factory) — the fallback identity-keyed
        # map for factories without a structural fingerprint
        self._traces = weakref.WeakKeyDictionary()
        # structural fingerprint -> compiled event program (strong: equal
        # geometries share one program even when their factory objects are
        # distinct or short-lived)
        self._keyed: Dict[str, EventProgram] = {}
        # observability: recordings counts actual structural passes;
        # cache_hits/misses count program_cache consultations (fingerprint
        # -keyed factories only)
        self.recordings = 0
        self.cache_hits = 0
        self.cache_misses = 0

    # -- signature interning (hot path) --------------------------------------

    def _comp_sid(self, name, params) -> int:
        key = (0, name, params)
        sid = self._sig_cache.get(key)
        if sid is None:
            sid = self._intern(comp_sig(name, *params))
            self._sig_cache[key] = sid
        return sid

    def _coll_sid(self, op, comm, nbytes) -> int:
        key = (1, op, comm.size, comm.stride, nbytes)
        sid = self._sig_cache.get(key)
        if sid is None:
            sid = self._intern(comm_sig(op, nbytes, comm.size, comm.stride))
            self._sig_cache[key] = sid
        return sid

    def _p2p_sid(self, name, nbytes) -> int:
        key = (2, name, nbytes)
        sid = self._sig_cache.get(key)
        if sid is None:
            sid = self._intern(p2p_sig(name, nbytes))
            self._sig_cache[key] = sid
        return sid

    # -- structural recording pass --------------------------------------------

    def _record(self, program_factory) -> list:
        """Run the rank generators to exhaustion, matching communication
        structurally, and record the flat interception sequence WITHOUT
        invoking the Critter protocol or consuming sampler RNG.

        Matching is independent of sampled times, so the recorded program
        replayed through the interpreters produces interceptions (and RNG
        consumption) bit-identical to the historical interleaved pass.  A
        deadlock or collective mismatch therefore raises before any
        profiler state is touched.

        KEEP IN SYNC with ``_run_live``: both implement the same
        structural matching semantics (collective site validation, p2p
        queues, heap sweeps, deadlock reporting); this copy exists so the
        recording pass pays zero interception branches per op.  Any
        change to matching must land in both; tests/test_cold_path.py and
        tests/test_golden_reports.py pin their equivalence."""
        world = self.world
        n = world.size
        gens = [program_factory(r, world) for r in range(n)]
        events: list = []
        append = events.append
        status = [RUNNABLE] * n
        blocked_on: List[Optional[object]] = [None] * n
        coll_sites: Dict[Tuple[int, int], _CollSite] = {}
        coll_counts: Dict[Tuple[int, int], int] = {}
        # send entry: (sender_rank, sig_id, slot_or_None); None = rendezvous
        sends: Dict[tuple, deque] = {}
        recvs: Dict[tuple, deque] = {}
        state = [0, 0, n]        # isend slot counter, next handle, live

        def advance(r, sweep, value=None):
            """Run rank r until it blocks or finishes."""
            gen = gens[r]
            while True:
                try:
                    op = gen.send(value)
                except StopIteration:
                    status[r] = DONE
                    state[2] -= 1
                    return
                value = None
                k = op.KIND
                if k == KIND_COMP:
                    sid = op.sig_id
                    if sid is None:
                        sid = op.sig_id = self._comp_sid(op.name, op.params)
                    append((EV_COMP, r, sid))
                    continue
                if k == KIND_COLL:
                    comm = op.comm
                    key = (comm.id, r)
                    idx = coll_counts.get(key, 0)
                    coll_counts[key] = idx + 1
                    skey = (comm.id, idx)
                    site = coll_sites.get(skey)
                    if site is None:
                        sid = op.sig_id
                        if sid is None:
                            sid = op.sig_id = \
                                self._coll_sid(op.op, comm, op.nbytes)
                        site = _CollSite(op.op, op.nbytes, comm.size, sid)
                        coll_sites[skey] = site
                    elif site.op != op.op:
                        raise RuntimeError(
                            f"collective mismatch on comm {comm.id} site {idx}:"
                            f" {site.op} vs {op.op} (rank {r})")
                    elif site.nbytes != op.nbytes:
                        raise RuntimeError(
                            f"collective byte-count mismatch on comm "
                            f"{comm.id} site {idx} ({site.op}): "
                            f"{site.nbytes}B vs {op.nbytes}B (rank {r})")
                    site.arrived.append(r)
                    if len(site.arrived) < site.needed:
                        status[r] = BLOCKED
                        blocked_on[r] = op
                        return
                    del coll_sites[skey]
                    append((EV_COLL, site.sig_id, comm))
                    for rr in site.arrived:
                        if rr != r:
                            status[rr] = RUNNABLE
                            blocked_on[rr] = None
                            heappush(heap,
                                     (sweep if rr > r else sweep + 1, rr))
                    continue
                if k == KIND_SEND:
                    sid = op.sig_id
                    if sid is None:
                        sid = op.sig_id = self._p2p_sid("send", op.nbytes)
                    pkey = (r, op.dst, op.tag)
                    q = recvs.get(pkey)
                    if q:
                        q.popleft()
                        append((EV_P2P, r, op.dst, sid))
                        dst = op.dst
                        status[dst] = RUNNABLE
                        blocked_on[dst] = None
                        heappush(heap,
                                 (sweep if dst > r else sweep + 1, dst))
                        continue
                    sends.setdefault(pkey, deque()).append((r, sid, None))
                    status[r] = BLOCKED
                    blocked_on[r] = op
                    return
                if k == KIND_RECV:
                    pkey = (op.src, r, op.tag)
                    q = sends.get(pkey)
                    if q:
                        src, sid, slot = q.popleft()
                        if slot is None:   # blocking sender, rendezvous
                            append((EV_P2P, src, r, sid))
                            status[src] = RUNNABLE
                            blocked_on[src] = None
                            heappush(heap,
                                     (sweep if src > r else sweep + 1, src))
                        else:              # buffered isend
                            append((EV_IMATCH, src, r, sid, slot))
                        continue
                    recvs.setdefault(pkey, deque()).append(r)
                    status[r] = BLOCKED
                    blocked_on[r] = op
                    return
                if k == KIND_ISEND:
                    sid = op.sig_id
                    if sid is None:
                        sid = op.sig_id = self._p2p_sid("send", op.nbytes)
                    slot = state[0]
                    state[0] = slot + 1
                    append((EV_IPOST, r, sid, slot))
                    pkey = (r, op.dst, op.tag)
                    q = recvs.get(pkey)
                    if q:
                        rcv = q.popleft()
                        append((EV_IMATCH, r, rcv, sid, slot))
                        status[rcv] = RUNNABLE
                        blocked_on[rcv] = None
                        heappush(heap,
                                 (sweep if rcv > r else sweep + 1, rcv))
                    else:
                        sends.setdefault(pkey, deque()).append((r, sid, slot))
                    state[1] += 1
                    value = state[1]
                    continue
                if k == KIND_WAIT:
                    continue
                raise TypeError(f"rank {r} yielded unknown op {op!r}")

        heap: List[Tuple[int, int]] = [(0, r) for r in range(n)]
        while heap:
            sweep, r = heappop(heap)
            if status[r] == RUNNABLE:
                advance(r, sweep)
        if state[2] > 0:
            blocked = [(r, blocked_on[r]) for r in range(n)
                       if status[r] == BLOCKED]
            if blocked:
                detail = ", ".join(f"rank {r}: {op!r}"
                                   for r, op in blocked[:8])
                raise DeadlockError(
                    f"{len(blocked)} ranks blocked with no progress: "
                    f"{detail}")
        self.recordings += 1
        return events

    # -- event-program compilation --------------------------------------------

    # the lowering passes live in .program (they are pure functions of the
    # event list + the World's interner table); these wrappers keep the
    # historical call sites — and the bench/test harnesses poking them —
    # working unchanged
    _compile_events = staticmethod(compile_events)

    def _build_cold(self, prog: EventProgram) -> ColdProgram:
        return build_cold(prog, self.world.interner.sigs)

    def _build_warm(self, prog: EventProgram) -> WarmProgram:
        return build_warm(prog, self.world.interner.sigs)

    def warm_meta(self, program_factory) -> dict:
        """Segmentation statistics of the compiled warm program for
        ``program_factory`` (recording + compiling it if needed) — consumed
        by the bench harness and the CI engine gate."""
        prog = self._get_program(program_factory)
        warm = prog.warm
        if warm is None:
            warm = prog.warm = self._build_warm(prog)
        return dict(warm.meta)

    # -- interpreters ---------------------------------------------------------

    def _run_events(self, prog: _EventProgram, sampler) -> None:
        """Execute a compiled event program: the scheduler, matching queues
        and generators are gone; only the interception sequence remains."""
        critter = self.critter
        overhead = self.overhead
        on_comp = critter.on_comp
        on_comp_block = critter.on_comp_block
        on_coll = critter.on_coll
        on_p2p = critter.on_p2p
        on_isend_match = critter.on_isend_match
        p2p_vote = critter.p2p_vote
        isend_snapshot = critter.isend_snapshot
        slots: List[Optional[tuple]] = [None] * prog.n_slots
        for ev in prog.events:
            k = ev[0]
            if k == EV_COMP:
                on_comp(ev[1], ev[2], sampler)
            elif k == EV_IPOST:
                slots[ev[3]] = (p2p_vote(ev[1], ev[2]),
                                isend_snapshot(ev[1]))
            elif k == EV_IMATCH:
                vote, snapshot = slots[ev[4]]
                on_isend_match(ev[1], ev[2], ev[3], sampler, vote, snapshot,
                               overhead)
            elif k == EV_P2P:
                on_p2p(ev[1], ev[2], ev[3], sampler,
                       p2p_vote(ev[1], ev[3]), overhead)
            elif k == EV_BLOCK:
                on_comp_block(ev[1], ev[2], sampler)
            else:
                on_coll(ev[1], ev[2], sampler, overhead)

    def _run_events_cold(self, cold: _ColdProgram) -> None:
        """Execute a cold program under force_execute.

        When the cost model batches, every sample of the run — computation
        AND communication — is drawn up front in one vectorized call and
        each step consumes its precomputed time at a running cursor;
        otherwise each sampling step draws through the scalar timer at its
        own position, which is the same call sequence as the interleaved
        seed engine.  All interceptions go through the force-specialized
        ``*_cold`` Critter methods, which operate on list-backed per-rank
        scalar mirrors for the duration of the run (``begin_cold`` ..
        ``finish_cold``) — NumPy scalar indexing dominates the p2p-heavy
        hot path otherwise, particularly under the scalar-fallback draws
        of straggler-enabled cost models."""
        critter = self.critter
        critter.state.ensure(cold.max_sid)
        critter.begin_cold()
        rng = self._rng
        timer = self.timer
        overhead = self.overhead
        on_comp_cold = critter.on_comp_cold
        on_comp_block_cold = critter.on_comp_block_cold
        on_coll_cold = critter.on_coll_cold
        on_p2p_cold = critter.on_p2p_cold
        on_isend_match_cold = critter.on_isend_match_cold
        isend_snapshot_cold = critter.isend_snapshot_cold
        slots: List[Optional[tuple]] = [None] * cold.n_slots

        ts = None
        if self._sample_block is not None and cold.draw_sigs:
            # counter-RNG batching: the whole draw sequence — stragglers
            # included — in one vectorized pass (no cache: the draw cursor
            # advances per run).  None when the model is not in counter
            # mode; fall through to the legacy batch/scalar paths.
            drawn = self._sample_block(cold.draw_sigs)
            if drawn is not None:
                ts = drawn.tolist()
        if ts is None:
            info = cold.batch
            if info is None:
                info = False
                if self._batch_info is not None and cold.draw_sigs:
                    bi = self._batch_info(cold.draw_sigs)
                    if bi is not None:
                        info = bi
                cold.batch = info
            if info is not False:
                det, sigma = info
                ts = (det * np.exp(
                    sigma * rng.standard_normal(len(det)))).tolist()
        cur = 0

        for st in cold.steps:
            k = st[0]
            if k == CS_COMP:
                if ts is None:
                    t = timer(st[3], rng)
                else:
                    t = ts[cur]
                    cur += 1
                on_comp_cold(st[1], st[2], t)
            elif k == CS_IPOST:
                slots[st[2]] = isend_snapshot_cold(st[1])
            elif k == CS_IMATCH:
                if ts is None:
                    t = timer(st[5], rng)
                else:
                    t = ts[cur]
                    cur += 1
                on_isend_match_cold(st[1], st[2], st[3], t, slots[st[4]],
                                    overhead)
            elif k == CS_BLOCK:
                block = st[2]
                if ts is None:
                    tsl = [timer(sig, rng) for sig in st[3]]
                else:
                    end = cur + block.n
                    tsl = ts[cur:end]
                    cur = end
                on_comp_block_cold(st[1], block, tsl)
            elif k == CS_P2P:
                if ts is None:
                    t = timer(st[4], rng)
                else:
                    t = ts[cur]
                    cur += 1
                on_p2p_cold(st[1], st[2], st[3], t, overhead)
            else:
                if ts is None:
                    t = timer(st[3], rng)
                else:
                    t = ts[cur]
                    cur += 1
                on_coll_cold(st[1], st[2], t, overhead)
        critter.finish_cold(cold.exec_rows, cold.exec_cols)

    # -- main loop ------------------------------------------------------------

    def run(self, program_factory, *, force_execute: bool = False,
            update_stats: bool = True) -> RunResult:
        critter = self.critter
        critter.begin_iteration(force_execute=force_execute,
                                update_stats=update_stats)
        rng = self._rng
        timer = self.timer
        sampler = lambda sig: timer(sig, rng)  # noqa: E731

        if not self.trace_cache:
            self._run_live(program_factory, sampler)
            return RunResult.from_report(critter.report())

        prog = self._get_program(program_factory)
        if force_execute:
            cold = prog.cold
            if cold is None:
                cold = prog.cold = self._build_cold(prog)
            self._run_events_cold(cold)
        elif self.compiled and critter.warm_eligible():
            warm = prog.warm
            if warm is None:
                warm = prog.warm = self._build_warm(prog)
            critter.run_warm(warm, sampler, self.overhead)
        else:
            self._run_events(prog, sampler)
        return RunResult.from_report(critter.report())

    def _get_program(self, program_factory) -> EventProgram:
        # fingerprint-keyed path: factories stamped with a structural
        # fingerprint (``program_key``) share programs across equal
        # geometries in-process and, when a ProgramCache is configured,
        # across Runtimes / processes / runs
        key = getattr(program_factory, "program_key", None)
        if key is not None:
            prog = self._keyed.get(key)
            if prog is not None:
                return prog
            cache = self.program_cache
            if cache is not None:
                prog = cache.get(key, self.world)
                if prog is not None:
                    self.cache_hits += 1
                    self._keyed[key] = prog
                    return prog
                self.cache_misses += 1
            prog = self._record_keyed(key, program_factory)
            self._keyed[key] = prog
            return prog
        try:
            prog = self._traces.get(program_factory)
        except TypeError:            # unhashable/unweakrefable factory
            prog = None
        if prog is None:
            prog = compile_events(self._record(program_factory))
            try:
                self._traces[program_factory] = prog
            except TypeError:
                pass
        return prog

    def _record_keyed(self, key: str, program_factory) -> EventProgram:
        """Record + compile under a structural fingerprint, publishing to
        the configured ProgramCache.  The World's communicator-creation
        delta is captured around the recording pass and stored with the
        artifact so a loading World replays the same creations in the same
        order (channel-registry aggregates are order-sensitive — see
        .program's bit-identity contract)."""
        n_comms = len(self.world._comms)
        prog = compile_events(self._record(program_factory))
        if self.program_cache is not None:
            new_comms = list(self.world._comms)[n_comms:]
            self.program_cache.put(key, prog, self.world, comms=new_comms)
        return prog

    def adopt_program(self, key: str, prog: EventProgram) -> None:
        """Inject a pre-recorded program under a structural fingerprint:
        subsequent runs of factories stamped with ``program_key == key``
        skip ``_record`` entirely.  The program must have been materialized
        into (or recorded in) THIS Runtime's World — sids are World-local."""
        self._keyed[key] = prog

    def _run_live(self, program_factory, sampler) -> None:
        """The seed engine's interleaved pass (``trace_cache=False``):
        generators, structural matching, and scalar Critter interception in
        one loop, nothing recorded.  Kept for programs whose op streams are
        nondeterministic or feedback-dependent — and as the reference
        implementation the recorded paths are pinned against
        (tests/test_cold_path.py, tests/test_golden_reports.py).

        KEEP IN SYNC with ``_record``: same structural matching semantics,
        see the note there."""
        world = self.world
        critter = self.critter
        overhead = self.overhead
        n = world.size
        gens = [program_factory(r, world) for r in range(n)]
        isend_slots = [0]
        status = [RUNNABLE] * n
        blocked_on: List[Optional[object]] = [None] * n
        # collective sites: (comm.id, site_index) -> _CollSite
        coll_sites: Dict[Tuple[int, int], _CollSite] = {}
        coll_counts: Dict[Tuple[int, int], int] = {}
        # p2p queues: (src, dst, tag) -> deque of entries
        # send entry: (sender_rank, sig_id, vote, snapshot_or_None, slot)
        sends: Dict[tuple, deque] = {}
        recvs: Dict[tuple, deque] = {}
        next_handle = [0]
        # runnable queue: (sweep, rank) min-heap reproducing the seed
        # engine's sorted round-robin sweeps exactly
        heap: List[Tuple[int, int]] = [(0, r) for r in range(n)]

        live = [n]

        def advance(r, sweep, value=None):
            """Run rank r until it blocks or finishes."""
            gen = gens[r]
            while True:
                try:
                    op = gen.send(value)
                except StopIteration:
                    status[r] = DONE
                    live[0] -= 1
                    return
                value = None
                k = op.KIND
                if k == KIND_COMP:
                    sid = op.sig_id
                    if sid is None:
                        sid = op.sig_id = self._comp_sid(op.name, op.params)
                    critter.on_comp(r, sid, sampler)
                    continue
                if k == KIND_COLL:
                    comm = op.comm
                    key = (comm.id, r)
                    idx = coll_counts.get(key, 0)
                    coll_counts[key] = idx + 1
                    skey = (comm.id, idx)
                    site = coll_sites.get(skey)
                    if site is None:
                        sid = op.sig_id
                        if sid is None:
                            sid = op.sig_id = \
                                self._coll_sid(op.op, comm, op.nbytes)
                        site = _CollSite(op.op, op.nbytes, comm.size, sid)
                        coll_sites[skey] = site
                    elif site.op != op.op:
                        raise RuntimeError(
                            f"collective mismatch on comm {comm.id} site {idx}:"
                            f" {site.op} vs {op.op} (rank {r})")
                    elif site.nbytes != op.nbytes:
                        raise RuntimeError(
                            f"collective byte-count mismatch on comm "
                            f"{comm.id} site {idx} ({site.op}): "
                            f"{site.nbytes}B vs {op.nbytes}B (rank {r})")
                    site.arrived.append(r)
                    if len(site.arrived) < site.needed:
                        status[r] = BLOCKED
                        blocked_on[r] = op
                        return
                    # complete the collective
                    del coll_sites[skey]
                    critter.on_coll(site.sig_id, comm, sampler, overhead)
                    for rr in site.arrived:
                        if rr != r:
                            status[rr] = RUNNABLE
                            blocked_on[rr] = None
                            heappush(heap,
                                     (sweep if rr > r else sweep + 1, rr))
                    continue
                if k == KIND_SEND:
                    sid = op.sig_id
                    if sid is None:
                        sid = op.sig_id = self._p2p_sid("send", op.nbytes)
                    pkey = (r, op.dst, op.tag)
                    q = recvs.get(pkey)
                    if q:
                        q.popleft()
                        vote = critter.p2p_vote(r, sid)
                        critter.on_p2p(r, op.dst, sid, sampler, vote,
                                       overhead)
                        dst = op.dst
                        status[dst] = RUNNABLE
                        blocked_on[dst] = None
                        heappush(heap,
                                 (sweep if dst > r else sweep + 1, dst))
                        continue
                    sends.setdefault(pkey, deque()).append(
                        (r, sid, None, None, 0))
                    status[r] = BLOCKED
                    blocked_on[r] = op
                    return
                if k == KIND_RECV:
                    pkey = (op.src, r, op.tag)
                    q = sends.get(pkey)
                    if q:
                        src, sid, vote, snapshot, slot = q.popleft()
                        if snapshot is None:   # blocking sender, rendezvous
                            vote = critter.p2p_vote(src, sid)
                            critter.on_p2p(src, r, sid, sampler, vote,
                                           overhead)
                            status[src] = RUNNABLE
                            blocked_on[src] = None
                            heappush(heap,
                                     (sweep if src > r else sweep + 1, src))
                        else:                  # buffered isend
                            critter.on_isend_match(src, r, sid, sampler,
                                                   vote, snapshot, overhead)
                        continue
                    recvs.setdefault(pkey, deque()).append(r)
                    status[r] = BLOCKED
                    blocked_on[r] = op
                    return
                if k == KIND_ISEND:
                    sid = op.sig_id
                    if sid is None:
                        sid = op.sig_id = self._p2p_sid("send", op.nbytes)
                    slot = isend_slots[0]
                    isend_slots[0] = slot + 1
                    vote = critter.p2p_vote(r, sid)
                    snapshot = critter.isend_snapshot(r)
                    pkey = (r, op.dst, op.tag)
                    q = recvs.get(pkey)
                    if q:
                        rcv = q.popleft()
                        critter.on_isend_match(r, rcv, sid, sampler, vote,
                                               snapshot, overhead)
                        status[rcv] = RUNNABLE
                        blocked_on[rcv] = None
                        heappush(heap,
                                 (sweep if rcv > r else sweep + 1, rcv))
                    else:
                        sends.setdefault(pkey, deque()).append(
                            (r, sid, vote, snapshot, slot))
                    next_handle[0] += 1
                    value = next_handle[0]
                    continue
                if k == KIND_WAIT:
                    # buffered isend: completion is free; the interception
                    # point exists but statistics were updated at match time
                    continue
                raise TypeError(f"rank {r} yielded unknown op {op!r}")

        while heap:
            sweep, r = heappop(heap)
            if status[r] == RUNNABLE:
                advance(r, sweep)
        if live[0] > 0:
            blocked = [(r, blocked_on[r]) for r in range(n)
                       if status[r] == BLOCKED]
            if blocked:
                detail = ", ".join(f"rank {r}: {op!r}"
                                   for r, op in blocked[:8])
                raise DeadlockError(
                    f"{len(blocked)} ranks blocked with no progress: "
                    f"{detail}")
