"""The discrete-event engine.

Runs one *tuning iteration* (one benchmark execution of one configuration):
every virtual rank executes its generator program; computation kernels are
handled inline; communications block until matched; each interception point
invokes the Critter protocol (core.critter), which advances per-rank clocks
and path profiles and makes the selective-execution decision.

Matching semantics:

- collectives match by per-communicator arrival index (the k-th collective
  a rank posts on communicator C completes with every other rank's k-th);
  a mismatch in op kind or byte count across participants is a schedule bug
  and raises;
- blocking Send/Recv are rendezvous; Isend is buffered (deposits a snapshot
  of the sender's path profile, sender proceeds); Recv matches Send/Isend
  in post order per (src, dst, tag);
- Wait on an Isend request is an interception no-op (buffered completion).

If no rank can make progress before all programs finish, DeadlockError
reports the blocked ranks and what they wait on.
"""

from __future__ import annotations

from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.critter import Critter, IterationReport
from repro.core.signatures import Signature, comm_sig, comp_sig, p2p_sig
from .comm import World
from .ops import Coll, Comp, Isend, Recv, Send, Wait

RUNNABLE, BLOCKED, DONE = 0, 1, 2


class DeadlockError(RuntimeError):
    pass


class RunResult(IterationReport):
    pass


class _CollSite:
    __slots__ = ("op", "nbytes", "arrived", "needed")

    def __init__(self, op, nbytes, needed):
        self.op = op
        self.nbytes = nbytes
        self.arrived: List[int] = []
        self.needed = needed


class Runtime:
    """One World + one Critter profiler + a timing source."""

    def __init__(self, world: World, critter: Critter,
                 timer: Callable[[Signature, np.random.Generator], float],
                 *, seed: int = 0, overhead: float = 1e-6):
        self.world = world
        self.critter = critter
        self.timer = timer
        self.overhead = overhead
        self._rng = np.random.default_rng(seed)
        self._sig_cache: Dict[tuple, Signature] = {}

    # -- signature interning (hot path) --------------------------------------

    def _comp_sig(self, name, params) -> Signature:
        key = (0, name, params)
        s = self._sig_cache.get(key)
        if s is None:
            s = comp_sig(name, *params)
            self._sig_cache[key] = s
        return s

    def _coll_sig(self, op, comm, nbytes) -> Signature:
        key = (1, op, comm.size, comm.stride, nbytes)
        s = self._sig_cache.get(key)
        if s is None:
            s = comm_sig(op, nbytes, comm.size, comm.stride)
            self._sig_cache[key] = s
        return s

    def _p2p_sig(self, name, nbytes) -> Signature:
        key = (2, name, nbytes)
        s = self._sig_cache.get(key)
        if s is None:
            s = p2p_sig(name, nbytes)
            self._sig_cache[key] = s
        return s

    # -- main loop ------------------------------------------------------------

    def run(self, program_factory, *, force_execute: bool = False,
            update_stats: bool = True) -> RunResult:
        world = self.world
        critter = self.critter
        critter.begin_iteration(force_execute=force_execute,
                                update_stats=update_stats)
        rng = self._rng
        timer = self.timer
        sampler = lambda sig: timer(sig, rng)  # noqa: E731
        overhead = self.overhead

        n = world.size
        gens = [program_factory(r, world) for r in range(n)]
        status = [RUNNABLE] * n
        blocked_on = [None] * n
        # collective sites: (comm.id, site_index) -> _CollSite
        coll_sites: Dict[Tuple[int, int], _CollSite] = {}
        coll_counts: Dict[Tuple[int, int], int] = {}
        # p2p queues: (src, dst, tag) -> deque of entries
        # send entry: (sender_rank, nbytes, vote, post_clock_or_None)
        sends: Dict[tuple, deque] = {}
        recvs: Dict[tuple, deque] = {}
        next_handle = [0]

        live = n

        def advance(r, value=None):
            """Run rank r until it blocks or finishes; returns ops handled."""
            nonlocal live
            gen = gens[r]
            while True:
                try:
                    op = gen.send(value)
                except StopIteration:
                    status[r] = DONE
                    live -= 1
                    return
                value = None
                cls = op.__class__
                if cls is Comp:
                    sig = self._comp_sig(op.name, op.params)
                    critter.on_comp(r, sig, sampler)
                    continue
                if cls is Coll:
                    comm = op.comm
                    key = (comm.id, r)
                    idx = coll_counts.get(key, 0)
                    coll_counts[key] = idx + 1
                    skey = (comm.id, idx)
                    site = coll_sites.get(skey)
                    if site is None:
                        site = _CollSite(op.op, op.nbytes, comm.size)
                        coll_sites[skey] = site
                    elif site.op != op.op:
                        raise RuntimeError(
                            f"collective mismatch on comm {comm.id} site {idx}:"
                            f" {site.op} vs {op.op} (rank {r})")
                    site.arrived.append(r)
                    if len(site.arrived) < site.needed:
                        status[r] = BLOCKED
                        blocked_on[r] = op
                        return
                    # complete the collective
                    del coll_sites[skey]
                    sig = self._coll_sig(op.op, comm, max(site.nbytes, op.nbytes))
                    critter.on_coll(sig, comm, sampler, overhead)
                    for rr in site.arrived:
                        if rr != r:
                            status[rr] = RUNNABLE
                            blocked_on[rr] = None
                    continue
                if cls is Send:
                    pkey = (r, op.dst, op.tag)
                    q = recvs.get(pkey)
                    if q:
                        q.popleft()
                        sig = self._p2p_sig("send", op.nbytes)
                        vote = critter.p2p_vote(r, sig)
                        critter.on_p2p(r, op.dst, sig, sampler, vote, overhead)
                        status[op.dst] = RUNNABLE
                        blocked_on[op.dst] = None
                        continue
                    sends.setdefault(pkey, deque()).append(
                        (r, op.nbytes, None, None))
                    status[r] = BLOCKED
                    blocked_on[r] = op
                    return
                if cls is Recv:
                    pkey = (op.src, r, op.tag)
                    q = sends.get(pkey)
                    if q:
                        src, nbytes, vote, snapshot = q.popleft()
                        sig = self._p2p_sig("send", nbytes)
                        if snapshot is None:   # blocking sender, rendezvous
                            vote = critter.p2p_vote(src, sig)
                            critter.on_p2p(src, r, sig, sampler, vote,
                                           overhead)
                            status[src] = RUNNABLE
                            blocked_on[src] = None
                        else:                  # buffered isend
                            critter.on_isend_match(src, r, sig, sampler,
                                                   vote, snapshot, overhead)
                        continue
                    recvs.setdefault(pkey, deque()).append(r)
                    status[r] = BLOCKED
                    blocked_on[r] = op
                    return
                if cls is Isend:
                    sig = self._p2p_sig("send", op.nbytes)
                    vote = critter.p2p_vote(r, sig)
                    snapshot = critter.isend_snapshot(r)
                    pkey = (r, op.dst, op.tag)
                    q = recvs.get(pkey)
                    if q:
                        rcv = q.popleft()
                        critter.on_isend_match(r, rcv, sig, sampler, vote,
                                               snapshot, overhead)
                        status[rcv] = RUNNABLE
                        blocked_on[rcv] = None
                    else:
                        sends.setdefault(pkey, deque()).append(
                            (r, op.nbytes, vote, snapshot))
                    next_handle[0] += 1
                    value = next_handle[0]
                    continue
                if cls is Wait:
                    # buffered isend: completion is free; the interception
                    # point exists but statistics were updated at match time
                    continue
                raise TypeError(f"rank {r} yielded unknown op {op!r}")

        # round-robin scheduling over runnable ranks
        made_progress = True
        while live > 0:
            made_progress = False
            for r in range(n):
                if status[r] == RUNNABLE:
                    made_progress = True
                    advance(r)
            if not made_progress:
                blocked = [(r, blocked_on[r]) for r in range(n)
                           if status[r] == BLOCKED]
                if not blocked:
                    break
                detail = ", ".join(f"rank {r}: {op!r}"
                                   for r, op in blocked[:8])
                raise DeadlockError(
                    f"{len(blocked)} ranks blocked with no progress: {detail}")

        rep = critter.report()
        return RunResult(rep.predicted_time, rep.wall_time, rep.crit_comp,
                         rep.crit_comm, rep.measured_time,
                         rep.max_measured_comp, rep.executed, rep.skipped,
                         rep.events)
