"""The discrete-event engine.

Runs one *tuning iteration* (one benchmark execution of one configuration):
every virtual rank executes its generator program; computation kernels are
handled inline; communications block until matched; each interception point
invokes the Critter protocol (core.critter), which advances per-rank clocks
and path profiles and makes the selective-execution decision.

Matching semantics:

- collectives match by per-communicator arrival index (the k-th collective
  a rank posts on communicator C completes with every other rank's k-th);
  a mismatch in op kind OR byte count across participants is a schedule bug
  and raises;
- blocking Send/Recv are rendezvous; Isend is buffered (deposits a snapshot
  of the sender's path profile, sender proceeds); Recv matches Send/Isend
  in post order per (src, dst, tag);
- Wait on an Isend request is an interception no-op (buffered completion).

If no rank can make progress before all programs finish, DeadlockError
reports the blocked ranks and what they wait on.

Hot-path design (see also core.critter):

- **signature interning**: every op resolves its Signature to a dense
  integer id once, cached on the op instance (ops are reused via trace
  replay), so the per-event cost is an attribute read instead of a
  dataclass hash;
- **record/replay split**: rank programs are generators whose op streams do
  not depend on engine feedback (the only value sent back is the opaque
  Isend handle, consumed by Wait), and communication matching in this
  engine is purely structural — independent of sampled times.  The
  interleaved sequence of Critter interceptions is therefore identical
  across iterations of one configuration, so the first execution of a
  program factory runs a *structural recording pass* (generators, matching
  queues, scheduler — no Critter, no RNG) that emits a flat event program;
  every iteration, including the first, then executes that program through
  an interpreter, skipping generators and matching entirely on all
  subsequent iterations (the common case — the tuner runs trials-many
  iterations per configuration).  Runs of consecutive computation kernels
  of one rank are fused into blocks that the profiler can charge in one
  vectorized step.  Pass ``trace_cache=False`` for programs whose op
  stream is nondeterministic or feedback-dependent; that path interleaves
  recording-free matching with scalar interception exactly like the seed
  engine;
- **batched cold runs**: forced (recording/reference) executions sample
  every kernel, so the cold interpreter pre-splits the event program into
  *segments* bounded by RNG-consuming communication events and draws each
  segment's computation-kernel samples in one vectorized call when the
  cost model supports it (``CostModel.batch_info``: lognormal noise with
  the straggler branch off), falling back to per-event scalar draws — the
  same calls in the same order — when it does not.  Charging is batched
  per fused block (``Critter.on_comp_block_cold``) with sequential
  float accumulation, so path metrics, statistics, and the sampler RNG
  stream stay bit-identical to the scalar path;
- **runnable queue**: first-run scheduling pops a (sweep, rank) heap
  instead of scanning all ranks per pass, preserving the exact round-robin
  order of the seed engine (a rank unblocked by a lower-ranked completer
  runs in the same sweep; one unblocked by a higher-ranked completer runs
  in the next), which keeps sampler RNG consumption — and therefore
  results — bit-identical.
"""

from __future__ import annotations

import weakref
from collections import deque
from heapq import heappop, heappush
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.critter import (Critter, IterationReport, W_BHEAD, W_BLOCK,
                                W_CHEAD, W_COLL, W_COMP, W_IMATCH, W_IPOST,
                                W_P2P)
from repro.core.signatures import Signature, comm_sig, comp_sig, p2p_sig
from .comm import World
from .ops import (CS_BLOCK, CS_COLL, CS_COMP, CS_IMATCH, CS_IPOST, CS_P2P,
                  EV_BLOCK, EV_COLL, EV_COMP, EV_IMATCH, EV_IPOST, EV_P2P,
                  KIND_COLL, KIND_COMP, KIND_ISEND, KIND_RECV, KIND_SEND,
                  KIND_WAIT)

RUNNABLE, BLOCKED, DONE = 0, 1, 2


class DeadlockError(RuntimeError):
    pass


class RunResult(IterationReport):

    @classmethod
    def from_report(cls, rep: IterationReport) -> "RunResult":
        return cls(rep.predicted_time, rep.wall_time, rep.crit_comp,
                   rep.crit_comm, rep.measured_time, rep.max_measured_comp,
                   rep.executed, rep.skipped, rep.events)


class _CompBlock:
    """A run of consecutive computation events of one rank, fused at event
    compilation: interned signature ids plus the unique-id/count arrays the
    profiler's vectorized skip path charges in one step."""

    __slots__ = ("sids", "sids_np", "uniq", "counts", "n", "max_sid",
                 "groups")

    def __init__(self, sids: List[int]):
        self.sids = sids
        self.sids_np = np.array(sids, dtype=np.intp)
        self.uniq, self.counts = np.unique(self.sids_np, return_counts=True)
        self.n = len(sids)
        self.max_sid = int(self.sids_np.max())
        # lazy per-unique-sid position lists (cold batched charging)
        self.groups: Optional[List[List[int]]] = None

    def group_indices(self) -> List[List[int]]:
        """Positions of each unique sid's samples within the block, in
        block order (so per-sid Welford updates see samples in the same
        order as per-event updates)."""
        g = self.groups
        if g is None:
            if len(self.uniq) == 1:
                g = [list(range(self.n))]
            else:
                g = [np.nonzero(self.sids_np == u)[0].tolist()
                     for u in self.uniq.tolist()]
            self.groups = g
        return g


# minimum run length worth a vectorized block (below this the fancy-index
# overhead exceeds the per-op savings)
_MIN_BLOCK = 4


class _EventProgram:
    """The flat interception sequence of one configuration run.

    events -- list of opcode tuples (see the EV_*/CS_* constants in .ops)
    n_slots -- number of isend post->match payload slots
    cold -- lazily-built batched cold-run program (_ColdProgram)
    warm -- lazily-built compiled warm program (_WarmProgram)
    """

    __slots__ = ("events", "n_slots", "cold", "warm")

    def __init__(self, events, n_slots):
        self.events = events
        self.n_slots = n_slots
        self.cold: Optional[_ColdProgram] = None
        self.warm: Optional[_WarmProgram] = None


class _WarmProgram:
    """The event program segmented for the compiled selective interpreter
    (``Critter.run_warm``).

    entries -- list of W_* opcode tuples (see core.critter): one entry per
             interception, with each maximal per-rank run of computation
             events between that rank's skip-decision / communication
             boundaries marked by a W_CHEAD / W_BHEAD head entry carrying
             the segment metadata ``(sids, uniq, counts, n_events,
             n_member_entries)``
    n_slots -- isend post->match payload slots (same as the event program)
    max_sid -- highest signature id any entry touches (pre-grow capacity)
    meta -- segmentation statistics for the bench harness / CI gate:
             segment count, fused event count, batch-size distribution
    """

    __slots__ = ("entries", "n_slots", "max_sid", "meta")

    def __init__(self, entries, n_slots, max_sid, meta):
        self.entries = entries
        self.n_slots = n_slots
        self.max_sid = max_sid
        self.meta = meta


class _ColdProgram:
    """The event program re-sliced for batched forced (cold) execution.

    A forced run samples EVERY kernel — computation and communication — in
    step order, so the whole run's draw sequence is known statically:
    ``draw_sigs`` lists the sampled signatures in consumption order (one
    per CS_COMP / CS_COLL / CS_P2P / CS_IMATCH step, ``block.n`` per
    CS_BLOCK step), and the interpreter walks ``steps`` with a running
    cursor into the draw buffer.  When the cost model can batch
    (``batch_info``: lognormal noise, straggler branch off), all draws
    come from ONE vectorized ``standard_normal`` call — bit-equal to the
    scalar stream because ``Generator.normal(0, s)`` is exactly
    ``standard_normal() * s`` and vectorized fills consume the bit stream
    identically to repeated scalar draws; otherwise each step draws through
    the scalar timer at its cursor position, the same calls in the same
    order as the interleaved seed engine.

    steps -- (CS_COMP, rank, sid, sig) | (CS_BLOCK, rank, block, sigs)
             | (CS_IPOST, rank, slot) | (CS_COLL, sid, comm, sig)
             | (CS_P2P, src, dst, sid, sig)
             | (CS_IMATCH, src, dst, sid, slot, sig)
    exec_rows/exec_cols -- the statically-known (rank, sid) pairs executed
             by every sampling step (collectives included), for
             Critter.finish_cold's deferred iter_exec/mean_arr bulk pass
    batch -- lazy cost-model batch support: None until probed, False when
             the timer cannot batch, else (det, sigma) draw-order arrays
    """

    __slots__ = ("steps", "draw_sigs", "n_slots", "max_sid", "exec_rows",
                 "exec_cols", "batch")

    def __init__(self, steps, draw_sigs, n_slots, max_sid, exec_pairs):
        self.steps = steps
        self.draw_sigs = draw_sigs
        self.n_slots = n_slots
        self.max_sid = max_sid
        pairs = sorted(exec_pairs)
        self.exec_rows = np.array([p[0] for p in pairs], dtype=np.intp)
        self.exec_cols = np.array([p[1] for p in pairs], dtype=np.intp)
        self.batch = None


class _CollSite:
    __slots__ = ("op", "nbytes", "arrived", "needed", "sig_id")

    def __init__(self, op, nbytes, needed, sig_id):
        self.op = op
        self.nbytes = nbytes
        self.arrived: List[int] = []
        self.needed = needed
        self.sig_id = sig_id


class Runtime:
    """One World + one Critter profiler + a timing source."""

    def __init__(self, world: World, critter: Critter,
                 timer: Callable[[Signature, np.random.Generator], float],
                 *, seed: int = 0, overhead: float = 1e-6,
                 trace_cache: bool = True, compiled: bool = True):
        self.world = world
        self.critter = critter
        self.timer = timer
        self.overhead = overhead
        self.trace_cache = trace_cache
        # compiled selective replay (Critter.run_warm over the segmented
        # warm program).  Bit-identical to the plain event interpreter;
        # ``compiled=False`` forces the scalar warm path (the bench harness
        # measures the compiled speedup against it)
        self.compiled = compiled
        self._rng = np.random.default_rng(seed)
        self._intern = world.interner.intern
        self._sig_cache: Dict[tuple, int] = {}
        # batched cold-run sampling: available when the timer is a bound
        # method of an object exposing ``batch_info(sigs) -> (det, sigma)
        # | None`` (CostModel); anything else falls back to per-event
        # scalar draws, which preserve the RNG stream by construction
        timer_obj = getattr(timer, "__self__", None)
        self._batch_info = getattr(timer_obj, "batch_info", None)
        # counter-RNG batched sampling (CostModel.sample_block): vectorizes
        # the whole draw sequence even with the straggler branch on — the
        # counter discipline gives every event fixed draw slots, so there
        # is no scalar fallback left to pay
        self._sample_block = getattr(timer_obj, "sample_block", None)
        # program_factory -> per-rank recorded op traces (weak: traces die
        # with the configuration's program factory)
        self._traces = weakref.WeakKeyDictionary()

    # -- signature interning (hot path) --------------------------------------

    def _comp_sid(self, name, params) -> int:
        key = (0, name, params)
        sid = self._sig_cache.get(key)
        if sid is None:
            sid = self._intern(comp_sig(name, *params))
            self._sig_cache[key] = sid
        return sid

    def _coll_sid(self, op, comm, nbytes) -> int:
        key = (1, op, comm.size, comm.stride, nbytes)
        sid = self._sig_cache.get(key)
        if sid is None:
            sid = self._intern(comm_sig(op, nbytes, comm.size, comm.stride))
            self._sig_cache[key] = sid
        return sid

    def _p2p_sid(self, name, nbytes) -> int:
        key = (2, name, nbytes)
        sid = self._sig_cache.get(key)
        if sid is None:
            sid = self._intern(p2p_sig(name, nbytes))
            self._sig_cache[key] = sid
        return sid

    # -- structural recording pass --------------------------------------------

    def _record(self, program_factory) -> list:
        """Run the rank generators to exhaustion, matching communication
        structurally, and record the flat interception sequence WITHOUT
        invoking the Critter protocol or consuming sampler RNG.

        Matching is independent of sampled times, so the recorded program
        replayed through the interpreters produces interceptions (and RNG
        consumption) bit-identical to the historical interleaved pass.  A
        deadlock or collective mismatch therefore raises before any
        profiler state is touched.

        KEEP IN SYNC with ``_run_live``: both implement the same
        structural matching semantics (collective site validation, p2p
        queues, heap sweeps, deadlock reporting); this copy exists so the
        recording pass pays zero interception branches per op.  Any
        change to matching must land in both; tests/test_cold_path.py and
        tests/test_golden_reports.py pin their equivalence."""
        world = self.world
        n = world.size
        gens = [program_factory(r, world) for r in range(n)]
        events: list = []
        append = events.append
        status = [RUNNABLE] * n
        blocked_on: List[Optional[object]] = [None] * n
        coll_sites: Dict[Tuple[int, int], _CollSite] = {}
        coll_counts: Dict[Tuple[int, int], int] = {}
        # send entry: (sender_rank, sig_id, slot_or_None); None = rendezvous
        sends: Dict[tuple, deque] = {}
        recvs: Dict[tuple, deque] = {}
        state = [0, 0, n]        # isend slot counter, next handle, live

        def advance(r, sweep, value=None):
            """Run rank r until it blocks or finishes."""
            gen = gens[r]
            while True:
                try:
                    op = gen.send(value)
                except StopIteration:
                    status[r] = DONE
                    state[2] -= 1
                    return
                value = None
                k = op.KIND
                if k == KIND_COMP:
                    sid = op.sig_id
                    if sid is None:
                        sid = op.sig_id = self._comp_sid(op.name, op.params)
                    append((EV_COMP, r, sid))
                    continue
                if k == KIND_COLL:
                    comm = op.comm
                    key = (comm.id, r)
                    idx = coll_counts.get(key, 0)
                    coll_counts[key] = idx + 1
                    skey = (comm.id, idx)
                    site = coll_sites.get(skey)
                    if site is None:
                        sid = op.sig_id
                        if sid is None:
                            sid = op.sig_id = \
                                self._coll_sid(op.op, comm, op.nbytes)
                        site = _CollSite(op.op, op.nbytes, comm.size, sid)
                        coll_sites[skey] = site
                    elif site.op != op.op:
                        raise RuntimeError(
                            f"collective mismatch on comm {comm.id} site {idx}:"
                            f" {site.op} vs {op.op} (rank {r})")
                    elif site.nbytes != op.nbytes:
                        raise RuntimeError(
                            f"collective byte-count mismatch on comm "
                            f"{comm.id} site {idx} ({site.op}): "
                            f"{site.nbytes}B vs {op.nbytes}B (rank {r})")
                    site.arrived.append(r)
                    if len(site.arrived) < site.needed:
                        status[r] = BLOCKED
                        blocked_on[r] = op
                        return
                    del coll_sites[skey]
                    append((EV_COLL, site.sig_id, comm))
                    for rr in site.arrived:
                        if rr != r:
                            status[rr] = RUNNABLE
                            blocked_on[rr] = None
                            heappush(heap,
                                     (sweep if rr > r else sweep + 1, rr))
                    continue
                if k == KIND_SEND:
                    sid = op.sig_id
                    if sid is None:
                        sid = op.sig_id = self._p2p_sid("send", op.nbytes)
                    pkey = (r, op.dst, op.tag)
                    q = recvs.get(pkey)
                    if q:
                        q.popleft()
                        append((EV_P2P, r, op.dst, sid))
                        dst = op.dst
                        status[dst] = RUNNABLE
                        blocked_on[dst] = None
                        heappush(heap,
                                 (sweep if dst > r else sweep + 1, dst))
                        continue
                    sends.setdefault(pkey, deque()).append((r, sid, None))
                    status[r] = BLOCKED
                    blocked_on[r] = op
                    return
                if k == KIND_RECV:
                    pkey = (op.src, r, op.tag)
                    q = sends.get(pkey)
                    if q:
                        src, sid, slot = q.popleft()
                        if slot is None:   # blocking sender, rendezvous
                            append((EV_P2P, src, r, sid))
                            status[src] = RUNNABLE
                            blocked_on[src] = None
                            heappush(heap,
                                     (sweep if src > r else sweep + 1, src))
                        else:              # buffered isend
                            append((EV_IMATCH, src, r, sid, slot))
                        continue
                    recvs.setdefault(pkey, deque()).append(r)
                    status[r] = BLOCKED
                    blocked_on[r] = op
                    return
                if k == KIND_ISEND:
                    sid = op.sig_id
                    if sid is None:
                        sid = op.sig_id = self._p2p_sid("send", op.nbytes)
                    slot = state[0]
                    state[0] = slot + 1
                    append((EV_IPOST, r, sid, slot))
                    pkey = (r, op.dst, op.tag)
                    q = recvs.get(pkey)
                    if q:
                        rcv = q.popleft()
                        append((EV_IMATCH, r, rcv, sid, slot))
                        status[rcv] = RUNNABLE
                        blocked_on[rcv] = None
                        heappush(heap,
                                 (sweep if rcv > r else sweep + 1, rcv))
                    else:
                        sends.setdefault(pkey, deque()).append((r, sid, slot))
                    state[1] += 1
                    value = state[1]
                    continue
                if k == KIND_WAIT:
                    continue
                raise TypeError(f"rank {r} yielded unknown op {op!r}")

        heap: List[Tuple[int, int]] = [(0, r) for r in range(n)]
        while heap:
            sweep, r = heappop(heap)
            if status[r] == RUNNABLE:
                advance(r, sweep)
        if state[2] > 0:
            blocked = [(r, blocked_on[r]) for r in range(n)
                       if status[r] == BLOCKED]
            if blocked:
                detail = ", ".join(f"rank {r}: {op!r}"
                                   for r, op in blocked[:8])
                raise DeadlockError(
                    f"{len(blocked)} ranks blocked with no progress: "
                    f"{detail}")
        return events

    # -- event-program compilation --------------------------------------------

    @staticmethod
    def _compile_events(events) -> _EventProgram:
        """Fuse runs of consecutive comp events of one rank into blocks.

        Only *globally* consecutive runs are fused — the interleaved order
        of interceptions across ranks (and therefore sampler RNG
        consumption) is preserved exactly."""
        out = []
        run_rank = -1
        run: List[int] = []
        n_slots = 0

        def flush():
            nonlocal run
            if len(run) >= _MIN_BLOCK:
                out.append((EV_BLOCK, run_rank, _CompBlock(run)))
            else:
                out.extend((EV_COMP, run_rank, sid) for sid in run)
            run = []

        for ev in events:
            if ev[0] == EV_COMP:
                if ev[1] != run_rank:
                    if run:
                        flush()
                    run_rank = ev[1]
                run.append(ev[2])
                continue
            if run:
                flush()
                run_rank = -1
            if ev[0] == EV_IPOST:
                n_slots = ev[3] + 1
            out.append(ev)
        if run:
            flush()
        return _EventProgram(out, n_slots)

    def _build_cold(self, prog: _EventProgram) -> _ColdProgram:
        """Flatten the event program into cold steps plus the forced run's
        static draw sequence (see _ColdProgram)."""
        sigs = self.world.interner.sigs
        steps: list = []
        draw_sigs: list = []
        exec_pairs: set = set()
        max_sid = 0
        for ev in prog.events:
            k = ev[0]
            if k == EV_COMP:
                sid = ev[2]
                steps.append((CS_COMP, ev[1], sid, sigs[sid]))
                draw_sigs.append(sigs[sid])
                exec_pairs.add((ev[1], sid))
            elif k == EV_BLOCK:
                block = ev[2]
                bsigs = [sigs[s] for s in block.sids]
                steps.append((CS_BLOCK, ev[1], block, bsigs))
                draw_sigs.extend(bsigs)
                exec_pairs.update((ev[1], s) for s in block.uniq.tolist())
                sid = block.max_sid
            elif k == EV_IPOST:
                sid = ev[2]
                steps.append((CS_IPOST, ev[1], ev[3]))
            elif k == EV_COLL:
                sid = ev[1]
                steps.append((CS_COLL, sid, ev[2], sigs[sid]))
                draw_sigs.append(sigs[sid])
                exec_pairs.update((r, sid) for r in ev[2].ranks)
            elif k == EV_P2P:
                sid = ev[3]
                steps.append((CS_P2P, ev[1], ev[2], sid, sigs[sid]))
                draw_sigs.append(sigs[sid])
                exec_pairs.add((ev[1], sid))
                exec_pairs.add((ev[2], sid))
            else:
                sid = ev[3]
                steps.append((CS_IMATCH, ev[1], ev[2], sid, ev[4],
                              sigs[sid]))
                draw_sigs.append(sigs[sid])
                exec_pairs.add((ev[1], sid))
                exec_pairs.add((ev[2], sid))
            if sid > max_sid:
                max_sid = sid
        return _ColdProgram(steps, draw_sigs, prog.n_slots, max_sid,
                            exec_pairs)

    def _build_warm(self, prog: _EventProgram) -> _WarmProgram:
        """Segment the event program for the compiled selective interpreter.

        Every maximal run of one rank's computation events (plain comps AND
        fused blocks) between two of that rank's *boundaries* — any event
        that touches the rank: a collective it participates in, a p2p it
        sends or receives, an isend post or match — becomes one segment.
        Within a segment no event of any other rank can observe the rank's
        comp-charged state (only boundary events read it), so when every
        kernel in the segment holds a memoized skip verdict the interpreter
        charges the whole segment at the head entry and consumes the member
        entries with a pending counter — the steady-state path that turns
        per-event interpretation into one accumulation loop per segment.
        A guard miss replays the members individually at their original
        positions, so decisions and RNG consumption never reorder."""
        sigs = self.world.interner.sigs
        entries: list = []
        # rank -> [entry indices, sids] of its currently-open comp run
        open_runs: Dict[int, list] = {}
        max_sid = 0
        run_sizes: List[int] = []
        n_comp = n_block = n_coll = n_p2p = n_ipost = n_imatch = 0

        def close(r):
            run = open_runs.pop(r, None)
            if run is None:
                return
            idxs, rsids = run
            if len(idxs) < 2:
                return           # single-entry segment: no head needed
            uniq: Dict[int, int] = {}
            for s in rsids:
                uniq[s] = uniq.get(s, 0) + 1
            meta = (rsids, list(uniq), list(uniq.values()), len(rsids),
                    len(idxs) - 1)
            head = entries[idxs[0]]
            if head[0] == W_COMP:
                entries[idxs[0]] = (W_CHEAD, head[1], head[2], meta)
            else:
                entries[idxs[0]] = (W_BHEAD, head[1], head[2], head[3],
                                    head[4], head[5], meta)
            run_sizes.append(len(rsids))

        for ev in prog.events:
            k = ev[0]
            if k == EV_COMP:
                r = ev[1]
                sid = ev[2]
                if sid > max_sid:
                    max_sid = sid
                run = open_runs.get(r)
                if run is None:
                    run = open_runs[r] = [[], []]
                run[0].append(len(entries))
                run[1].append(sid)
                entries.append((W_COMP, r, sid))
                n_comp += 1
            elif k == EV_BLOCK:
                r = ev[1]
                block = ev[2]
                if block.max_sid > max_sid:
                    max_sid = block.max_sid
                run = open_runs.get(r)
                if run is None:
                    run = open_runs[r] = [[], []]
                run[0].append(len(entries))
                run[1].extend(block.sids)
                entries.append((W_BLOCK, r, block.sids, block.uniq.tolist(),
                                block.counts.tolist(), block.n))
                n_block += 1
            elif k == EV_IPOST:
                r = ev[1]
                sid = ev[2]
                if sid > max_sid:
                    max_sid = sid
                close(r)
                entries.append((W_IPOST, r, sid, ev[3]))
                n_ipost += 1
            elif k == EV_COLL:
                sid = ev[1]
                comm = ev[2]
                if sid > max_sid:
                    max_sid = sid
                for r in comm.ranks:
                    close(r)
                entries.append((W_COLL, sid, comm, comm.ranks, sigs[sid]))
                n_coll += 1
            elif k == EV_P2P:
                sid = ev[3]
                if sid > max_sid:
                    max_sid = sid
                close(ev[1])
                close(ev[2])
                entries.append((W_P2P, ev[1], ev[2], sid, sigs[sid]))
                n_p2p += 1
            else:                               # EV_IMATCH
                sid = ev[3]
                if sid > max_sid:
                    max_sid = sid
                close(ev[1])
                close(ev[2])
                entries.append((W_IMATCH, ev[1], ev[2], sid, ev[4],
                                sigs[sid]))
                n_imatch += 1
        for r in list(open_runs):
            close(r)

        fused = sum(run_sizes)
        meta = {
            "entries": len(entries),
            "segments": len(run_sizes),
            "fused_events": fused,
            "max_batch": max(run_sizes) if run_sizes else 0,
            "mean_batch": round(fused / len(run_sizes), 2)
            if run_sizes else 0.0,
            "comp_entries": n_comp,
            "block_entries": n_block,
            "coll_entries": n_coll,
            "p2p_entries": n_p2p,
            "ipost_entries": n_ipost,
            "imatch_entries": n_imatch,
        }
        return _WarmProgram(entries, prog.n_slots, max_sid, meta)

    def warm_meta(self, program_factory) -> dict:
        """Segmentation statistics of the compiled warm program for
        ``program_factory`` (recording + compiling it if needed) — consumed
        by the bench harness and the CI engine gate."""
        prog = self._get_program(program_factory)
        warm = prog.warm
        if warm is None:
            warm = prog.warm = self._build_warm(prog)
        return dict(warm.meta)

    # -- interpreters ---------------------------------------------------------

    def _run_events(self, prog: _EventProgram, sampler) -> None:
        """Execute a compiled event program: the scheduler, matching queues
        and generators are gone; only the interception sequence remains."""
        critter = self.critter
        overhead = self.overhead
        on_comp = critter.on_comp
        on_comp_block = critter.on_comp_block
        on_coll = critter.on_coll
        on_p2p = critter.on_p2p
        on_isend_match = critter.on_isend_match
        p2p_vote = critter.p2p_vote
        isend_snapshot = critter.isend_snapshot
        slots: List[Optional[tuple]] = [None] * prog.n_slots
        for ev in prog.events:
            k = ev[0]
            if k == EV_COMP:
                on_comp(ev[1], ev[2], sampler)
            elif k == EV_IPOST:
                slots[ev[3]] = (p2p_vote(ev[1], ev[2]),
                                isend_snapshot(ev[1]))
            elif k == EV_IMATCH:
                vote, snapshot = slots[ev[4]]
                on_isend_match(ev[1], ev[2], ev[3], sampler, vote, snapshot,
                               overhead)
            elif k == EV_P2P:
                on_p2p(ev[1], ev[2], ev[3], sampler,
                       p2p_vote(ev[1], ev[3]), overhead)
            elif k == EV_BLOCK:
                on_comp_block(ev[1], ev[2], sampler)
            else:
                on_coll(ev[1], ev[2], sampler, overhead)

    def _run_events_cold(self, cold: _ColdProgram) -> None:
        """Execute a cold program under force_execute.

        When the cost model batches, every sample of the run — computation
        AND communication — is drawn up front in one vectorized call and
        each step consumes its precomputed time at a running cursor;
        otherwise each sampling step draws through the scalar timer at its
        own position, which is the same call sequence as the interleaved
        seed engine.  All interceptions go through the force-specialized
        ``*_cold`` Critter methods, which operate on list-backed per-rank
        scalar mirrors for the duration of the run (``begin_cold`` ..
        ``finish_cold``) — NumPy scalar indexing dominates the p2p-heavy
        hot path otherwise, particularly under the scalar-fallback draws
        of straggler-enabled cost models."""
        critter = self.critter
        critter.state.ensure(cold.max_sid)
        critter.begin_cold()
        rng = self._rng
        timer = self.timer
        overhead = self.overhead
        on_comp_cold = critter.on_comp_cold
        on_comp_block_cold = critter.on_comp_block_cold
        on_coll_cold = critter.on_coll_cold
        on_p2p_cold = critter.on_p2p_cold
        on_isend_match_cold = critter.on_isend_match_cold
        isend_snapshot_cold = critter.isend_snapshot_cold
        slots: List[Optional[tuple]] = [None] * cold.n_slots

        ts = None
        if self._sample_block is not None and cold.draw_sigs:
            # counter-RNG batching: the whole draw sequence — stragglers
            # included — in one vectorized pass (no cache: the draw cursor
            # advances per run).  None when the model is not in counter
            # mode; fall through to the legacy batch/scalar paths.
            drawn = self._sample_block(cold.draw_sigs)
            if drawn is not None:
                ts = drawn.tolist()
        if ts is None:
            info = cold.batch
            if info is None:
                info = False
                if self._batch_info is not None and cold.draw_sigs:
                    bi = self._batch_info(cold.draw_sigs)
                    if bi is not None:
                        info = bi
                cold.batch = info
            if info is not False:
                det, sigma = info
                ts = (det * np.exp(
                    sigma * rng.standard_normal(len(det)))).tolist()
        cur = 0

        for st in cold.steps:
            k = st[0]
            if k == CS_COMP:
                if ts is None:
                    t = timer(st[3], rng)
                else:
                    t = ts[cur]
                    cur += 1
                on_comp_cold(st[1], st[2], t)
            elif k == CS_IPOST:
                slots[st[2]] = isend_snapshot_cold(st[1])
            elif k == CS_IMATCH:
                if ts is None:
                    t = timer(st[5], rng)
                else:
                    t = ts[cur]
                    cur += 1
                on_isend_match_cold(st[1], st[2], st[3], t, slots[st[4]],
                                    overhead)
            elif k == CS_BLOCK:
                block = st[2]
                if ts is None:
                    tsl = [timer(sig, rng) for sig in st[3]]
                else:
                    end = cur + block.n
                    tsl = ts[cur:end]
                    cur = end
                on_comp_block_cold(st[1], block, tsl)
            elif k == CS_P2P:
                if ts is None:
                    t = timer(st[4], rng)
                else:
                    t = ts[cur]
                    cur += 1
                on_p2p_cold(st[1], st[2], st[3], t, overhead)
            else:
                if ts is None:
                    t = timer(st[3], rng)
                else:
                    t = ts[cur]
                    cur += 1
                on_coll_cold(st[1], st[2], t, overhead)
        critter.finish_cold(cold.exec_rows, cold.exec_cols)

    # -- main loop ------------------------------------------------------------

    def run(self, program_factory, *, force_execute: bool = False,
            update_stats: bool = True) -> RunResult:
        critter = self.critter
        critter.begin_iteration(force_execute=force_execute,
                                update_stats=update_stats)
        rng = self._rng
        timer = self.timer
        sampler = lambda sig: timer(sig, rng)  # noqa: E731

        if not self.trace_cache:
            self._run_live(program_factory, sampler)
            return RunResult.from_report(critter.report())

        prog = self._get_program(program_factory)
        if force_execute:
            cold = prog.cold
            if cold is None:
                cold = prog.cold = self._build_cold(prog)
            self._run_events_cold(cold)
        elif self.compiled and critter.warm_eligible():
            warm = prog.warm
            if warm is None:
                warm = prog.warm = self._build_warm(prog)
            critter.run_warm(warm, sampler, self.overhead)
        else:
            self._run_events(prog, sampler)
        return RunResult.from_report(critter.report())

    def _get_program(self, program_factory) -> _EventProgram:
        try:
            prog = self._traces.get(program_factory)
        except TypeError:            # unhashable/unweakrefable factory
            prog = None
        if prog is None:
            prog = self._compile_events(self._record(program_factory))
            try:
                self._traces[program_factory] = prog
            except TypeError:
                pass
        return prog

    def _run_live(self, program_factory, sampler) -> None:
        """The seed engine's interleaved pass (``trace_cache=False``):
        generators, structural matching, and scalar Critter interception in
        one loop, nothing recorded.  Kept for programs whose op streams are
        nondeterministic or feedback-dependent — and as the reference
        implementation the recorded paths are pinned against
        (tests/test_cold_path.py, tests/test_golden_reports.py).

        KEEP IN SYNC with ``_record``: same structural matching semantics,
        see the note there."""
        world = self.world
        critter = self.critter
        overhead = self.overhead
        n = world.size
        gens = [program_factory(r, world) for r in range(n)]
        isend_slots = [0]
        status = [RUNNABLE] * n
        blocked_on: List[Optional[object]] = [None] * n
        # collective sites: (comm.id, site_index) -> _CollSite
        coll_sites: Dict[Tuple[int, int], _CollSite] = {}
        coll_counts: Dict[Tuple[int, int], int] = {}
        # p2p queues: (src, dst, tag) -> deque of entries
        # send entry: (sender_rank, sig_id, vote, snapshot_or_None, slot)
        sends: Dict[tuple, deque] = {}
        recvs: Dict[tuple, deque] = {}
        next_handle = [0]
        # runnable queue: (sweep, rank) min-heap reproducing the seed
        # engine's sorted round-robin sweeps exactly
        heap: List[Tuple[int, int]] = [(0, r) for r in range(n)]

        live = [n]

        def advance(r, sweep, value=None):
            """Run rank r until it blocks or finishes."""
            gen = gens[r]
            while True:
                try:
                    op = gen.send(value)
                except StopIteration:
                    status[r] = DONE
                    live[0] -= 1
                    return
                value = None
                k = op.KIND
                if k == KIND_COMP:
                    sid = op.sig_id
                    if sid is None:
                        sid = op.sig_id = self._comp_sid(op.name, op.params)
                    critter.on_comp(r, sid, sampler)
                    continue
                if k == KIND_COLL:
                    comm = op.comm
                    key = (comm.id, r)
                    idx = coll_counts.get(key, 0)
                    coll_counts[key] = idx + 1
                    skey = (comm.id, idx)
                    site = coll_sites.get(skey)
                    if site is None:
                        sid = op.sig_id
                        if sid is None:
                            sid = op.sig_id = \
                                self._coll_sid(op.op, comm, op.nbytes)
                        site = _CollSite(op.op, op.nbytes, comm.size, sid)
                        coll_sites[skey] = site
                    elif site.op != op.op:
                        raise RuntimeError(
                            f"collective mismatch on comm {comm.id} site {idx}:"
                            f" {site.op} vs {op.op} (rank {r})")
                    elif site.nbytes != op.nbytes:
                        raise RuntimeError(
                            f"collective byte-count mismatch on comm "
                            f"{comm.id} site {idx} ({site.op}): "
                            f"{site.nbytes}B vs {op.nbytes}B (rank {r})")
                    site.arrived.append(r)
                    if len(site.arrived) < site.needed:
                        status[r] = BLOCKED
                        blocked_on[r] = op
                        return
                    # complete the collective
                    del coll_sites[skey]
                    critter.on_coll(site.sig_id, comm, sampler, overhead)
                    for rr in site.arrived:
                        if rr != r:
                            status[rr] = RUNNABLE
                            blocked_on[rr] = None
                            heappush(heap,
                                     (sweep if rr > r else sweep + 1, rr))
                    continue
                if k == KIND_SEND:
                    sid = op.sig_id
                    if sid is None:
                        sid = op.sig_id = self._p2p_sid("send", op.nbytes)
                    pkey = (r, op.dst, op.tag)
                    q = recvs.get(pkey)
                    if q:
                        q.popleft()
                        vote = critter.p2p_vote(r, sid)
                        critter.on_p2p(r, op.dst, sid, sampler, vote,
                                       overhead)
                        dst = op.dst
                        status[dst] = RUNNABLE
                        blocked_on[dst] = None
                        heappush(heap,
                                 (sweep if dst > r else sweep + 1, dst))
                        continue
                    sends.setdefault(pkey, deque()).append(
                        (r, sid, None, None, 0))
                    status[r] = BLOCKED
                    blocked_on[r] = op
                    return
                if k == KIND_RECV:
                    pkey = (op.src, r, op.tag)
                    q = sends.get(pkey)
                    if q:
                        src, sid, vote, snapshot, slot = q.popleft()
                        if snapshot is None:   # blocking sender, rendezvous
                            vote = critter.p2p_vote(src, sid)
                            critter.on_p2p(src, r, sid, sampler, vote,
                                           overhead)
                            status[src] = RUNNABLE
                            blocked_on[src] = None
                            heappush(heap,
                                     (sweep if src > r else sweep + 1, src))
                        else:                  # buffered isend
                            critter.on_isend_match(src, r, sid, sampler,
                                                   vote, snapshot, overhead)
                        continue
                    recvs.setdefault(pkey, deque()).append(r)
                    status[r] = BLOCKED
                    blocked_on[r] = op
                    return
                if k == KIND_ISEND:
                    sid = op.sig_id
                    if sid is None:
                        sid = op.sig_id = self._p2p_sid("send", op.nbytes)
                    slot = isend_slots[0]
                    isend_slots[0] = slot + 1
                    vote = critter.p2p_vote(r, sid)
                    snapshot = critter.isend_snapshot(r)
                    pkey = (r, op.dst, op.tag)
                    q = recvs.get(pkey)
                    if q:
                        rcv = q.popleft()
                        critter.on_isend_match(r, rcv, sid, sampler, vote,
                                               snapshot, overhead)
                        status[rcv] = RUNNABLE
                        blocked_on[rcv] = None
                        heappush(heap,
                                 (sweep if rcv > r else sweep + 1, rcv))
                    else:
                        sends.setdefault(pkey, deque()).append(
                            (r, sid, vote, snapshot, slot))
                    next_handle[0] += 1
                    value = next_handle[0]
                    continue
                if k == KIND_WAIT:
                    # buffered isend: completion is free; the interception
                    # point exists but statistics were updated at match time
                    continue
                raise TypeError(f"rank {r} yielded unknown op {op!r}")

        while heap:
            sweep, r = heappop(heap)
            if status[r] == RUNNABLE:
                advance(r, sweep)
        if live[0] > 0:
            blocked = [(r, blocked_on[r]) for r in range(n)
                       if status[r] == BLOCKED]
            if blocked:
                detail = ", ".join(f"rank {r}: {op!r}"
                                   for r, op in blocked[:8])
                raise DeadlockError(
                    f"{len(blocked)} ranks blocked with no progress: "
                    f"{detail}")
