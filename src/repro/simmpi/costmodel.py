"""Stochastic kernel cost models.

The paper measures real wall-clock on Stampede2 (KNL + Omni-Path) and
observes high run-to-run variability.  On this CPU-only container we provide
two timing sources:

- **modeled** (this module): a calibrated stochastic cost model — a
  deterministic roofline/alpha-beta part plus multiplicative lognormal noise
  and a persistent per-(signature, allocation) bias.  The bias term models
  the paper's observation that distinct node allocations give systematically
  different timings (they run every experiment on two allocations); the
  lognormal term models run-to-run noise (network/memory contention).
- **measured** (linalg.blas): real wall-clock of local jnp BLAS kernels at
  laptop scale, used by the measured-mode demo and tests.

Both plug into the Runtime through the same ``sample(sig, rng)`` interface.
"""

from __future__ import annotations

import math
import zlib
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.core.signatures import Signature, flops_of, bytes_of
from repro.core.stats import norm_ppf


# -- counter-based (Philox-style) draw discipline -----------------------------
#
# splitmix64 finalizer constants: the i-th draw of a model keyed by ``key``
# is ``mix64(key + (i + 1) * GAMMA)``, so any contiguous run of draw slots
# can be generated as one vectorized pass over ``arange`` — there is no
# sequential generator state to thread through, only the cursor
# ``draw_index``.  That is what lets a straggler-enabled cost model batch
# its mixed normal/uniform draws per segment: each event owns THREE fixed
# counter slots (normal, straggler gate, straggler scale), consumed
# positionally whether or not the straggler branch fires.
_MIX_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_MIX_M1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX_M2 = np.uint64(0x94D049BB133111EB)
_U64_TO_UNIT = 2.0 ** -53      # (z >> 11) + 0.5 in (0, 1), never 0 or 1


def counter_uniforms(key: np.uint64, start: int, n: int) -> np.ndarray:
    """Uniform(0,1) deviates for counter slots [start, start + n)."""
    with np.errstate(over="ignore"):
        z = (np.arange(start + 1, start + n + 1, dtype=np.uint64)
             * _MIX_GAMMA + key)
        z ^= z >> np.uint64(30)
        z *= _MIX_M1
        z ^= z >> np.uint64(27)
        z *= _MIX_M2
        z ^= z >> np.uint64(31)
    return ((z >> np.uint64(11)).astype(np.float64) + 0.5) * _U64_TO_UNIT


@dataclass(frozen=True)
class MachineSpec:
    """Per-node compute + interconnect constants."""

    name: str
    # compute
    peak_flops: float          # attainable flop/s per rank (not marketing peak)
    mem_bw: float              # bytes/s per rank
    comp_latency: float        # fixed per-kernel invocation overhead (s)
    # network (alpha-beta, per message)
    net_alpha: float           # latency per message (s)
    net_beta: float            # seconds per byte (1/injection bandwidth)


# Stampede2: KNL ~3 Tflop/s marketing per node / 64 ranks used per node and
# realistic BLAS efficiency => ~20 Gflop/s per rank; OPA 12.5 GB/s injection
# shared per node => ~0.8 GB/s per rank sustained.
KNL_STAMPEDE2 = MachineSpec(
    name="knl-stampede2",
    peak_flops=20e9,
    mem_bw=6e9,
    comp_latency=2e-6,
    net_alpha=5e-6,
    net_beta=1.0 / 0.8e9,
)

# TPU v5e chip: 197 Tflop/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
TPU_V5E = MachineSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    mem_bw=819e9,
    comp_latency=2e-6,
    net_alpha=1e-6,
    net_beta=1.0 / 50e9,
)


class CostModel:
    """time(sig) = deterministic(sig) * bias(sig, allocation) * lognormal(sigma)

    - deterministic compute: max(flops/peak, bytes/mem_bw) + latency
    - deterministic collective: tree/ring alpha-beta terms by op kind
    - ``allocation`` reseeds the persistent bias field — the paper's "two
      distinct node allocations".
    - a small straggler probability injects heavy-tail spikes (network/OS
      noise), which is what makes tight confidence intervals *earned* rather
      than automatic.
    """

    def __init__(self, spec: MachineSpec, *, allocation: int = 0,
                 noise: float = 0.08, comm_noise: float = 0.18,
                 bias_sigma: float = 0.06, straggler_p: float = 0.002,
                 straggler_scale: float = 4.0, seed: int = 0,
                 counter_rng: bool = False):
        self.spec = spec
        self.noise = noise
        self.comm_noise = comm_noise
        self.bias_sigma = bias_sigma
        self.straggler_p = straggler_p
        self.straggler_scale = straggler_scale
        self._bias_seed = (seed * 1_000_003 + allocation * 7919) & 0xFFFFFFFF
        self._bias: Dict[Signature, float] = {}
        # deterministic-part cache: base_time(sig) * bias(sig) per signature
        # (both factors are pure in sig), so the per-sample cost is one dict
        # lookup plus the stochastic draw
        self._det: Dict[Signature, float] = {}
        # counter-based draw discipline (opt-in: the legacy sequential
        # Generator stream keeps every committed golden/report valid).
        # ``draw_index`` is the public RNG-stream cursor — the counter-mode
        # analogue of Generator.bit_generator.state, pinned by the
        # bit-identity gates.
        self.counter_rng = bool(counter_rng)
        self.draw_index = 0
        with np.errstate(over="ignore"):
            self._ctr_key = (np.uint64(seed & 0xFFFFFFFFFFFFFFFF)
                             * np.uint64(0x2545F4914F6CDD1D)
                             + np.uint64((allocation * 7919 + 1)
                                         & 0xFFFFFFFFFFFFFFFF))

    # -- deterministic part --------------------------------------------------

    def base_time(self, sig: Signature) -> float:
        if sig.kind == "comp":
            f = sig.flops if hasattr(sig, "flops") else None
            fl = flops_of(sig)
            by = bytes_of(sig)
            return (max(fl / self.spec.peak_flops, by / self.spec.mem_bw)
                    + self.spec.comp_latency)
        # communication: params = (nbytes, comm_size, comm_stride)
        nbytes, p = float(sig.params[0]), max(int(sig.params[1]), 2)
        a, b = self.spec.net_alpha, self.spec.net_beta
        lg = math.log2(p)
        op = sig.name
        if op in ("send", "recv", "isend", "sendrecv"):
            return a + nbytes * b
        if op == "bcast":
            return lg * a + 2.0 * nbytes * b          # scatter+allgather
        if op in ("reduce", "scatter", "gather"):
            return lg * a + nbytes * b
        if op == "allreduce":
            return 2 * lg * a + 2.0 * nbytes * b      # RS + AG ring
        if op == "allgather":
            return lg * a + nbytes * b * (p - 1) / p * 2
        if op == "alltoall":
            return (p - 1) * a + nbytes * b
        if op == "barrier":
            return 2 * lg * a
        return a + nbytes * b

    # -- stochastic part ------------------------------------------------------

    def _bias_of(self, sig: Signature) -> float:
        v = self._bias.get(sig)
        if v is None:
            # crc32 of the stable string form, NOT hash(): the builtin str
            # hash is PYTHONHASHSEED-randomized per interpreter, which
            # would make the bias field differ across processes and break
            # checkpoint-resumed studies (repro.api session journals) and
            # any cross-process reproduction of a sweep
            h = (zlib.crc32(str(sig).encode())
                 ^ self._bias_seed) & 0xFFFFFFFF
            rng = np.random.default_rng(h)
            v = float(np.exp(rng.normal(0.0, self.bias_sigma)))
            self._bias[sig] = v
        return v

    def batch_info(self, sigs):
        """Vectorized-draw support for the engine's batched cold path.

        Returns ``(det, sigma)`` — draw-order arrays of the per-signature
        deterministic parts and lognormal sigmas — when a batch of
        ``sigs`` can be sampled as ``det * exp(sigma * standard_normal(n))``
        with the exact RNG stream of per-event ``sample`` calls:
        ``Generator.normal(0, s)`` is bitwise ``standard_normal() * s``
        and vectorized fills consume the bit stream identically to
        repeated scalar draws, so this holds whenever every per-event draw
        is the single normal — i.e. the straggler branch is off.  With
        stragglers on (each event draws normal + uniform(s), a
        data-dependent interleaving no vector call reproduces) returns
        ``None`` and the engine falls back to per-event scalar ``sample``
        calls, which preserve the stream by construction.  Counter-mode
        models return ``None`` here too: their stream lives on the
        ``draw_index`` cursor, and the engine batches them through
        ``sample_block`` instead (which handles stragglers)."""
        if self.counter_rng or self.straggler_p > 0 or not sigs:
            return None
        det_cache = self._det
        n = len(sigs)
        det = np.empty(n)
        sigma = np.empty(n)
        comm_noise, noise = self.comm_noise, self.noise
        for i, sig in enumerate(sigs):
            d = det_cache.get(sig)
            if d is None:
                d = self.base_time(sig) * self._bias_of(sig)
                det_cache[sig] = d
            det[i] = d
            sigma[i] = comm_noise if sig.kind == "comm" else noise
        return det, sigma

    def sample(self, sig: Signature, rng: np.random.Generator) -> float:
        det = self._det.get(sig)
        if det is None:
            det = self.base_time(sig) * self._bias_of(sig)
            self._det[sig] = det
        sigma = self.comm_noise if sig.kind == "comm" else self.noise
        if self.counter_rng:
            # counter discipline: 3 fixed slots per event; the scalar path
            # computes through the SAME vectorized ufuncs (on length-1
            # arrays) as sample_block, so a segment drawn in one pass is
            # bitwise identical to per-event draws
            i = self.draw_index
            self.draw_index = i + 3
            u = counter_uniforms(self._ctr_key, i, 3)
            t = det * float(np.exp(sigma * norm_ppf(u[0:1])[0]))
            if self.straggler_p > 0 and u[1] < self.straggler_p:
                t *= 1.0 + float(u[2]) * self.straggler_scale
            return t
        t = det * float(np.exp(rng.normal(0.0, sigma)))
        if self.straggler_p > 0 and rng.random() < self.straggler_p:
            t *= 1.0 + rng.random() * self.straggler_scale
        return t

    def sample_block(self, sigs) -> Optional[np.ndarray]:
        """Draw one time per signature in a single vectorized pass.

        Only available in counter mode (returns ``None`` otherwise, and the
        engine falls back to ``batch_info`` / per-event ``sample``).  Unlike
        ``batch_info`` this handles straggler-enabled models too: every
        event owns 3 positional counter slots regardless of whether its
        straggler branch fires, so the block draw consumes exactly the
        counters the equivalent per-event ``sample`` calls would — same
        cursor advance, bitwise-identical times."""
        if not self.counter_rng or not sigs:
            return None
        det_cache = self._det
        n = len(sigs)
        det = np.empty(n)
        sigma = np.empty(n)
        comm_noise, noise = self.comm_noise, self.noise
        for i, sig in enumerate(sigs):
            d = det_cache.get(sig)
            if d is None:
                d = self.base_time(sig) * self._bias_of(sig)
                det_cache[sig] = d
            det[i] = d
            sigma[i] = comm_noise if sig.kind == "comm" else noise
        i = self.draw_index
        self.draw_index = i + 3 * n
        u = counter_uniforms(self._ctr_key, i, 3 * n).reshape(n, 3)
        t = det * np.exp(sigma * norm_ppf(u[:, 0]))
        p = self.straggler_p
        if p > 0:
            mask = u[:, 1] < p
            if mask.any():
                t[mask] *= 1.0 + u[mask, 2] * self.straggler_scale
        return t
