"""Stochastic kernel cost models.

The paper measures real wall-clock on Stampede2 (KNL + Omni-Path) and
observes high run-to-run variability.  On this CPU-only container we provide
two timing sources:

- **modeled** (this module): a calibrated stochastic cost model — a
  deterministic roofline/alpha-beta part plus multiplicative lognormal noise
  and a persistent per-(signature, allocation) bias.  The bias term models
  the paper's observation that distinct node allocations give systematically
  different timings (they run every experiment on two allocations); the
  lognormal term models run-to-run noise (network/memory contention).
- **measured** (linalg.blas): real wall-clock of local jnp BLAS kernels at
  laptop scale, used by the measured-mode demo and tests.

Both plug into the Runtime through the same ``sample(sig, rng)`` interface.
"""

from __future__ import annotations

import math
import zlib
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.core.signatures import Signature, flops_of, bytes_of


@dataclass(frozen=True)
class MachineSpec:
    """Per-node compute + interconnect constants."""

    name: str
    # compute
    peak_flops: float          # attainable flop/s per rank (not marketing peak)
    mem_bw: float              # bytes/s per rank
    comp_latency: float        # fixed per-kernel invocation overhead (s)
    # network (alpha-beta, per message)
    net_alpha: float           # latency per message (s)
    net_beta: float            # seconds per byte (1/injection bandwidth)


# Stampede2: KNL ~3 Tflop/s marketing per node / 64 ranks used per node and
# realistic BLAS efficiency => ~20 Gflop/s per rank; OPA 12.5 GB/s injection
# shared per node => ~0.8 GB/s per rank sustained.
KNL_STAMPEDE2 = MachineSpec(
    name="knl-stampede2",
    peak_flops=20e9,
    mem_bw=6e9,
    comp_latency=2e-6,
    net_alpha=5e-6,
    net_beta=1.0 / 0.8e9,
)

# TPU v5e chip: 197 Tflop/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
TPU_V5E = MachineSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    mem_bw=819e9,
    comp_latency=2e-6,
    net_alpha=1e-6,
    net_beta=1.0 / 50e9,
)


class CostModel:
    """time(sig) = deterministic(sig) * bias(sig, allocation) * lognormal(sigma)

    - deterministic compute: max(flops/peak, bytes/mem_bw) + latency
    - deterministic collective: tree/ring alpha-beta terms by op kind
    - ``allocation`` reseeds the persistent bias field — the paper's "two
      distinct node allocations".
    - a small straggler probability injects heavy-tail spikes (network/OS
      noise), which is what makes tight confidence intervals *earned* rather
      than automatic.
    """

    def __init__(self, spec: MachineSpec, *, allocation: int = 0,
                 noise: float = 0.08, comm_noise: float = 0.18,
                 bias_sigma: float = 0.06, straggler_p: float = 0.002,
                 straggler_scale: float = 4.0, seed: int = 0):
        self.spec = spec
        self.noise = noise
        self.comm_noise = comm_noise
        self.bias_sigma = bias_sigma
        self.straggler_p = straggler_p
        self.straggler_scale = straggler_scale
        self._bias_seed = (seed * 1_000_003 + allocation * 7919) & 0xFFFFFFFF
        self._bias: Dict[Signature, float] = {}
        # deterministic-part cache: base_time(sig) * bias(sig) per signature
        # (both factors are pure in sig), so the per-sample cost is one dict
        # lookup plus the stochastic draw
        self._det: Dict[Signature, float] = {}

    # -- deterministic part --------------------------------------------------

    def base_time(self, sig: Signature) -> float:
        if sig.kind == "comp":
            f = sig.flops if hasattr(sig, "flops") else None
            fl = flops_of(sig)
            by = bytes_of(sig)
            return (max(fl / self.spec.peak_flops, by / self.spec.mem_bw)
                    + self.spec.comp_latency)
        # communication: params = (nbytes, comm_size, comm_stride)
        nbytes, p = float(sig.params[0]), max(int(sig.params[1]), 2)
        a, b = self.spec.net_alpha, self.spec.net_beta
        lg = math.log2(p)
        op = sig.name
        if op in ("send", "recv", "isend", "sendrecv"):
            return a + nbytes * b
        if op == "bcast":
            return lg * a + 2.0 * nbytes * b          # scatter+allgather
        if op in ("reduce", "scatter", "gather"):
            return lg * a + nbytes * b
        if op == "allreduce":
            return 2 * lg * a + 2.0 * nbytes * b      # RS + AG ring
        if op == "allgather":
            return lg * a + nbytes * b * (p - 1) / p * 2
        if op == "alltoall":
            return (p - 1) * a + nbytes * b
        if op == "barrier":
            return 2 * lg * a
        return a + nbytes * b

    # -- stochastic part ------------------------------------------------------

    def _bias_of(self, sig: Signature) -> float:
        v = self._bias.get(sig)
        if v is None:
            # crc32 of the stable string form, NOT hash(): the builtin str
            # hash is PYTHONHASHSEED-randomized per interpreter, which
            # would make the bias field differ across processes and break
            # checkpoint-resumed studies (repro.api session journals) and
            # any cross-process reproduction of a sweep
            h = (zlib.crc32(str(sig).encode())
                 ^ self._bias_seed) & 0xFFFFFFFF
            rng = np.random.default_rng(h)
            v = float(np.exp(rng.normal(0.0, self.bias_sigma)))
            self._bias[sig] = v
        return v

    def batch_info(self, sigs):
        """Vectorized-draw support for the engine's batched cold path.

        Returns ``(det, sigma)`` — draw-order arrays of the per-signature
        deterministic parts and lognormal sigmas — when a batch of
        ``sigs`` can be sampled as ``det * exp(sigma * standard_normal(n))``
        with the exact RNG stream of per-event ``sample`` calls:
        ``Generator.normal(0, s)`` is bitwise ``standard_normal() * s``
        and vectorized fills consume the bit stream identically to
        repeated scalar draws, so this holds whenever every per-event draw
        is the single normal — i.e. the straggler branch is off.  With
        stragglers on (each event draws normal + uniform(s), a
        data-dependent interleaving no vector call reproduces) returns
        ``None`` and the engine falls back to per-event scalar ``sample``
        calls, which preserve the stream by construction."""
        if self.straggler_p > 0 or not sigs:
            return None
        det_cache = self._det
        n = len(sigs)
        det = np.empty(n)
        sigma = np.empty(n)
        comm_noise, noise = self.comm_noise, self.noise
        for i, sig in enumerate(sigs):
            d = det_cache.get(sig)
            if d is None:
                d = self.base_time(sig) * self._bias_of(sig)
                det_cache[sig] = d
            det[i] = d
            sigma[i] = comm_noise if sig.kind == "comm" else noise
        return det, sigma

    def sample(self, sig: Signature, rng: np.random.Generator) -> float:
        det = self._det.get(sig)
        if det is None:
            det = self.base_time(sig) * self._bias_of(sig)
            self._det[sig] = det
        sigma = self.comm_noise if sig.kind == "comm" else self.noise
        t = det * float(np.exp(rng.normal(0.0, sigma)))
        if self.straggler_p > 0 and rng.random() < self.straggler_p:
            t *= 1.0 + rng.random() * self.straggler_scale
        return t
