"""simmpi — a discrete-event, virtual-rank MPI runtime.

The paper's mechanism (Figure 2) is a PMPI interposer: every MPI call is
intercepted, an internal message carrying ``(exec_time, keys, freqs,
execute)`` is exchanged among the participants, the longest sub-critical
path wins, and the *user* communication is then executed selectively.

There is no PMPI on TPU and JAX programs are compiled SPMD programs, so we
re-host the identical protocol inside a discrete-event simulator: each
virtual rank runs a Python generator program that yields computation and
communication kernels; the runtime matches communications, advances
per-rank clocks, and invokes the Critter interception logic at exactly the
points the real tool would.  The update rules executed at each interception
are those of Figure 2, verbatim (max-path adoption, OR'd execute votes,
winner's kernel frequencies adopted).
"""

from .ops import Comp, Coll, Send, Recv, Isend, Wait, Barrier
from .comm import Comm, World
from .costmodel import CostModel, MachineSpec, KNL_STAMPEDE2, TPU_V5E
from .runtime import Runtime, RunResult, DeadlockError

__all__ = [
    "Comp", "Coll", "Send", "Recv", "Isend", "Wait", "Barrier",
    "Comm", "World",
    "CostModel", "MachineSpec", "KNL_STAMPEDE2", "TPU_V5E",
    "Runtime", "RunResult", "DeadlockError",
]
