"""Version compatibility shims for jax.

The repo targets the modern jax sharding API (``jax.sharding.AxisType``,
``jax.make_mesh(..., axis_types=...)``, ``jax.sharding.get_abstract_mesh``),
but must also run on jax 0.4.x where none of these exist.  Import the
symbols from here instead of from jax directly:

    from repro.compat import AxisType, make_mesh, get_abstract_mesh

On old jax, ``AxisType`` is a stand-in enum (its values are only ever
compared for identity/equality), ``make_mesh`` drops the unsupported
``axis_types`` keyword, and ``get_abstract_mesh`` returns None (callers
treat "no abstract mesh" as "not inside a manual region").
"""

from __future__ import annotations

import enum
from typing import Optional, Sequence

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType  # type: ignore

    HAS_AXIS_TYPES = True
except ImportError:  # jax 0.4.x
    HAS_AXIS_TYPES = False

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        """Stand-in for jax.sharding.AxisType on old jax: meshes have no
        axis-type concept there, so every axis behaves as Auto."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, axis_types=None, **kwargs):
    """jax.make_mesh that tolerates old jax without ``axis_types``."""
    if HAS_AXIS_TYPES and axis_types is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=axis_types, **kwargs)
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def get_abstract_mesh() -> Optional[object]:
    """jax.sharding.get_abstract_mesh, or None where it does not exist."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is None:
        return None
    return fn()


# jax.shard_map graduated from jax.experimental in 0.5/0.6, renaming
# check_rep -> check_vma and replacing `auto` (axes left unsharded by the
# manual region) with `axis_names` (axes the region is manual over).  Wrap
# the experimental symbol on 0.4.x so call sites can use the modern API.
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_04

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True,
                  axis_names=None, **kwargs):
        if axis_names is not None:
            kwargs["auto"] = frozenset(mesh.axis_names) - set(axis_names)
        return _shard_map_04(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=check_vma,
                             **kwargs)
