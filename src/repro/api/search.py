"""Search drivers over a backend run (lifted out of ``core.tuner``).

``exhaustive`` is the paper's evaluation protocol (§VI.A): for each
configuration, one full reference execution, the policy's optional charged
offline pass, then ``trials`` selective executions; statistics reset
between configurations per the space's protocol switch.

``racing`` is the beyond-paper successive-elimination search driven by the
paper's own confidence intervals: each round gives every surviving
configuration one selective trial and prunes a configuration once the
lower CI bound of its predicted time exceeds the incumbent's upper bound.

``model_guided`` never visits most of the grid at all: it fits a
Gaussian-copula candidate model over recorded statistics banks
(``transfer.CopulaModel``), scores every point through its RNG-free
structural profile (``BackendRun.kernel_profile``) under seeded joint
kernel-time draws, prefilters the top-scored candidates with analytic
roofline lower bounds against an optional measured incumbent
(``BackendRun.cost_lower_bound``), and hands the survivors to ``racing``
for statistical-confidence arbitration — paper-geometry sweeps touching
<10% of the grid with the same winners.

All produce the uniform ``ConfigRecord``/``StudyResult`` rows; the
``Autotuner`` shim in ``core.tuner`` delegates here, so the sim goldens
pin these drivers bit-for-bit.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.policies import Policy
from repro.core.stats import t_quantile_975

from .result import ConfigRecord
from .space import ConfigPoint, SearchSpace

# NOTE: this module deliberately does not import .backends (the run is
# duck-typed) — core.tuner imports these drivers at module level, and a
# .backends dependency would close an import cycle through repro.core.

SEARCHES = ("exhaustive", "racing", "model_guided")


def measure_config(run: "BackendRun", point: ConfigPoint, policy: Policy, *,
                   trials: int = 3) -> ConfigRecord:
    """The paper's per-configuration measurement sequence."""
    ref = run.run_reference(point)
    full_time = ref.time

    selective_cost = 0.0
    if policy.needs_offline_pass:
        off = run.run_offline(point)
        selective_cost += off.cost

    predictions: List[float] = []
    last = ref
    for _ in range(trials):
        last = run.run_trial(point)
        selective_cost += last.cost
        predictions.append(last.predicted)

    predicted = predictions[-1]
    rel_error = (abs(predicted - full_time) / full_time
                 if full_time > 0 else 0.0)
    comp_error = (abs(last.comp - ref.comp) / ref.comp
                  if ref.comp > 0 else 0.0)
    extra = dict(ref.extra)
    extra.update(last.extra)
    return ConfigRecord(
        name=point.name, params=point.params, full_time=full_time,
        predicted=predicted, rel_error=rel_error, comp_error=comp_error,
        selective_cost=selective_cost, full_cost=full_time * trials,
        executed=last.executed, skipped=last.skipped,
        predictions=predictions, extra=extra)


def exhaustive(run: "BackendRun", space: SearchSpace, policy: Policy, *,
               trials: int = 3,
               start_records: Optional[List[ConfigRecord]] = None,
               on_record: Optional[Callable[[ConfigRecord], None]] = None,
               ) -> Tuple[List[ConfigRecord], dict]:
    """Measure every point in order.  ``start_records`` resumes a
    checkpointed study: the first ``len(start_records)`` points are taken
    as done (valid because resumption is only offered when statistics
    reset between configurations, so a fresh backend run at point k is in
    the same state as one that measured points 0..k-1 and reset)."""
    records = list(start_records or ())
    reset = space.should_reset(policy)
    for i, point in enumerate(space.points):
        if i < len(records):
            continue
        if i > 0 and reset:
            run.reset_models()
        rec = measure_config(run, point, policy, trials=trials)
        records.append(rec)
        if on_record is not None:
            on_record(rec)
    return records, {}


def racing(run: "BackendRun", space: SearchSpace, policy: Policy, *,
           max_rounds: int = 6, min_survivor_trials: int = 2,
           trials: int = 1) -> Tuple[List[ConfigRecord], dict]:
    """Successive elimination driven by the paper's CIs.

    The per-kernel statistical machinery is reused verbatim — racing only
    changes *which* configurations keep getting iterations, exactly the
    composition the paper suggests with search-space pruning studies.
    Models are never reset (racing interleaves configurations; resetting
    would discard everything each step).

    Returns one record per configuration: ``predictions`` holds the
    config's per-round selective samples, ``predicted`` their mean, and
    ``extra`` carries the racing artifacts (round pruned, survivor set).
    ``trials`` is accepted for driver-signature uniformity and ignored
    (each round is one trial per survivor).
    """
    points = list(space.points)
    samples: Dict[str, List[float]] = {p.name: [] for p in points}
    costs: Dict[str, float] = {p.name: 0.0 for p in points}
    counts: Dict[str, Tuple[int, int]] = {p.name: (0, 0) for p in points}
    active = {p.name for p in points}
    pruned_at: Dict[str, int] = {}
    cost = 0.0

    def ci(name: str) -> Tuple[float, float]:
        xs = samples[name]
        n = len(xs)
        m = float(np.mean(xs))
        if n < 2:
            return m, math.inf
        hw = t_quantile_975(n - 1) * float(np.std(xs, ddof=1)) \
            / math.sqrt(n)
        return m, hw

    rounds = 0
    for rnd in range(max_rounds):
        rounds = rnd + 1
        for p in points:
            if p.name not in active:
                continue
            m = run.run_trial(p)
            cost += m.cost
            costs[p.name] += m.cost
            counts[p.name] = (m.executed, m.skipped)
            samples[p.name].append(m.predicted)
        stats = {nm: ci(nm) for nm in active}
        inc = min(stats, key=lambda nm: stats[nm][0])
        inc_hi = stats[inc][0] + stats[inc][1]
        for nm in list(active):
            if nm == inc:
                continue
            m, hw = stats[nm]
            if len(samples[nm]) >= min_survivor_trials and m - hw > inc_hi:
                active.remove(nm)
                pruned_at[nm] = rnd
        if len(active) == 1:
            break

    best = min(active, key=lambda nm: float(np.mean(samples[nm])))
    records = []
    for p in points:
        xs = samples[p.name]
        ex, sk = counts[p.name]
        records.append(ConfigRecord(
            name=p.name, params=p.params, full_time=0.0,
            predicted=float(np.mean(xs)) if xs else math.inf,
            rel_error=0.0, comp_error=0.0,
            selective_cost=costs[p.name], full_cost=0.0,
            executed=ex, skipped=sk, predictions=list(xs),
            extra={"pruned_at": pruned_at.get(p.name)}))
    extra = {"best": best, "survivors": sorted(active),
             "pruned_at": pruned_at, "rounds": rounds,
             "total_iterations": sum(len(v) for v in samples.values()),
             "cost": cost}
    return records, extra


# ------------------------------------------------------------- model-guided

def normalize_options(search: str, options: dict) -> dict:
    """JSON-normalize driver options at session construction so scheduler
    task payloads ship them verbatim (``StatisticsBank`` / ``CopulaModel``
    objects become their ``to_json`` payloads — a forked or remote worker
    reconstructs the identical model)."""
    if search != "model_guided":
        return options
    out = dict(options)
    banks = out.get("banks")
    if banks:
        out["banks"] = [b if isinstance(b, dict) else b.to_json()
                        for b in banks]
    model = out.get("model")
    if model is not None and not isinstance(model, dict):
        out["model"] = model.to_json()
    return out


def _coverage_budget(n_points: int, max_coverage: float) -> int:
    """Largest candidate count strictly under ``max_coverage`` of the
    grid, floored at one (some candidate must always be dispatched)."""
    k = int(n_points * max_coverage + 1e-9)
    if k >= n_points * max_coverage - 1e-9:
        k -= 1
    return max(1, k)


def _incumbent_upper(incumbent) -> Optional[float]:
    """Resolve an incumbent spec to its upper confidence bound: a float,
    ``{"upper": t}``, or ``{"mean": m, "halfwidth": h}``.  ``None`` (or an
    empty dict) means no incumbent — the prefilter passes everything."""
    if incumbent is None:
        return None
    if isinstance(incumbent, (int, float)):
        return float(incumbent)
    if "upper" in incumbent:
        return float(incumbent["upper"])
    if "mean" in incumbent:
        return float(incumbent["mean"]) \
            + float(incumbent.get("halfwidth", 0.0))
    return None


def _surrogate_scores(run: "BackendRun", points: List[ConfigPoint], model,
                      rng, n_samples: int) -> Optional[List[float]]:
    """Per-point critical-path surrogate under the copula model: for each
    of ``n_samples`` joint kernel-time draws, charge every occurrence its
    drawn time on each participating rank (the backend's structural
    profile) and take the slowest rank; the score is the mean over draws.
    ``None`` when the backend cannot profile or the model covers no
    profiled kernel — the driver then samples candidates uniformly.

    Profiling the full grid goes through the backend's compiled-program
    map (and its ``ProgramCache`` when one is configured — see
    ``repro.simmpi.program``), so scoring records each unique geometry at
    most once, survivors' measurements reuse the scorer's programs, and a
    warm cache makes grid scoring recording-free entirely."""
    if not model:
        return None
    profiles = []
    for p in points:
        prof = run.kernel_profile(p)
        if prof is None:
            return None
        profiles.append(prof)
    index = {k: j for j, k in enumerate(model.keys)}
    draws = model.sample(n_samples, rng).T          # (keys, samples)
    scores: List[float] = []
    overlap = 0
    for prof in profiles:
        counts = None
        for key, per_rank in prof.items():
            j = index.get(key)
            if j is None:
                continue                # kernel unknown to the model
            overlap += 1
            if counts is None:
                counts = np.zeros((len(per_rank), len(model.keys)))
            counts[:, j] += per_rank
        if counts is None:
            scores.append(math.inf)     # nothing modeled: rank last
            continue
        per_rank_draws = counts @ draws             # (ranks, samples)
        scores.append(float(per_rank_draws.max(axis=0).mean()))
    return scores if overlap else None


def model_guided(run: "BackendRun", space: SearchSpace, policy: Policy, *,
                 trials: int = 1, banks: Optional[list] = None,
                 model=None, seed: int = 0, n_samples: int = 64,
                 max_coverage: float = 0.10, top_k: Optional[int] = None,
                 incumbent=None, max_rounds: int = 6,
                 min_survivor_trials: int = 2,
                 start_state: Optional[dict] = None,
                 on_state: Optional[Callable[[dict], None]] = None,
                 ) -> Tuple[List[ConfigRecord], dict]:
    """Copula-sampled, roofline-pruned candidate search.

    Three stages: (1) fit a ``transfer.CopulaModel`` over ``banks`` (or
    use a pre-fitted ``model``) and score every grid point by the mean
    critical-path surrogate over ``n_samples`` seeded joint draws, keeping
    the best ``top_k`` (default: the largest count strictly under
    ``max_coverage`` of the grid); (2) drop candidates whose analytic
    roofline lower bound (``run.cost_lower_bound``) provably exceeds the
    ``incumbent``'s measured upper CI bound — they are never dispatched;
    (3) let ``racing`` arbitrate the survivors with statistical
    confidence.  Unvisited points keep a record with ``predicted = inf``
    and no samples, so results stay shape-uniform with the other drivers.

    Selection is deterministic from ``seed`` and the space's pinned
    enumeration order, and the post-selection sampler RNG state is
    journaled through ``on_state`` / replayed via ``start_state``
    (alongside the survivor set and the space's ``order_fingerprint``,
    which resume validates), so a killed-and-resumed or fork-dispatched
    study is bit-identical to the serial driver.

    Degenerate models (empty/unmatched banks, a backend without profiles)
    fall back to uniform candidate sampling under the same seed —
    coverage still holds; only the guidance is lost.
    """
    from .transfer import CopulaModel, StatisticsBank

    points = list(space.points)
    n_points = len(points)
    order = space.order_fingerprint()
    rng = np.random.default_rng(seed)

    if start_state is not None:
        if start_state.get("space_order") != order:
            raise ValueError(
                "checkpointed model-guided selection was sampled over a "
                f"different point enumeration ({start_state.get('space_order')!r}"
                f" != {order!r}); refusing to resume")
        sel = dict(start_state)
        rng.bit_generator.state = sel["rng"]
    else:
        if model is not None and not isinstance(model, CopulaModel):
            model = CopulaModel.from_json(model)
        if model is None:
            model = CopulaModel.fit(
                [b if isinstance(b, StatisticsBank)
                 else StatisticsBank.from_json(b) for b in (banks or [])])
        k = _coverage_budget(n_points, max_coverage) if top_k is None \
            else max(1, min(top_k, n_points))
        scores = _surrogate_scores(run, points, model, rng, n_samples)
        if scores is None:
            ranked = [int(i) for i in rng.permutation(n_points)]
            fallback = "uniform"
        else:
            ranked = sorted(range(n_points),
                            key=lambda i: (scores[i], i))
            fallback = None
        candidates = [points[i].name for i in ranked[:k]]
        pruned: List[str] = []
        upper = _incumbent_upper(incumbent)
        if upper is not None:
            by_name = {p.name: p for p in points}
            kept = []
            for nm in candidates:
                lb = run.cost_lower_bound(by_name[nm])
                if lb is not None and lb > upper:
                    pruned.append(nm)
                else:
                    kept.append(nm)
            candidates = kept
        sel = {"space_order": order, "survivors": candidates,
               "roofline_pruned": pruned, "fallback": fallback,
               "rho": model.rho, "model_keys": len(model),
               "rng": rng.bit_generator.state}
        if on_state is not None:
            on_state(sel)

    chosen = set(sel["survivors"])
    surv = [p for p in points if p.name in chosen]
    if surv:
        sub = SearchSpace(name=space.name, points=surv,
                          reset_between_configs=space.reset_between_configs,
                          world_size=space.world_size,
                          machine=space.machine)
        sub_records, race = racing(
            run, sub, policy, max_rounds=max_rounds,
            min_survivor_trials=min_survivor_trials, trials=trials)
    else:
        # every candidate was provably dominated by the incumbent: nothing
        # to measure, and nothing here beats what the caller already has
        sub_records, race = [], {
            "best": None, "survivors": [], "pruned_at": {}, "rounds": 0,
            "total_iterations": 0, "cost": 0.0}
    by = {r.name: r for r in sub_records}
    pruned_set = set(sel["roofline_pruned"])
    records: List[ConfigRecord] = []
    for p in points:
        rec = by.get(p.name)
        if rec is None:
            rec = ConfigRecord(
                name=p.name, params=p.params, full_time=0.0,
                predicted=math.inf, rel_error=0.0, comp_error=0.0,
                selective_cost=0.0, full_cost=0.0, executed=0, skipped=0,
                predictions=[],
                extra={"selected": False,
                       "roofline_pruned": p.name in pruned_set})
        records.append(rec)
    extra = {"best": race["best"], "survivors": race["survivors"],
             "pruned_at": race["pruned_at"], "rounds": race["rounds"],
             "total_iterations": race["total_iterations"],
             "cost": race["cost"],
             "dispatched": [p.name for p in surv],
             "coverage": len(surv) / n_points if n_points else 0.0,
             "roofline_pruned": list(sel["roofline_pruned"]),
             "fallback": sel["fallback"],
             "sampler": {"seed": seed, "n_samples": n_samples,
                         "rho": sel["rho"],
                         "model_keys": sel["model_keys"],
                         "space_order": order}}
    return records, extra
