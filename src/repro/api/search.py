"""Search drivers over a backend run (lifted out of ``core.tuner``).

``exhaustive`` is the paper's evaluation protocol (§VI.A): for each
configuration, one full reference execution, the policy's optional charged
offline pass, then ``trials`` selective executions; statistics reset
between configurations per the space's protocol switch.

``racing`` is the beyond-paper successive-elimination search driven by the
paper's own confidence intervals: each round gives every surviving
configuration one selective trial and prunes a configuration once the
lower CI bound of its predicted time exceeds the incumbent's upper bound.

Both produce the uniform ``ConfigRecord``/``StudyResult`` rows; the
``Autotuner`` shim in ``core.tuner`` delegates here, so the sim goldens
pin these drivers bit-for-bit.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.policies import Policy
from repro.core.stats import t_quantile_975

from .result import ConfigRecord
from .space import ConfigPoint, SearchSpace

# NOTE: this module deliberately does not import .backends (the run is
# duck-typed) — core.tuner imports these drivers at module level, and a
# .backends dependency would close an import cycle through repro.core.

SEARCHES = ("exhaustive", "racing")


def measure_config(run: "BackendRun", point: ConfigPoint, policy: Policy, *,
                   trials: int = 3) -> ConfigRecord:
    """The paper's per-configuration measurement sequence."""
    ref = run.run_reference(point)
    full_time = ref.time

    selective_cost = 0.0
    if policy.needs_offline_pass:
        off = run.run_offline(point)
        selective_cost += off.cost

    predictions: List[float] = []
    last = ref
    for _ in range(trials):
        last = run.run_trial(point)
        selective_cost += last.cost
        predictions.append(last.predicted)

    predicted = predictions[-1]
    rel_error = (abs(predicted - full_time) / full_time
                 if full_time > 0 else 0.0)
    comp_error = (abs(last.comp - ref.comp) / ref.comp
                  if ref.comp > 0 else 0.0)
    extra = dict(ref.extra)
    extra.update(last.extra)
    return ConfigRecord(
        name=point.name, params=point.params, full_time=full_time,
        predicted=predicted, rel_error=rel_error, comp_error=comp_error,
        selective_cost=selective_cost, full_cost=full_time * trials,
        executed=last.executed, skipped=last.skipped,
        predictions=predictions, extra=extra)


def exhaustive(run: "BackendRun", space: SearchSpace, policy: Policy, *,
               trials: int = 3,
               start_records: Optional[List[ConfigRecord]] = None,
               on_record: Optional[Callable[[ConfigRecord], None]] = None,
               ) -> Tuple[List[ConfigRecord], dict]:
    """Measure every point in order.  ``start_records`` resumes a
    checkpointed study: the first ``len(start_records)`` points are taken
    as done (valid because resumption is only offered when statistics
    reset between configurations, so a fresh backend run at point k is in
    the same state as one that measured points 0..k-1 and reset)."""
    records = list(start_records or ())
    reset = space.should_reset(policy)
    for i, point in enumerate(space.points):
        if i < len(records):
            continue
        if i > 0 and reset:
            run.reset_models()
        rec = measure_config(run, point, policy, trials=trials)
        records.append(rec)
        if on_record is not None:
            on_record(rec)
    return records, {}


def racing(run: "BackendRun", space: SearchSpace, policy: Policy, *,
           max_rounds: int = 6, min_survivor_trials: int = 2,
           trials: int = 1) -> Tuple[List[ConfigRecord], dict]:
    """Successive elimination driven by the paper's CIs.

    The per-kernel statistical machinery is reused verbatim — racing only
    changes *which* configurations keep getting iterations, exactly the
    composition the paper suggests with search-space pruning studies.
    Models are never reset (racing interleaves configurations; resetting
    would discard everything each step).

    Returns one record per configuration: ``predictions`` holds the
    config's per-round selective samples, ``predicted`` their mean, and
    ``extra`` carries the racing artifacts (round pruned, survivor set).
    ``trials`` is accepted for driver-signature uniformity and ignored
    (each round is one trial per survivor).
    """
    points = list(space.points)
    samples: Dict[str, List[float]] = {p.name: [] for p in points}
    costs: Dict[str, float] = {p.name: 0.0 for p in points}
    counts: Dict[str, Tuple[int, int]] = {p.name: (0, 0) for p in points}
    active = {p.name for p in points}
    pruned_at: Dict[str, int] = {}
    cost = 0.0

    def ci(name: str) -> Tuple[float, float]:
        xs = samples[name]
        n = len(xs)
        m = float(np.mean(xs))
        if n < 2:
            return m, math.inf
        hw = t_quantile_975(n - 1) * float(np.std(xs, ddof=1)) \
            / math.sqrt(n)
        return m, hw

    rounds = 0
    for rnd in range(max_rounds):
        rounds = rnd + 1
        for p in points:
            if p.name not in active:
                continue
            m = run.run_trial(p)
            cost += m.cost
            costs[p.name] += m.cost
            counts[p.name] = (m.executed, m.skipped)
            samples[p.name].append(m.predicted)
        stats = {nm: ci(nm) for nm in active}
        inc = min(stats, key=lambda nm: stats[nm][0])
        inc_hi = stats[inc][0] + stats[inc][1]
        for nm in list(active):
            if nm == inc:
                continue
            m, hw = stats[nm]
            if len(samples[nm]) >= min_survivor_trials and m - hw > inc_hi:
                active.remove(nm)
                pruned_at[nm] = rnd
        if len(active) == 1:
            break

    best = min(active, key=lambda nm: float(np.mean(samples[nm])))
    records = []
    for p in points:
        xs = samples[p.name]
        ex, sk = counts[p.name]
        records.append(ConfigRecord(
            name=p.name, params=p.params, full_time=0.0,
            predicted=float(np.mean(xs)) if xs else math.inf,
            rel_error=0.0, comp_error=0.0,
            selective_cost=costs[p.name], full_cost=0.0,
            executed=ex, skipped=sk, predictions=list(xs),
            extra={"pruned_at": pruned_at.get(p.name)}))
    extra = {"best": best, "survivors": sorted(active),
             "pruned_at": pruned_at, "rounds": rounds,
             "total_iterations": sum(len(v) for v in samples.values()),
             "cost": cost}
    return records, extra
