"""Async work-queue scheduling of sweep tasks over pluggable executors.

The sweep machinery used to be scattered across ``session.sweep`` (grid
logic + checkpoint), ``parallel.run_tasks`` (a fork pool), and the
benchmark drivers.  This module lifts it into one subsystem:

- a ``Task`` is one unit of sweep work (one (policy, tolerance, seed,
  allocation) study) with explicit state — ``pending`` -> ``running`` ->
  ``done`` | ``failed`` — and an attempt history (``attempts``: one entry
  per failed execution, with the error and the worker it ran on);
- an ``Executor`` is the substrate tasks run on:

  * ``InProcessExecutor`` — synchronous, in this process (the serial
    driver; the only executor for backends that are not ``parallel_safe``);
  * ``ForkExecutor``      — ``os.fork`` children, results over pipes
    (subsumes the old ``repro.api.parallel`` pool: study spaces carry
    closures that do not pickle, and a forked child inherits them — plus
    the parent's warm imports — for free);
  * ``RemoteExecutor``    — socket-connected ``python -m repro.api.worker``
    processes speaking newline-delimited JSON; each worker owns its own
    (space, backend) built from an import spec and executes the same task
    payloads, so a sweep can span machines;

- the ``Scheduler`` drives the queue asynchronously: it keeps the executor
  saturated up to its capacity, builds each task's payload at *dispatch*
  time (``prepare`` hook — this is what lets mid-sweep statistics sharing
  hand later tasks the priors harvested from earlier completions, see
  ``session.AutotuneSession.sweep(share_stats=True)``), and fires
  ``on_done`` as results land, in completion order.

Tasks are dispatched in queue order and the caller merges results by task
index, so the *merged* output is deterministic regardless of completion
order; whether the measurements themselves are scheduling-independent is
the caller's contract (cold tasks always are; mid-sweep sharing is not,
which is why the session offers ``deterministic=True``).

Failure semantics — at fleet scale worker loss and stragglers are
routine, so a task error does not abort the sweep by default policy
alone:

- a failed execution (worker death, task deadline, task exception) is
  recorded in ``Task.attempts`` and the task is *requeued* with
  exponential backoff, up to ``max_retries`` extra attempts; a retried
  task's payload is rebuilt by ``prepare`` at re-dispatch;
- only when retries are exhausted does the task reach ``failed``; then
  ``on_failure="raise"`` (default) raises ``SchedulerError`` carrying the
  full attempt history, while ``on_failure="skip"`` records the failure
  and lets the rest of the grid complete (partial results);
- every recovery event (retry, task failure, worker loss/join, heartbeat
  timeout, task deadline) flows through the ``on_event`` callback so
  callers can journal it (``session.sweep`` persists them into the sweep
  checkpoint and surfaces per-task histories in ``StudyResult.extra``);
- ``RemoteExecutor`` detects *wedged* (not just disconnected) workers via
  a per-task deadline (``task_timeout``) and idle-worker liveness pings
  (``heartbeat_interval`` + the worker protocol's ``{"op": "ping"}``),
  and can accept workers joining mid-sweep on a listening socket
  (``listen=``; workers dial in with ``--connect``), so capacity
  recovers — see ``repro.api.supervisor.WorkerPool`` for the process
  supervision half.

Interrupts stay interrupts: executors convert task ``Exception``s into
failed attempts but let ``KeyboardInterrupt``/``SystemExit`` propagate
(the scheduler still closes the executor on the way out).
"""

from __future__ import annotations

import json
import os
import selectors
import socket
import sys
import time
import traceback
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

PENDING = "pending"
RUNNING = "running"
DONE = "done"
FAILED = "failed"

#: exhausted-retries policies (see ``Scheduler``)
ON_FAILURE = ("raise", "skip")


class SchedulerError(RuntimeError):
    """A task failed on its executor (the attempt history and the last
    worker traceback are in the message; the failed ``Task`` is in
    ``.task``)."""

    def __init__(self, message: str, task: "Task" = None):
        super().__init__(message)
        self.task = task


@dataclass
class Task:
    """One unit of sweep work, with explicit lifecycle state."""

    index: int                     # position in the submission order
    spec: Any                      # caller-level description (opaque here)
    state: str = PENDING
    payload: Optional[dict] = None  # JSON-able message built at dispatch
    result: Optional[dict] = None   # the runner's JSON result (state DONE)
    error: Optional[str] = None     # last traceback (state FAILED)
    #: one entry per *failed* execution: {"attempt": n, "error": traceback,
    #: "worker": identity} — a task that eventually succeeded keeps its
    #: earlier failures here (surfaced as recovery provenance)
    attempts: List[dict] = field(default_factory=list)
    meta: dict = field(default_factory=dict)


def fork_available() -> bool:
    return hasattr(os, "fork")


def _last_line(tb: Optional[str]) -> str:
    if not tb:
        return "?"
    lines = [ln for ln in tb.strip().splitlines() if ln.strip()]
    return lines[-1] if lines else "?"


# ------------------------------------------------------------- executors

class Executor:
    """Task execution substrate.

    ``start(runner)`` readies the executor (``runner(payload) -> dict`` is
    the in-process task function; socket executors ignore it and ship the
    payload instead).  ``submit`` must not block on task completion;
    ``poll`` blocks until at least one in-flight task finishes and returns
    ``[(task_index, {"ok": result} | {"err": traceback, "worker": id})]``.
    ``capacity`` is the number of tasks the executor can hold in flight;
    ``can_grow`` executors may regain capacity while the scheduler waits
    (elastic worker join), so losing every worker is not final until a
    join window expires.  Recovery events accumulate via ``_emit`` and are
    drained by the scheduler through ``drain_events``.
    """

    capacity: int = 1
    can_grow: bool = False

    def start(self, runner: Callable[[dict], dict]) -> None:
        raise NotImplementedError

    def submit(self, index: int, payload: dict) -> None:
        raise NotImplementedError

    def poll(self) -> List[Tuple[int, dict]]:
        raise NotImplementedError

    def close(self) -> None:
        pass

    def _emit(self, **event) -> None:
        self.__dict__.setdefault("_events", []).append(event)

    def drain_events(self) -> List[dict]:
        """Recovery events (worker loss/join/restart, timeouts) since the
        last drain, oldest first."""
        ev = self.__dict__.get("_events")
        if not ev:
            return []
        out, ev[:] = list(ev), []
        return out


class InProcessExecutor(Executor):
    """Synchronous execution in the calling process — the serial driver.

    ``submit`` runs the task immediately (capacity 1 keeps the scheduler
    from queueing ahead), so execution order is exactly submission order
    and shared in-process state (e.g. a study checkpoint journaling
    per-configuration records) behaves as under the historical serial
    sweep."""

    capacity = 1

    def __init__(self):
        self._runner = None
        self._ready: List[Tuple[int, dict]] = []

    def start(self, runner) -> None:
        self._runner = runner

    def submit(self, index: int, payload: dict) -> None:
        # Exception, not BaseException: Ctrl-C / SystemExit must interrupt
        # the sweep, not masquerade as a failed (and then retried!) task
        try:
            out = {"ok": self._runner(payload)}
        except Exception:
            out = {"err": traceback.format_exc(), "worker": "in-process"}
        self._ready.append((index, out))

    def poll(self) -> List[Tuple[int, dict]]:
        out, self._ready = self._ready, []
        return out


class ForkExecutor(Executor):
    """``os.fork`` children, one per in-flight task, results over pipes.

    Children return results as JSON over a pipe (length-unframed: the
    child writes once and closes; the parent reads to EOF via
    ``selectors`` so pipe-buffer backpressure cannot deadlock the pool).
    """

    def __init__(self, workers: int):
        if not fork_available():
            raise RuntimeError("ForkExecutor requires os.fork")
        self.capacity = max(int(workers), 1)
        self._runner = None
        self._sel = None
        self._live: Dict[int, dict] = {}       # read-fd -> {index, pid, buf}

    def start(self, runner) -> None:
        self._runner = runner
        self._sel = selectors.DefaultSelector()

    def submit(self, index: int, payload: dict) -> None:
        rfd, wfd = os.pipe()
        # jax warns on any fork once imported anywhere in the process;
        # backends that actually touch jax declare parallel_safe=False and
        # never reach this pool, so the warning is noise here
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message=r".*os\.fork\(\).*",
                category=RuntimeWarning)
            pid = os.fork()
        if pid == 0:                            # child
            os.close(rfd)
            code = 0
            try:
                out = {"ok": self._runner(payload)}
            except BaseException:               # the child must report and
                out = {"err": traceback.format_exc()}   # die, whatever hit it
                code = 1
            try:
                with os.fdopen(wfd, "w") as w:
                    json.dump(out, w)
                sys.stdout.flush()
                sys.stderr.flush()
            finally:
                os._exit(code)                  # skip parent atexit/finalizers
        os.close(wfd)
        os.set_blocking(rfd, False)
        self._live[rfd] = {"index": index, "pid": pid, "buf": bytearray()}
        self._sel.register(rfd, selectors.EVENT_READ)

    def poll(self) -> List[Tuple[int, dict]]:
        results: List[Tuple[int, dict]] = []
        while not results and self._live:
            for key, _ in self._sel.select():
                rfd = key.fd
                st = self._live[rfd]
                while True:
                    try:
                        chunk = os.read(rfd, 1 << 16)
                    except BlockingIOError:
                        break
                    if not chunk:               # EOF: child wrote and closed
                        self._sel.unregister(rfd)
                        os.close(rfd)
                        del self._live[rfd]
                        os.waitpid(st["pid"], 0)
                        raw = bytes(st["buf"])
                        if not raw:
                            self._emit(event="worker_lost",
                                       worker=f"fork:{st['pid']}",
                                       task=st["index"])
                            out = {"err": f"fork worker for task "
                                          f"{st['index']} died without a "
                                          f"result",
                                   "worker": f"fork:{st['pid']}"}
                        else:
                            out = json.loads(raw)
                            if "err" in out:
                                out.setdefault("worker",
                                               f"fork:{st['pid']}")
                        results.append((st["index"], out))
                        break
                    st["buf"] += chunk
        return results

    def close(self) -> None:
        for st in self._live.values():
            try:
                os.kill(st["pid"], 9)
                os.waitpid(st["pid"], 0)
            except OSError:
                pass
        self._live.clear()


class RemoteExecutor(Executor):
    """Socket-connected remote workers (``python -m repro.api.worker``).

    ``addresses`` are ``"host:port"`` strings; one task is in flight per
    worker.  The protocol is newline-delimited JSON:

    - ``{"op": "hello"}`` -> ``{"ok": {"space", "n_points", "backend"}}``
      (sent at ``start`` and to every joining worker; when the scheduler
      supplies ``expect``, the worker's space/backend identity is checked
      against it so a sweep never lands on a worker tuning a different
      study);
    - ``{"op": "run", "id": i, "task": payload}`` -> ``{"id": i,
      "ok": result}`` or ``{"id": i, "err": traceback}``;
    - ``{"op": "ping"}`` -> ``{"ok": "pong"}`` (liveness heartbeat).

    Workers own their (space, backend) — closures never cross the wire,
    only task payloads and JSON results, which is what lets a sweep span
    machines.  Recorded event programs never cross it either: payloads
    carry per-point *structural fingerprints* (``program_fingerprints``,
    attached by ``AutotuneSession._task_payload`` when the dispatching
    backend has a ``ProgramCache``), and each worker keeps its own
    sweep-scoped cache (``--program-cache``, default in-memory), so a
    worker records each unique geometry once across every task it serves
    and re-dispatch never re-ships — or re-records — a program the worker
    already holds.  Fingerprint mismatch between dispatcher and worker is
    a loud task error (geometry drift), surfaced like any task failure.

    Fault tolerance:

    - a worker that disconnects mid-task yields an ``err`` result (the
      scheduler requeues the task) and stops counting toward capacity;
    - ``task_timeout`` is a per-task deadline: a *wedged* worker — alive
      but silent past the deadline — is dropped and its task reassigned
      (without it, a hung worker stalls ``poll`` forever);
    - ``heartbeat_interval`` pings idle workers; one that stays silent for
      a further interval is dropped before a task is wasted on it (busy
      workers are covered by the task deadline — a single-threaded worker
      cannot answer pings mid-task);
    - ``listen`` (``"host:port"`` or an int port; 0 binds an ephemeral
      port, see ``listen_address``) accepts workers joining mid-sweep:
      ``python -m repro.api.worker --connect <listen_address>`` dials in,
      is identity-checked like a static worker, and restores capacity —
      this is how a supervisor-restarted worker rejoins
      (``repro.api.supervisor.WorkerPool``).  With only elastic workers
      (``addresses=()``), ``poll`` waits up to ``join_timeout`` for the
      first join before the scheduler declares the fleet lost."""

    def __init__(self, addresses: Sequence[str] = (), *,
                 expect: Optional[dict] = None, timeout: float = 30.0,
                 task_timeout: Optional[float] = None,
                 heartbeat_interval: Optional[float] = None,
                 listen: Union[str, int, None] = None,
                 join_timeout: float = 30.0):
        self.addresses = list(addresses)
        self._srv = None
        self.listen_address: Optional[str] = None
        if listen is not None:
            spec = listen if isinstance(listen, str) else f":{int(listen)}"
            host, port = self._parse(spec)
            self._srv = socket.create_server((host, port))
            self._srv.setblocking(False)
            bh, bp = self._srv.getsockname()[:2]
            self.listen_address = f"{bh}:{bp}"
        if not self.addresses and self._srv is None:
            raise ValueError("RemoteExecutor needs at least one worker "
                             "address (or listen= for elastic workers)")
        self.capacity = len(self.addresses)
        self.expect = expect
        self.timeout = timeout
        self.task_timeout = task_timeout
        self.heartbeat_interval = heartbeat_interval
        self.join_timeout = join_timeout
        self._sel = None
        self._workers: Dict[socket.socket, dict] = {}
        self._free: List[socket.socket] = []
        self._stash: List[Tuple[int, dict]] = []

    @property
    def can_grow(self) -> bool:
        return self._srv is not None

    @staticmethod
    def _parse(addr: str) -> Tuple[str, int]:
        host, _, port = addr.rpartition(":")
        return host or "127.0.0.1", int(port)

    @staticmethod
    def _send(sock: socket.socket, msg: dict) -> None:
        sock.sendall(json.dumps(msg).encode() + b"\n")

    @staticmethod
    def _recv_line(sock: socket.socket, buf: bytearray) -> dict:
        """Blocking read of one JSON line (handshakes only; task replies
        go through the selector loop in ``poll``)."""
        while b"\n" not in buf:
            chunk = sock.recv(1 << 16)
            if not chunk:
                raise SchedulerError("remote worker closed the connection "
                                     "during handshake")
            buf += chunk
        line, _, rest = bytes(buf).partition(b"\n")
        buf[:] = rest
        return json.loads(line)

    def start(self, runner) -> None:          # runner unused: work ships out
        self._sel = selectors.DefaultSelector()
        if self._srv is not None:
            self._sel.register(self._srv, selectors.EVENT_READ)
        for addr in self.addresses:
            host, port = self._parse(addr)
            sock = socket.create_connection((host, port),
                                            timeout=self.timeout)
            self._admit(sock, addr)

    def _admit(self, sock: socket.socket, addr: str) -> None:
        """Handshake a worker (static or joining) and add it to the pool;
        raises ``SchedulerError`` on identity mismatch."""
        sock.settimeout(self.timeout)
        buf = bytearray()
        self._send(sock, {"op": "hello"})
        hello = self._recv_line(sock, buf)
        if "err" in hello:
            raise SchedulerError(
                f"worker {addr} refused hello: {hello['err']}")
        ident = hello.get("ok", {})
        if self.expect is not None:
            for k, want in self.expect.items():
                got = ident.get(k)
                if got != want:
                    raise SchedulerError(
                        f"worker {addr} serves {k}={got!r}, this sweep "
                        f"needs {k}={want!r} — wrong --spec?")
        sock.setblocking(False)
        now = time.monotonic()
        self._workers[sock] = {"addr": addr, "buf": buf, "ident": ident,
                               "index": None, "t_dispatch": None,
                               "last_seen": now, "ping_sent": None}
        self._free.append(sock)
        self._sel.register(sock, selectors.EVENT_READ)
        self.capacity = len(self._workers)

    def _accept(self) -> None:
        """An elastic worker dialed the listening socket: handshake it
        like a static one; a mismatched or broken joiner is rejected
        without disturbing the sweep."""
        try:
            conn, peer = self._srv.accept()
        except OSError:
            return
        addr = f"{peer[0]}:{peer[1]}"
        try:
            self._admit(conn, addr)
        except (SchedulerError, OSError, ValueError) as e:
            self._emit(event="worker_rejected", worker=addr,
                       error=str(e))
            try:
                conn.close()
            except OSError:
                pass
            return
        self._emit(event="worker_joined", worker=addr,
                   capacity=self.capacity)

    def submit(self, index: int, payload: dict) -> None:
        while self._free:
            sock = self._free.pop(0)
            st = self._workers[sock]
            try:
                sock.settimeout(self.timeout)   # a wedged worker fails the
                self._send(sock, {"op": "run", "id": index,    # send
                                  "task": payload})
                sock.setblocking(False)
            except OSError:
                # the worker died while idle: try the next free one
                self._emit(event="worker_lost", worker=st["addr"],
                           phase="submit")
                self._drop(sock)
                continue
            st["index"] = index
            st["t_dispatch"] = time.monotonic()
            return
        # every free worker turned out dead at dispatch: fail the attempt
        # (the scheduler retries or raises per its policy)
        self._stash.append((index, {
            "err": f"no live remote worker available for task {index}",
            "worker": None}))

    def poll(self) -> List[Tuple[int, dict]]:
        results, self._stash = self._stash, []
        join_deadline = time.monotonic() + self.join_timeout
        while not results:
            busy = any(st["index"] is not None
                       for st in self._workers.values())
            if not busy:
                # nothing in flight: hand control back so the scheduler
                # can dispatch — unless the pool is empty and elastic, in
                # which case wait (up to join_timeout) for a worker to join
                if self._free or not self.can_grow:
                    break
                if time.monotonic() >= join_deadline:
                    break
            for key, _ in self._sel.select(
                    self._tick(busy, join_deadline)):
                if key.fileobj is self._srv:
                    self._accept()
                    continue
                self._read(key.fileobj, results)
            now = time.monotonic()
            self._check_deadlines(now, results)
            self._check_heartbeats(now)
        return results

    def _tick(self, busy: bool, join_deadline: float) -> Optional[float]:
        """The next time-driven wakeup: task deadline, heartbeat due, or
        join-window expiry.  None = block until socket activity."""
        now = time.monotonic()
        cands: List[float] = []
        if not busy and self.can_grow and not self._free:
            cands.append(join_deadline - now)
        if self.task_timeout is not None:
            for st in self._workers.values():
                if st["t_dispatch"] is not None:
                    cands.append(st["t_dispatch"] + self.task_timeout - now)
        if self.heartbeat_interval is not None:
            for st in self._workers.values():
                if st["index"] is None:
                    base = st["ping_sent"] if st["ping_sent"] is not None \
                        else st["last_seen"]
                    cands.append(base + self.heartbeat_interval - now)
        if not cands:
            return None
        return max(0.0, min(cands))

    def _read(self, sock: socket.socket,
              results: List[Tuple[int, dict]]) -> None:
        st = self._workers.get(sock)
        if st is None:
            return
        try:
            chunk = sock.recv(1 << 16)
        except (BlockingIOError, InterruptedError):
            return
        except OSError:
            chunk = b""
        if not chunk:
            idx = st["index"]
            self._emit(event="worker_lost", worker=st["addr"], task=idx,
                       phase="recv")
            self._drop(sock)
            if idx is not None:
                results.append((idx, {
                    "err": f"remote worker {st['addr']} died mid-task",
                    "worker": st["addr"]}))
            return
        st["buf"] += chunk
        st["last_seen"] = time.monotonic()
        while b"\n" in st["buf"]:
            line, _, rest = bytes(st["buf"]).partition(b"\n")
            st["buf"][:] = rest
            try:
                msg = json.loads(line)
            except ValueError:
                # a corrupt reply means the stream cannot be trusted:
                # fail the in-flight task and drop the worker
                idx = st["index"]
                self._emit(event="worker_lost", worker=st["addr"],
                           task=idx, phase="corrupt-reply")
                self._drop(sock)
                if idx is not None:
                    results.append((idx, {
                        "err": f"remote worker {st['addr']} sent a "
                               f"corrupt reply: {line[:120]!r}",
                        "worker": st["addr"]}))
                return
            if msg.get("ok") == "pong" and "id" not in msg:
                st["ping_sent"] = None          # heartbeat answered
                continue
            idx = msg.get("id", st["index"])
            st["index"] = None
            st["t_dispatch"] = None
            self._free.append(sock)
            out = {"ok": msg["ok"]} if "ok" in msg \
                else {"err": msg.get("err", "malformed reply"),
                      "worker": st["addr"]}
            results.append((idx, out))

    def _check_deadlines(self, now: float,
                         results: List[Tuple[int, dict]]) -> None:
        """Drop busy workers whose task has exceeded ``task_timeout`` —
        a wedged worker never closes its socket, so only a deadline can
        unstick the sweep."""
        if self.task_timeout is None:
            return
        for sock, st in list(self._workers.items()):
            if st["t_dispatch"] is None:
                continue
            if now - st["t_dispatch"] >= self.task_timeout:
                idx = st["index"]
                self._emit(event="task_deadline", worker=st["addr"],
                           task=idx, timeout_s=self.task_timeout)
                self._drop(sock)
                results.append((idx, {
                    "err": f"remote worker {st['addr']} exceeded the "
                           f"{self.task_timeout}s task deadline on task "
                           f"{idx} (wedged?) — dropped for reassignment",
                    "worker": st["addr"]}))

    def _check_heartbeats(self, now: float) -> None:
        """Ping idle workers every ``heartbeat_interval``; one whose ping
        stays unanswered for a further interval is dropped."""
        if self.heartbeat_interval is None:
            return
        for sock, st in list(self._workers.items()):
            if st["index"] is not None:
                continue        # busy workers are the task deadline's job
            if st["ping_sent"] is not None:
                if now - st["ping_sent"] >= self.heartbeat_interval:
                    self._emit(event="heartbeat_timeout",
                               worker=st["addr"],
                               silent_s=round(now - st["last_seen"], 3))
                    self._drop(sock)
                continue
            if now - st["last_seen"] >= self.heartbeat_interval:
                try:
                    self._send(sock, {"op": "ping"})
                    st["ping_sent"] = now
                except BlockingIOError:
                    pass                        # send buffer full: later
                except OSError:
                    self._emit(event="worker_lost", worker=st["addr"],
                               phase="ping")
                    self._drop(sock)

    def _drop(self, sock) -> None:
        try:
            self._sel.unregister(sock)
        except (KeyError, ValueError):
            pass
        self._workers.pop(sock, None)
        if sock in self._free:
            self._free.remove(sock)
        # a dead worker no longer counts toward in-flight capacity; with a
        # listening socket the capacity can recover as workers rejoin,
        # otherwise the scheduler raises once none remains
        self.capacity = len(self._workers)
        sock.close()

    def close(self) -> None:
        for sock in list(self._workers):
            try:
                sock.close()
            except OSError:
                pass
        self._workers.clear()
        self._free.clear()
        if self._srv is not None:
            try:
                self._srv.close()
            except OSError:
                pass


# ------------------------------------------------------------- scheduler

class Scheduler:
    """Drives a task queue over an executor, keeping it saturated.

    ``run(specs, prepare=..., on_done=...)`` turns each spec into a
    ``Task``, builds its payload at dispatch time via ``prepare(task)``
    (late binding — this is the mid-sweep statistics-sharing hook), and
    executes them ``executor.capacity`` at a time.  ``on_done(task)``
    fires as each task completes, in completion order.  Returns the full
    task list (submission order) once every task reached a terminal state.

    Failure policy: a failed execution is requeued (payload rebuilt by
    ``prepare``) with exponential backoff ``retry_backoff * 2**(n-1)``,
    up to ``max_retries`` extra attempts; each failure is recorded in
    ``Task.attempts``.  Once exhausted, ``on_failure="raise"`` raises
    ``SchedulerError`` with the full history, ``"skip"`` marks the task
    ``failed`` and completes the rest of the queue.  ``on_event(dict)``
    receives every recovery event (``task_retry``, ``task_failed``, plus
    whatever the executor emits: ``worker_lost``, ``worker_joined``,
    ``task_deadline``, ``heartbeat_timeout``...)."""

    def __init__(self, executor: Executor,
                 runner: Optional[Callable[[dict], dict]] = None, *,
                 max_retries: int = 0, retry_backoff: float = 0.0,
                 on_failure: str = "raise",
                 on_event: Optional[Callable[[dict], None]] = None):
        if on_failure not in ON_FAILURE:
            raise ValueError(f"on_failure must be one of {ON_FAILURE}, "
                             f"got {on_failure!r}")
        self.executor = executor
        self.runner = runner
        self.max_retries = int(max_retries)
        self.retry_backoff = float(retry_backoff)
        self.on_failure = on_failure
        self.on_event = on_event

    def _emit(self, event: dict) -> None:
        if self.on_event is not None:
            self.on_event(event)

    def _drain(self) -> None:
        for ev in self.executor.drain_events():
            self._emit(ev)

    def run(self, specs: Sequence[Any], *,
            prepare: Optional[Callable[[Task], dict]] = None,
            on_done: Optional[Callable[[Task], None]] = None) -> List[Task]:
        tasks = [Task(i, spec) for i, spec in enumerate(specs)]
        ex = self.executor
        queue = deque(tasks)
        waiting: List[Tuple[float, Task]] = []   # (ready_at, task) backoffs
        inflight: Dict[int, Task] = {}
        try:
            ex.start(self.runner)
            self._drain()
            while queue or waiting or inflight:
                if waiting:
                    now = time.monotonic()
                    due = [t for ready, t in waiting if ready <= now]
                    waiting = [(r, t) for r, t in waiting if r > now]
                    queue.extend(due)
                while queue and len(inflight) < ex.capacity:
                    t = queue.popleft()
                    t.payload = prepare(t) if prepare is not None \
                        else t.spec
                    t.state = RUNNING
                    inflight[t.index] = t
                    ex.submit(t.index, t.payload)
                if not inflight:
                    if waiting:
                        time.sleep(max(0.0, min(r for r, _ in waiting)
                                       - time.monotonic()))
                        continue
                    if queue:
                        # an elastic executor may regain capacity (worker
                        # restart + rejoin); give it one join window
                        if ex.can_grow:
                            ex.poll()
                            self._drain()
                            if ex.capacity > 0:
                                continue
                        raise SchedulerError(
                            f"executor has no capacity left with "
                            f"{len(queue)} tasks still pending (all "
                            f"workers lost?)")
                    break
                for idx, out in ex.poll():
                    t = inflight.pop(idx, None)
                    if t is None:
                        continue        # late duplicate for a handled task
                    if "err" in out:
                        self._failed_attempt(t, out, queue, waiting)
                        continue
                    t.state = DONE
                    t.result = out["ok"]
                    if t.attempts:
                        t.meta["retries"] = len(t.attempts)
                    if on_done is not None:
                        on_done(t)
                self._drain()
        finally:
            try:
                ex.close()
            finally:
                self._drain()
        return tasks

    def _failed_attempt(self, t: Task, out: dict, queue: deque,
                        waiting: List[Tuple[float, Task]]) -> None:
        attempt = {"attempt": len(t.attempts) + 1,
                   "error": out["err"], "worker": out.get("worker")}
        t.attempts.append(attempt)
        if len(t.attempts) <= self.max_retries:
            t.state = PENDING
            delay = self.retry_backoff * (2 ** (len(t.attempts) - 1))
            self._emit({"event": "task_retry", "task": t.index,
                        "attempt": len(t.attempts),
                        "delay_s": round(delay, 3),
                        "worker": attempt["worker"],
                        "error": _last_line(out["err"])})
            if delay > 0:
                waiting.append((time.monotonic() + delay, t))
            else:
                queue.append(t)
            return
        t.state = FAILED
        t.error = out["err"]
        self._emit({"event": "task_failed", "task": t.index,
                    "attempts": len(t.attempts),
                    "worker": attempt["worker"],
                    "error": _last_line(out["err"])})
        if self.on_failure == "raise":
            history = "\n".join(
                f"  attempt {a['attempt']} on {a['worker'] or 'executor'}: "
                f"{_last_line(a['error'])}" for a in t.attempts)
            raise SchedulerError(
                f"sweep task {t.index} failed after {len(t.attempts)} "
                f"attempt(s):\n{history}\n\nlast traceback:\n{t.error}",
                task=t)
        # on_failure="skip": the task stays FAILED with its history; the
        # caller reports partial results and journals the failure


def run_tasks(tasks: Sequence[Any], runner: Callable[[Any], dict], *,
              workers: int = 1,
              on_result: Callable[[int, dict], None] = None) -> List[dict]:
    """Historical ``repro.api.parallel.run_tasks`` API over the scheduler:
    run ``runner(task) -> json-able dict`` over every task, ``workers`` at
    a time, returning results in task order; ``on_result(index, res)``
    fires as each result lands."""
    tasks = list(tasks)
    if workers <= 1 or len(tasks) <= 1 or not fork_available():
        executor: Executor = InProcessExecutor()
    else:
        executor = ForkExecutor(min(workers, len(tasks)))

    def on_done(t: Task) -> None:
        if on_result is not None:
            on_result(t.index, t.result)

    done = Scheduler(executor, runner).run(tasks, on_done=on_done)
    return [t.result for t in done]
