"""Async work-queue scheduling of sweep tasks over pluggable executors.

The sweep machinery used to be scattered across ``session.sweep`` (grid
logic + checkpoint), ``parallel.run_tasks`` (a fork pool), and the
benchmark drivers.  This module lifts it into one subsystem:

- a ``Task`` is one unit of sweep work (one (policy, tolerance, seed,
  allocation) study) with explicit state — ``pending`` -> ``running`` ->
  ``done`` | ``failed``;
- an ``Executor`` is the substrate tasks run on:

  * ``InProcessExecutor`` — synchronous, in this process (the serial
    driver; the only executor for backends that are not ``parallel_safe``);
  * ``ForkExecutor``      — ``os.fork`` children, results over pipes
    (subsumes the old ``repro.api.parallel`` pool: study spaces carry
    closures that do not pickle, and a forked child inherits them — plus
    the parent's warm imports — for free);
  * ``RemoteExecutor``    — socket-connected ``python -m repro.api.worker``
    processes speaking newline-delimited JSON; each worker owns its own
    (space, backend) built from an import spec and executes the same task
    payloads, so a sweep can span machines;

- the ``Scheduler`` drives the queue asynchronously: it keeps the executor
  saturated up to its capacity, builds each task's payload at *dispatch*
  time (``prepare`` hook — this is what lets mid-sweep statistics sharing
  hand later tasks the priors harvested from earlier completions, see
  ``session.AutotuneSession.sweep(share_stats=True)``), and fires
  ``on_done`` as results land, in completion order.

Tasks are dispatched in queue order and the caller merges results by task
index, so the *merged* output is deterministic regardless of completion
order; whether the measurements themselves are scheduling-independent is
the caller's contract (cold tasks always are; mid-sweep sharing is not,
which is why the session offers ``deterministic=True``).

A worker error fails the task and raises ``SchedulerError`` — sweeps are
resumable from their checkpoint, so failing loudly loses at most the
in-flight measurements.
"""

from __future__ import annotations

import json
import os
import selectors
import socket
import sys
import traceback
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

PENDING = "pending"
RUNNING = "running"
DONE = "done"
FAILED = "failed"


class SchedulerError(RuntimeError):
    """A task failed on its executor (the worker's traceback is in the
    message; the failed ``Task`` is in ``.task``)."""

    def __init__(self, message: str, task: "Task" = None):
        super().__init__(message)
        self.task = task


@dataclass
class Task:
    """One unit of sweep work, with explicit lifecycle state."""

    index: int                     # position in the submission order
    spec: Any                      # caller-level description (opaque here)
    state: str = PENDING
    payload: Optional[dict] = None  # JSON-able message built at dispatch
    result: Optional[dict] = None   # the runner's JSON result (state DONE)
    error: Optional[str] = None     # worker traceback (state FAILED)
    meta: dict = field(default_factory=dict)


def fork_available() -> bool:
    return hasattr(os, "fork")


# ------------------------------------------------------------- executors

class Executor:
    """Task execution substrate.

    ``start(runner)`` readies the executor (``runner(payload) -> dict`` is
    the in-process task function; socket executors ignore it and ship the
    payload instead).  ``submit`` must not block on task completion;
    ``poll`` blocks until at least one in-flight task finishes and returns
    ``[(task_index, {"ok": result} | {"err": traceback})]``.  ``capacity``
    is the number of tasks the executor can hold in flight.
    """

    capacity: int = 1

    def start(self, runner: Callable[[dict], dict]) -> None:
        raise NotImplementedError

    def submit(self, index: int, payload: dict) -> None:
        raise NotImplementedError

    def poll(self) -> List[Tuple[int, dict]]:
        raise NotImplementedError

    def close(self) -> None:
        pass


class InProcessExecutor(Executor):
    """Synchronous execution in the calling process — the serial driver.

    ``submit`` runs the task immediately (capacity 1 keeps the scheduler
    from queueing ahead), so execution order is exactly submission order
    and shared in-process state (e.g. a study checkpoint journaling
    per-configuration records) behaves as under the historical serial
    sweep."""

    capacity = 1

    def __init__(self):
        self._runner = None
        self._ready: List[Tuple[int, dict]] = []

    def start(self, runner) -> None:
        self._runner = runner

    def submit(self, index: int, payload: dict) -> None:
        try:
            out = {"ok": self._runner(payload)}
        except BaseException:
            out = {"err": traceback.format_exc()}
        self._ready.append((index, out))

    def poll(self) -> List[Tuple[int, dict]]:
        out, self._ready = self._ready, []
        return out


class ForkExecutor(Executor):
    """``os.fork`` children, one per in-flight task, results over pipes.

    Children return results as JSON over a pipe (length-unframed: the
    child writes once and closes; the parent reads to EOF via
    ``selectors`` so pipe-buffer backpressure cannot deadlock the pool).
    """

    def __init__(self, workers: int):
        if not fork_available():
            raise RuntimeError("ForkExecutor requires os.fork")
        self.capacity = max(int(workers), 1)
        self._runner = None
        self._sel = None
        self._live: Dict[int, dict] = {}       # read-fd -> {index, pid, buf}

    def start(self, runner) -> None:
        self._runner = runner
        self._sel = selectors.DefaultSelector()

    def submit(self, index: int, payload: dict) -> None:
        rfd, wfd = os.pipe()
        # jax warns on any fork once imported anywhere in the process;
        # backends that actually touch jax declare parallel_safe=False and
        # never reach this pool, so the warning is noise here
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message=r".*os\.fork\(\).*",
                category=RuntimeWarning)
            pid = os.fork()
        if pid == 0:                            # child
            os.close(rfd)
            code = 0
            try:
                out = {"ok": self._runner(payload)}
            except BaseException:
                out = {"err": traceback.format_exc()}
                code = 1
            try:
                with os.fdopen(wfd, "w") as w:
                    json.dump(out, w)
                sys.stdout.flush()
                sys.stderr.flush()
            finally:
                os._exit(code)                  # skip parent atexit/finalizers
        os.close(wfd)
        os.set_blocking(rfd, False)
        self._live[rfd] = {"index": index, "pid": pid, "buf": bytearray()}
        self._sel.register(rfd, selectors.EVENT_READ)

    def poll(self) -> List[Tuple[int, dict]]:
        results: List[Tuple[int, dict]] = []
        while not results and self._live:
            for key, _ in self._sel.select():
                rfd = key.fd
                st = self._live[rfd]
                while True:
                    try:
                        chunk = os.read(rfd, 1 << 16)
                    except BlockingIOError:
                        break
                    if not chunk:               # EOF: child wrote and closed
                        self._sel.unregister(rfd)
                        os.close(rfd)
                        del self._live[rfd]
                        os.waitpid(st["pid"], 0)
                        raw = bytes(st["buf"])
                        if not raw:
                            out = {"err": f"fork worker for task "
                                          f"{st['index']} died without a "
                                          f"result"}
                        else:
                            out = json.loads(raw)
                        results.append((st["index"], out))
                        break
                    st["buf"] += chunk
        return results

    def close(self) -> None:
        for st in self._live.values():
            try:
                os.kill(st["pid"], 9)
                os.waitpid(st["pid"], 0)
            except OSError:
                pass
        self._live.clear()


class RemoteExecutor(Executor):
    """Socket-connected remote workers (``python -m repro.api.worker``).

    ``addresses`` are ``"host:port"`` strings; one task is in flight per
    worker.  The protocol is newline-delimited JSON:

    - ``{"op": "hello"}`` -> ``{"ok": {"space", "n_points", "backend"}}``
      (sent at ``start``; when the scheduler supplies ``expect``, the
      worker's space/backend identity is checked against it so a sweep
      never lands on a worker tuning a different study);
    - ``{"op": "run", "id": i, "task": payload}`` -> ``{"id": i,
      "ok": result}`` or ``{"id": i, "err": traceback}``.

    Workers own their (space, backend) — closures never cross the wire,
    only task payloads and JSON results, which is what lets a sweep span
    machines."""

    def __init__(self, addresses: Sequence[str], *,
                 expect: Optional[dict] = None, timeout: float = 30.0):
        if not addresses:
            raise ValueError("RemoteExecutor needs at least one worker "
                             "address")
        self.addresses = list(addresses)
        self.capacity = len(self.addresses)
        self.expect = expect
        self.timeout = timeout
        self._sel = None
        self._workers: Dict[socket.socket, dict] = {}
        self._free: List[socket.socket] = []

    @staticmethod
    def _parse(addr: str) -> Tuple[str, int]:
        host, _, port = addr.rpartition(":")
        return host or "127.0.0.1", int(port)

    @staticmethod
    def _send(sock: socket.socket, msg: dict) -> None:
        sock.sendall(json.dumps(msg).encode() + b"\n")

    @staticmethod
    def _recv_line(sock: socket.socket, buf: bytearray) -> dict:
        """Blocking read of one JSON line (start-time handshake only; task
        replies go through the selector loop in ``poll``)."""
        while b"\n" not in buf:
            chunk = sock.recv(1 << 16)
            if not chunk:
                raise SchedulerError("remote worker closed the connection "
                                     "during handshake")
            buf += chunk
        line, _, rest = bytes(buf).partition(b"\n")
        buf[:] = rest
        return json.loads(line)

    def start(self, runner) -> None:          # runner unused: work ships out
        self._sel = selectors.DefaultSelector()
        for addr in self.addresses:
            host, port = self._parse(addr)
            sock = socket.create_connection((host, port),
                                            timeout=self.timeout)
            sock.settimeout(self.timeout)
            buf = bytearray()
            self._send(sock, {"op": "hello"})
            hello = self._recv_line(sock, buf)
            if "err" in hello:
                raise SchedulerError(
                    f"worker {addr} refused hello: {hello['err']}")
            ident = hello.get("ok", {})
            if self.expect is not None:
                for k, want in self.expect.items():
                    got = ident.get(k)
                    if got != want:
                        raise SchedulerError(
                            f"worker {addr} serves {k}={got!r}, this sweep "
                            f"needs {k}={want!r} — wrong --spec?")
            sock.setblocking(False)
            self._workers[sock] = {"addr": addr, "buf": buf, "ident": ident,
                                   "index": None}
            self._free.append(sock)
            self._sel.register(sock, selectors.EVENT_READ)

    def submit(self, index: int, payload: dict) -> None:
        sock = self._free.pop(0)
        st = self._workers[sock]
        st["index"] = index
        sock.settimeout(self.timeout)       # a wedged worker fails the send
        self._send(sock, {"op": "run", "id": index, "task": payload})
        sock.setblocking(False)

    def poll(self) -> List[Tuple[int, dict]]:
        results: List[Tuple[int, dict]] = []
        busy = any(st["index"] is not None
                   for st in self._workers.values())
        while not results and busy:
            for key, _ in self._sel.select():
                sock = key.fileobj
                st = self._workers.get(sock)
                if st is None:
                    continue
                try:
                    chunk = sock.recv(1 << 16)
                except (BlockingIOError, InterruptedError):
                    continue
                if not chunk:
                    idx = st["index"]
                    self._drop(sock)
                    if idx is not None:
                        results.append((idx, {
                            "err": f"remote worker {st['addr']} died "
                                   f"mid-task"}))
                    continue
                st["buf"] += chunk
                while b"\n" in st["buf"]:
                    line, _, rest = bytes(st["buf"]).partition(b"\n")
                    st["buf"][:] = rest
                    msg = json.loads(line)
                    idx = msg.get("id", st["index"])
                    st["index"] = None
                    self._free.append(sock)
                    out = {"ok": msg["ok"]} if "ok" in msg \
                        else {"err": msg.get("err", "malformed reply")}
                    results.append((idx, out))
            busy = any(s["index"] is not None
                       for s in self._workers.values())
        return results

    def _drop(self, sock) -> None:
        self._sel.unregister(sock)
        self._workers.pop(sock, None)
        if sock in self._free:
            self._free.remove(sock)
        # a dead worker no longer counts toward in-flight capacity; the
        # scheduler raises rather than stall once no capacity remains
        self.capacity = len(self._workers)
        sock.close()

    def close(self) -> None:
        for sock in list(self._workers):
            try:
                sock.close()
            except OSError:
                pass
        self._workers.clear()
        self._free.clear()


# ------------------------------------------------------------- scheduler

class Scheduler:
    """Drives a task queue over an executor, keeping it saturated.

    ``run(specs, prepare=..., on_done=...)`` turns each spec into a
    ``Task``, builds its payload at dispatch time via ``prepare(task)``
    (late binding — this is the mid-sweep statistics-sharing hook), and
    executes them ``executor.capacity`` at a time.  ``on_done(task)``
    fires as each task completes, in completion order.  Returns the full
    task list (submission order) once every task is done; raises
    ``SchedulerError`` on the first failed task."""

    def __init__(self, executor: Executor,
                 runner: Optional[Callable[[dict], dict]] = None):
        self.executor = executor
        self.runner = runner

    def run(self, specs: Sequence[Any], *,
            prepare: Optional[Callable[[Task], dict]] = None,
            on_done: Optional[Callable[[Task], None]] = None) -> List[Task]:
        tasks = [Task(i, spec) for i, spec in enumerate(specs)]
        ex = self.executor
        queue = deque(tasks)
        inflight: Dict[int, Task] = {}
        try:
            ex.start(self.runner)
            while queue or inflight:
                while queue and len(inflight) < ex.capacity:
                    t = queue.popleft()
                    t.payload = prepare(t) if prepare is not None \
                        else t.spec
                    t.state = RUNNING
                    inflight[t.index] = t
                    ex.submit(t.index, t.payload)
                if not inflight:
                    if queue:
                        raise SchedulerError(
                            f"executor has no capacity left with "
                            f"{len(queue)} tasks still pending (all "
                            f"workers lost?)")
                    break
                for idx, out in ex.poll():
                    t = inflight.pop(idx)
                    if "err" in out:
                        t.state = FAILED
                        t.error = out["err"]
                        raise SchedulerError(
                            f"sweep task {t.index} failed:\n{t.error}",
                            task=t)
                    t.state = DONE
                    t.result = out["ok"]
                    if on_done is not None:
                        on_done(t)
        finally:
            ex.close()
        return tasks


def run_tasks(tasks: Sequence[Any], runner: Callable[[Any], dict], *,
              workers: int = 1,
              on_result: Callable[[int, dict], None] = None) -> List[dict]:
    """Historical ``repro.api.parallel.run_tasks`` API over the scheduler:
    run ``runner(task) -> json-able dict`` over every task, ``workers`` at
    a time, returning results in task order; ``on_result(index, res)``
    fires as each result lands."""
    tasks = list(tasks)
    if workers <= 1 or len(tasks) <= 1 or not fork_available():
        executor: Executor = InProcessExecutor()
    else:
        executor = ForkExecutor(min(workers, len(tasks)))

    def on_done(t: Task) -> None:
        if on_result is not None:
            on_result(t.index, t.result)

    done = Scheduler(executor, runner).run(tasks, on_done=on_done)
    return [t.result for t in done]
