"""Chaos harness for the scheduler fleet: seeded, reproducible faults.

Two halves, matching the two places failures originate:

- ``FaultPlan`` — a *worker-side* fault schedule, passed to
  ``python -m repro.api.worker --faults '<json>'``.  Deterministic by
  construction (counter-driven, no clock/randomness), so a chaos run is
  replayable: the Nth task request kills or wedges the worker, a reply is
  delayed / dropped / corrupted on schedule.  The ``marker`` file arms
  the lethal faults exactly once across supervisor restarts — the
  restarted worker finds the marker and runs clean, which is what lets a
  "kill one worker mid-task, supervise it back, finish the sweep" script
  converge.

- ``FaultInjector`` — an *executor wrapper* for in-process chaos: wraps
  any ``Executor`` and sabotages results on a seeded ``random.Random``
  schedule (synthesized worker deaths, corrupted replies, straggler
  delays), so ``Scheduler`` retry/skip paths are testable without
  sockets or subprocesses.  The real result of a killed task is computed
  and then discarded — with a deterministic backend the retried attempt
  reproduces it bit-identically, which is exactly the property the chaos
  tests pin.

Every injected fault is journaled (``FaultInjector.log`` and the
executor event stream), so a failing chaos run states what it broke.
"""

from __future__ import annotations

import os
import random
import time
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .scheduler import Executor


@dataclass
class FaultPlan:
    """Deterministic worker-side fault schedule (all counters 1-based,
    counted over ``run`` requests; ``None`` disables a fault).

    - ``kill_after``:    die (``os._exit(137)``) upon *receiving* the Nth
      task — mid-task from the scheduler's point of view: the request was
      dispatched, no reply will ever come;
    - ``hang_after``:    sleep ``hang_s`` on the Nth task (wedged, not
      dead — exercises the ``RemoteExecutor`` task deadline);
    - ``delay_s``:       straggle every reply by this many seconds;
    - ``drop_after``:    swallow the Nth reply (send nothing);
    - ``corrupt_after``: replace the Nth reply with non-JSON garbage;
    - ``marker``:        filesystem path arming ``kill_after`` /
      ``hang_after`` exactly once: they only fire while the file does not
      exist and create it when they do, so a supervisor-restarted worker
      runs clean.
    """

    kill_after: Optional[int] = None
    hang_after: Optional[int] = None
    delay_s: float = 0.0
    drop_after: Optional[int] = None
    corrupt_after: Optional[int] = None
    hang_s: float = 3600.0
    marker: Optional[str] = None
    _count: int = field(default=0, repr=False)

    @classmethod
    def from_json(cls, d: dict) -> "FaultPlan":
        return cls(**{k: v for k, v in d.items()
                      if not k.startswith("_")})

    def to_json(self) -> dict:
        d = asdict(self)
        d.pop("_count")
        return d

    def _armed(self) -> bool:
        return self.marker is None or not os.path.exists(self.marker)

    def _fire_marker(self) -> None:
        if self.marker is not None:
            with open(self.marker, "w") as f:
                f.write("fired\n")

    def before_task(self) -> None:
        """Called by the worker when a ``run`` request arrives, before
        executing it.  May never return."""
        self._count += 1
        if self.kill_after is not None and self._count == self.kill_after \
                and self._armed():
            self._fire_marker()
            os._exit(137)           # die mid-task: no reply is ever sent
        if self.hang_after is not None and self._count == self.hang_after \
                and self._armed():
            self._fire_marker()
            time.sleep(self.hang_s)  # wedged: socket stays open, silent

    def transform_reply(self, raw: bytes) -> Optional[bytes]:
        """Sabotage one serialized reply line: returns the bytes to send,
        or ``None`` to drop the reply entirely."""
        if self.delay_s > 0:
            time.sleep(self.delay_s)
        if self.drop_after is not None and self._count == self.drop_after:
            return None
        if self.corrupt_after is not None \
                and self._count == self.corrupt_after:
            return b"\x00garbled{{{not json"
        return raw


class FaultInjector(Executor):
    """Wrap any executor and sabotage its results on a seeded schedule.

    Fates are decided per submission (task index + attempt), so a retried
    task rolls fresh dice — and targeted kills (``kill_tasks``) fire once
    per listed index, which makes "kill exactly task K's first attempt"
    scripts deterministic:

    - ``kill``:    the inner result is discarded and replaced by a
      worker-death error (the scheduler sees a died-mid-task worker);
    - ``corrupt``: the inner result is replaced by a corrupt-reply error;
    - ``delay``:   the result is held for ``delay_s`` (straggler).

    Probabilistic fates draw from ``random.Random(seed)`` with
    probabilities ``kill_prob`` / ``corrupt_prob`` / ``delay_prob``;
    ``max_faults`` caps the total number of injected faults so a chaos
    sweep under retries always terminates.  Injected faults are recorded
    in ``self.log`` and emitted as ``chaos_*`` executor events."""

    def __init__(self, inner: Executor, *, seed: int = 0,
                 kill_tasks: Sequence[int] = (),
                 kill_prob: float = 0.0, corrupt_prob: float = 0.0,
                 delay_prob: float = 0.0, delay_s: float = 0.02,
                 max_faults: Optional[int] = None):
        self.inner = inner
        self.rng = random.Random(seed)
        self.kill_tasks = set(kill_tasks)
        self.kill_prob = kill_prob
        self.corrupt_prob = corrupt_prob
        self.delay_prob = delay_prob
        self.delay_s = delay_s
        self.max_faults = max_faults
        self.log: List[dict] = []
        self._killed: Set[int] = set()
        self._fates: Dict[int, Optional[str]] = {}

    # capacity/can_grow mirror the wrapped executor
    @property
    def capacity(self) -> int:
        return self.inner.capacity

    @property
    def can_grow(self) -> bool:
        return self.inner.can_grow

    def _budget_left(self) -> bool:
        return self.max_faults is None or len(self.log) < self.max_faults

    def start(self, runner) -> None:
        self.inner.start(runner)

    def submit(self, index: int, payload: dict) -> None:
        fate = None
        if index in self.kill_tasks and index not in self._killed:
            fate = "kill"
            self._killed.add(index)
        elif self._budget_left():
            r = self.rng.random()
            if r < self.kill_prob:
                fate = "kill"
            elif r < self.kill_prob + self.corrupt_prob:
                fate = "corrupt"
            elif r < self.kill_prob + self.corrupt_prob + self.delay_prob:
                fate = "delay"
        if fate is not None:
            self.log.append({"task": index, "fate": fate})
        self._fates[index] = fate
        self.inner.submit(index, payload)

    def poll(self) -> List[Tuple[int, dict]]:
        out: List[Tuple[int, dict]] = []
        for idx, res in self.inner.poll():
            fate = self._fates.pop(idx, None)
            if fate == "kill" and "ok" in res:
                self._emit(event="chaos_kill", task=idx)
                res = {"err": f"[chaos] worker killed mid-task {idx} "
                              f"(result discarded by FaultInjector)",
                       "worker": "chaos"}
            elif fate == "corrupt" and "ok" in res:
                self._emit(event="chaos_corrupt", task=idx)
                res = {"err": f"[chaos] corrupted reply for task {idx}",
                       "worker": "chaos"}
            elif fate == "delay":
                self._emit(event="chaos_delay", task=idx,
                           delay_s=self.delay_s)
                time.sleep(self.delay_s)
            out.append((idx, res))
        return out

    def drain_events(self) -> List[dict]:
        return self.inner.drain_events() + super().drain_events()

    def close(self) -> None:
        self.inner.close()
