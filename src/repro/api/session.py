"""The unified autotuning front-end.

One declarative entry point over every tuning path in the repo::

    from repro.api import AutotuneSession, SimBackend
    from repro.linalg.studies import search_space

    session = AutotuneSession(search_space("capital-cholesky"),
                              backend=SimBackend(),
                              policy="eager", tolerance=0.25)
    result = session.run()            # -> StudyResult

- ``space``    a ``SearchSpace`` (what is tuned);
- ``backend``  a ``Backend`` (how a configuration is measured): sim,
               wall clock, or dry run;
- ``policy`` / ``tolerance``  the paper's selective-execution policy and
               confidence tolerance;
- ``search``   ``"exhaustive"`` (paper protocol) or ``"racing"``
               (CI-driven successive elimination).

``run`` measures one (policy, tolerance) study.  ``sweep`` runs the
paper's policy x tolerance measurement grid, optionally process-parallel
(``workers=N``; fork-based, bit-identical to the serial run, merged in
deterministic task order) and optionally checkpointed (``checkpoint=
path``: completed sweep points — and completed configurations inside a
resumable exhaustive study — are journaled to JSON and skipped on
re-run, so long paper-scale sweeps survive interruption).

Cross-study transfer (``repro.api.transfer``): ``collect_stats=True``
attaches the study's per-kernel statistics bank to
``StudyResult.extra["kernel_stats"]``; ``prior=bank`` (optionally
weakened by ``prior_discount``) seeds a later session's models from it,
so already-confident kernels start in the skip regime.  A warm study's
exported bank folds the transferred prior back in exactly once —
measured evidence is harvested prior-free across model resets
(``transfer.Harvest``), so chained warm-starts do not compound
transferred confidence.  A study resumed mid-way from a checkpoint
exports no bank (the journaled configurations never fed its models).
Priors fingerprint into checkpoint keys: journaled warm results are
never replayed as cold ones (or under a different bank).
"""

from __future__ import annotations

import itertools
import json
import os
import time
from dataclasses import replace
from typing import Any, Dict, List, Optional, Sequence, Union

from repro.core.policies import Policy, policy as make_policy

from . import search as _search
from .backends import Backend
from .parallel import run_tasks
from .result import StudyResult
from .serialize import dumps_canonical
from .space import SearchSpace

_DRIVERS = {"exhaustive": _search.exhaustive, "racing": _search.racing}


class AutotuneSession:
    """A tuning study bound to a space, a backend, and a protocol."""

    def __init__(self, space: SearchSpace, backend: Backend, *,
                 policy: Union[str, Policy] = "conditional",
                 tolerance: Optional[float] = None,
                 search: str = "exhaustive", trials: int = 3,
                 seed: int = 0, allocation: int = 0,
                 search_options: Optional[dict] = None,
                 prior=None, prior_discount: float = 0.5,
                 collect_stats: bool = False,
                 **policy_kwargs):
        if search not in _DRIVERS:
            raise ValueError(f"unknown search {search!r}; "
                             f"want one of {tuple(_DRIVERS)}")
        self.space = space
        self.backend = backend
        self.search = search
        self.trials = trials
        self.seed = seed
        self.allocation = allocation
        self.search_options = dict(search_options or {})
        # cross-study transfer: the discount is applied once, here, so the
        # checkpoint fingerprint below reflects the evidence actually
        # seeded; an empty (or None) prior is exactly a cold session
        self.prior = prior.discounted(prior_discount) \
            if prior is not None and len(prior) else None
        self.collect_stats = bool(collect_stats)
        if isinstance(policy, Policy):
            self._base_policy = policy if tolerance is None \
                else replace(policy, tolerance=tolerance)
        else:
            self._base_policy = make_policy(
                policy, tolerance=0.25 if tolerance is None else tolerance,
                **policy_kwargs)

    # -- policy resolution ---------------------------------------------------

    def _policy(self, name: Optional[str] = None,
                tolerance: Optional[float] = None) -> Policy:
        pol = self._base_policy
        if name is not None and name != pol.name:
            # carry every other policy field (min_samples, vote fraction,
            # extrapolate) across the sweep grid — a sweep must compare
            # policies under one statistical setting
            pol = replace(pol, name=name)
        if tolerance is not None:
            pol = replace(pol, tolerance=tolerance)
        return pol

    # -- one study -----------------------------------------------------------

    def _key(self, pol: Policy, seed: int, allocation: int) -> dict:
        key = {"space": self.space.name, "n_points": len(self.space),
               "backend": self.backend.fingerprint(),
               "policy": pol.name,
               "tolerance": pol.tolerance, "trials": self.trials,
               "search": self.search, "seed": seed,
               "allocation": allocation}
        # only non-default transfer settings enter the key, so existing
        # cold checkpoints keep resolving under their original identity
        if self.prior is not None:
            key["prior"] = self.prior.fingerprint()
        if self.collect_stats:
            key["collect_stats"] = True
        return key

    def _run_one(self, pol: Policy, seed: int, allocation: int, *,
                 checkpoint: Optional["_Checkpoint"] = None) -> StudyResult:
        t0 = time.time()
        run = self.backend.open(self.space, pol, seed=seed,
                                allocation=allocation, prior=self.prior)
        driver = _DRIVERS[self.search]
        opts = dict(self.search_options)
        key = self._key(pol, seed, allocation)
        start = None
        if checkpoint is not None and self.search == "exhaustive" \
                and self.space.should_reset(pol):
            # per-configuration journaling is protocol-safe only when
            # statistics reset between configurations: a fresh backend at
            # point k is then in the same state as one that measured
            # points 0..k-1 — up to the backend's carry state (the sim
            # RNG stream), journaled with every record and restored here
            # (anything else resumes whole studies only)
            start, carry = checkpoint.partial(key)
            if start:
                run.restore_carry(carry)
            opts["start_records"] = start
            opts["on_record"] = lambda rec: checkpoint.add_record(
                key, rec, run.carry_state())
        records, extra = driver(run, self.space, pol, trials=self.trials,
                                **opts)
        if self.collect_stats and not start:
            # configurations replayed from a checkpoint journal never fed
            # this run's models, so a resumed study cannot export the full
            # posterior — omit the bank rather than present a partial one
            # (resume the study without collect_stats, or re-run cold, to
            # obtain a complete bank)
            bank = run.export_stats()
            if bank is not None:
                extra = dict(extra)
                extra["kernel_stats"] = bank
        result = StudyResult(
            study=self.space.name, policy=pol.name,
            tolerance=pol.tolerance, records=records,
            full_tuning_time=sum(r.full_cost for r in records),
            selective_tuning_time=sum(r.selective_cost for r in records),
            backend=self.backend.name, search=self.search, seed=seed,
            allocation=allocation, wall_s=round(time.time() - t0, 3),
            extra=extra)
        return result

    def run(self, *, checkpoint: Optional[str] = None) -> StudyResult:
        """Run the study; with ``checkpoint``, resume a partial one."""
        pol = self._policy()
        if checkpoint is None:
            return self._run_one(pol, self.seed, self.allocation)
        ck = _Checkpoint(checkpoint)
        key = self._key(pol, self.seed, self.allocation)
        done = ck.result_for(key)
        if done is not None:
            return done
        result = self._run_one(pol, self.seed, self.allocation,
                               checkpoint=ck)
        ck.add_result(key, result)
        return result

    # -- policy x tolerance sweeps -------------------------------------------

    def sweep(self, *, policies: Optional[Sequence[str]] = None,
              tolerances: Optional[Sequence[float]] = None,
              seeds: Sequence[int] = (0,),
              allocations: Sequence[int] = (0,),
              workers: int = 1,
              checkpoint: Optional[str] = None) -> List[StudyResult]:
        """The paper's measurement grid (§VI.A): one independent study per
        (policy, tolerance, seed, allocation), merged in grid order."""
        policies = list(policies) if policies is not None \
            else [self._base_policy.name]
        tolerances = list(tolerances) if tolerances is not None \
            else [self._base_policy.tolerance]
        grid = list(itertools.product(policies, tolerances, seeds,
                                      allocations))
        ck = _Checkpoint(checkpoint) if checkpoint else None

        results: List[Optional[StudyResult]] = [None] * len(grid)
        todo = []
        for i, spec in enumerate(grid):
            pol = self._policy(spec[0], spec[1])
            done = ck.result_for(self._key(pol, spec[2], spec[3])) \
                if ck else None
            if done is not None:
                results[i] = done
            else:
                todo.append((i, spec))

        if not getattr(self.backend, "parallel_safe", True):
            workers = 1       # jax/wall-clock backends measure serially

        # serial execution journals inside each study too (per-config
        # records survive a kill mid-study); forked children cannot share
        # the journal file, so parallel sweeps checkpoint whole points
        inflight_ck = ck if workers <= 1 else None

        def runner(spec) -> dict:
            pol = self._policy(spec[0], spec[1])
            return self._run_one(pol, spec[2], spec[3],
                                 checkpoint=inflight_ck).to_json()

        def land(j: int, res: dict) -> None:
            i = todo[j][0]
            results[i] = StudyResult.from_json(res)
            if ck:
                pol = self._policy(*todo[j][1][:2])
                ck.add_result(self._key(pol, *todo[j][1][2:]), results[i])

        run_tasks([spec for _, spec in todo], runner, workers=workers,
                  on_result=land)
        return list(results)


# ----------------------------------------------------------------- journal

class _Checkpoint:
    """JSON journal of completed studies / configuration records.

    One file holds a dict keyed by the study key's canonical JSON:
    ``{"results": {key: result_json},
       "records": {key: {"recs": [record_json], "carry": state}}}``.
    Writes are atomic (tmp + rename) after every landed unit, so a killed
    sweep loses at most the in-flight measurement.
    """

    def __init__(self, path: str):
        self.path = path
        self._data: Dict[str, Any] = {"results": {}, "records": {}}
        if os.path.exists(path):
            with open(path) as f:
                loaded = json.load(f)
            if not isinstance(loaded, dict) or "results" not in loaded:
                raise ValueError(f"{path}: not a session checkpoint file")
            self._data = loaded
            self._data.setdefault("records", {})

    @staticmethod
    def _k(key: dict) -> str:
        # one canonical identity string per key (shared with bank
        # fingerprints); tolerates tuples/NumPy scalars in key values
        return dumps_canonical(key)

    def _flush(self) -> None:
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self._data, f)
        os.replace(tmp, self.path)

    def result_for(self, key: dict) -> Optional[StudyResult]:
        got = self._data["results"].get(self._k(key))
        return StudyResult.from_json(got) if got is not None else None

    def add_result(self, key: dict, result: StudyResult) -> None:
        k = self._k(key)
        self._data["results"][k] = result.to_json()
        self._data["records"].pop(k, None)   # subsumed by the full result
        self._flush()

    def partial(self, key: dict):
        """(records-so-far, carry-state-after-the-last-one)."""
        from .result import ConfigRecord
        got = self._data["records"].get(self._k(key))
        if not got:
            return [], None
        return ([ConfigRecord.from_json(r) for r in got["recs"]],
                got.get("carry"))

    def add_record(self, key: dict, record, carry=None) -> None:
        entry = self._data["records"].setdefault(
            self._k(key), {"recs": [], "carry": None})
        entry["recs"].append(record.to_json())
        entry["carry"] = carry
        self._flush()
