"""The unified autotuning front-end.

One declarative entry point over every tuning path in the repo::

    from repro.api import AutotuneSession, SimBackend
    from repro.linalg.studies import search_space

    session = AutotuneSession(search_space("capital-cholesky"),
                              backend=SimBackend(),
                              policy="eager", tolerance=0.25)
    result = session.run()            # -> StudyResult

- ``space``    a ``SearchSpace`` (what is tuned);
- ``backend``  a ``Backend`` (how a configuration is measured): sim,
               wall clock, or dry run;
- ``policy`` / ``tolerance``  the paper's selective-execution policy and
               confidence tolerance;
- ``search``   ``"exhaustive"`` (paper protocol) or ``"racing"``
               (CI-driven successive elimination).

``run`` measures one (policy, tolerance) study.  ``sweep`` runs the
paper's policy x tolerance measurement grid through the
``repro.api.scheduler`` work queue: every sweep point is a task with
explicit state, executed on a pluggable executor — in-process (serial),
fork-pool (``workers=N``; bit-identical to the serial run, merged in
grid order), or remote socket workers (``executor=RemoteExecutor([...])``
over ``python -m repro.api.worker`` processes) — and optionally
checkpointed (``checkpoint=path``: completed sweep points — and completed
configurations inside a resumable exhaustive study — are journaled to
JSON and skipped on re-run, so long paper-scale sweeps survive
interruption).

``sweep(share_stats=True)`` streams each completed task's statistics bank
into a shared prior, so sweep points dispatched later warm-start
mid-sweep (already-confident kernels start in the skip regime; eager
pre-switches them off machine-wide).  Shared results depend on completion
order and are journaled under a ``shared_stats`` key; pass
``deterministic=True`` to defer sharing to checkpoint boundaries instead:
tasks of one invocation all run from the bank the checkpoint held at
start (none on the first run — bit-identical to the cold serial driver),
and the banks they harvest only seed the *next* invocation.

Cross-study transfer (``repro.api.transfer``): ``collect_stats=True``
attaches the study's per-kernel statistics bank to
``StudyResult.extra["kernel_stats"]``; ``prior=bank`` (optionally
weakened by ``prior_discount``) seeds a later session's models from it,
so already-confident kernels start in the skip regime.  A warm study's
exported bank folds the transferred prior back in exactly once —
measured evidence is harvested prior-free across model resets
(``transfer.Harvest``), so chained warm-starts do not compound
transferred confidence.  A study resumed mid-way from a checkpoint
exports no bank (the journaled configurations never fed its models).
Priors fingerprint into checkpoint keys: journaled warm results are
never replayed as cold ones (or under a different bank).
"""

from __future__ import annotations

import itertools
import json
import os
import tempfile
import time
import zlib
from dataclasses import asdict, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.core.policies import Policy, policy as make_policy

from . import search as _search
from .backends import Backend
from .result import StudyResult
from .scheduler import (FAILED, Executor, ForkExecutor, InProcessExecutor,
                        Scheduler, Task, fork_available)
from .serialize import dumps_canonical
from .space import SearchSpace

_DRIVERS = {"exhaustive": _search.exhaustive, "racing": _search.racing,
            "model_guided": _search.model_guided}

#: sentinel distinguishing "use the session default" from an explicit None
_UNSET = object()


class AutotuneSession:
    """A tuning study bound to a space, a backend, and a protocol."""

    def __init__(self, space: SearchSpace, backend: Backend, *,
                 policy: Union[str, Policy] = "conditional",
                 tolerance: Optional[float] = None,
                 search: str = "exhaustive", trials: int = 3,
                 seed: int = 0, allocation: int = 0,
                 search_options: Optional[dict] = None,
                 prior=None, prior_discount: float = 0.5,
                 prior_max_cv: Optional[float] = None,
                 collect_stats: bool = False,
                 **policy_kwargs):
        if search not in _DRIVERS:
            raise ValueError(f"unknown search {search!r}; "
                             f"want one of {tuple(_DRIVERS)}")
        self.space = space
        self.backend = backend
        self.search = search
        self.trials = trials
        self.seed = seed
        self.allocation = allocation
        # JSON-normalized once, here, so scheduler task payloads ship the
        # options verbatim (model-guided banks/models become their JSON)
        self.search_options = _search.normalize_options(
            search, dict(search_options or {}))
        # cross-study transfer: the per-key quality filter and the discount
        # are applied once, here, so the checkpoint fingerprint below
        # reflects the evidence actually seeded; an empty (or None) prior
        # is exactly a cold session.  ``prior_max_cv`` drops bank entries
        # whose dispersion betrays a pooled mixture (byte-bucketed comm
        # keys pooling several configurations' message sizes) — see
        # ``StatisticsBank.filtered``.
        if prior is not None and prior_max_cv is not None:
            prior = prior.filtered(max_cv=prior_max_cv)
        self.prior = prior.discounted(prior_discount) \
            if prior is not None and len(prior) else None
        self.prior_discount = prior_discount
        self.prior_max_cv = prior_max_cv
        self.collect_stats = bool(collect_stats)
        #: recovery events of the most recent sweep (retries, worker
        #: loss/join, deadlines) — also journaled to the checkpoint
        self.last_sweep_events: List[dict] = []
        if isinstance(policy, Policy):
            self._base_policy = policy if tolerance is None \
                else replace(policy, tolerance=tolerance)
        else:
            self._base_policy = make_policy(
                policy, tolerance=0.25 if tolerance is None else tolerance,
                **policy_kwargs)

    # -- policy resolution ---------------------------------------------------

    def _policy(self, name: Optional[str] = None,
                tolerance: Optional[float] = None) -> Policy:
        pol = self._base_policy
        if name is not None and name != pol.name:
            # carry every other policy field (min_samples, vote fraction,
            # extrapolate) across the sweep grid — a sweep must compare
            # policies under one statistical setting
            pol = replace(pol, name=name)
        if tolerance is not None:
            pol = replace(pol, tolerance=tolerance)
        return pol

    # -- one study -----------------------------------------------------------

    def _key(self, pol: Policy, seed: int, allocation: int, *,
             prior=_UNSET, collect=None, shared=False) -> dict:
        if prior is _UNSET:
            prior = self.prior
        if collect is None:
            collect = self.collect_stats
        key = {"space": self.space.name, "n_points": len(self.space),
               "backend": self.backend.fingerprint(),
               "policy": pol.name,
               "tolerance": pol.tolerance, "trials": self.trials,
               "search": self.search, "seed": seed,
               "allocation": allocation}
        if self.search_options:
            # driver options change what a study measures (racing rounds,
            # model-guided banks/seed/coverage): journaled results must
            # never be replayed across different options.  Fingerprinted —
            # a bank in the options would otherwise bloat every key.
            key["search_options"] = "opts:%08x" % zlib.crc32(
                dumps_canonical(self.search_options).encode())
        # only non-default transfer settings enter the key, so existing
        # cold checkpoints keep resolving under their original identity
        if shared:
            # statistics-sharing sweeps: the prior a task ran under depends
            # on completion order (live mode) or on which invocation first
            # dispatched it (deterministic mode), so shared results carry a
            # mode marker (True | "deterministic") instead of a bank
            # fingerprint — resumption reuses them, and the key still
            # prevents replaying them as cold results (or across modes)
            key["shared_stats"] = shared
        elif prior is not None:
            key["prior"] = prior.fingerprint()
        if collect:
            key["collect_stats"] = True
        return key

    def _run_one(self, pol: Policy, seed: int, allocation: int, *,
                 checkpoint: Optional["_Checkpoint"] = None,
                 prior=_UNSET, collect=None, shared=False) -> StudyResult:
        if prior is _UNSET:
            prior = self.prior
        if collect is None:
            collect = self.collect_stats
        t0 = time.time()
        run = self.backend.open(self.space, pol, seed=seed,
                                allocation=allocation, prior=prior)
        driver = _DRIVERS[self.search]
        opts = dict(self.search_options)
        key = self._key(pol, seed, allocation, prior=prior,
                        collect=collect, shared=shared)
        start = None
        if checkpoint is not None and not shared \
                and self.search == "exhaustive" \
                and self.space.should_reset(pol):
            # per-configuration journaling is protocol-safe only when
            # statistics reset between configurations: a fresh backend at
            # point k is then in the same state as one that measured
            # points 0..k-1 — up to the backend's carry state (the sim
            # RNG stream), journaled with every record and restored here
            # (anything else resumes whole studies only).  Mid-sweep-shared
            # tasks never journal partial records: a re-dispatched task may
            # run under a different evolved prior than the killed one.
            start, carry = checkpoint.partial(key)
            if start:
                run.restore_carry(carry)
            opts["start_records"] = start
            opts["on_record"] = lambda rec: checkpoint.add_record(
                key, rec, run.carry_state())
        if self.search == "model_guided":
            if prior is not None and "banks" not in opts \
                    and "model" not in opts:
                # the seeded prior doubles as the candidate model unless
                # the caller supplied explicit banks — mid-sweep shared
                # statistics thereby sharpen later tasks' samplers, not
                # just their skip regimes
                opts["banks"] = [prior.to_json()]
            if checkpoint is not None and not shared:
                # the candidate selection (survivor set + post-selection
                # sampler RNG) is journaled so a killed-and-resumed study
                # re-races the same survivors without re-consuming sampler
                # draws — bit-identical to the uninterrupted driver
                st = checkpoint.search_state(key)
                if st is not None:
                    opts["start_state"] = st
                opts["on_state"] = \
                    lambda s: checkpoint.add_search_state(key, s)
        records, extra = driver(run, self.space, pol, trials=self.trials,
                                **opts)
        if collect and not start:
            # configurations replayed from a checkpoint journal never fed
            # this run's models, so a resumed study cannot export the full
            # posterior — omit the bank rather than present a partial one
            # (resume the study without collect_stats, or re-run cold, to
            # obtain a complete bank)
            bank = run.export_stats()
            if bank is not None:
                extra = dict(extra)
                extra["kernel_stats"] = bank
        cache_info = run.cache_info()
        if cache_info is not None:
            # program-cache provenance: per-point structural fingerprints
            # plus this task's hit/miss/recording counters, so the nightly
            # drift gate can attribute changes to code vs cached artifact
            extra = dict(extra)
            extra["program_cache"] = cache_info
        result = StudyResult(
            study=self.space.name, policy=pol.name,
            tolerance=pol.tolerance, records=records,
            full_tuning_time=sum(r.full_cost for r in records),
            selective_tuning_time=sum(r.selective_cost for r in records),
            backend=self.backend.name, search=self.search, seed=seed,
            allocation=allocation, wall_s=round(time.time() - t0, 3),
            extra=extra)
        return result

    def run(self, *, checkpoint: Optional[str] = None) -> StudyResult:
        """Run the study; with ``checkpoint``, resume a partial one."""
        pol = self._policy()
        if checkpoint is None:
            return self._run_one(pol, self.seed, self.allocation)
        ck = _Checkpoint(checkpoint)
        key = self._key(pol, self.seed, self.allocation)
        done = ck.result_for(key)
        if done is not None:
            return done
        result = self._run_one(pol, self.seed, self.allocation,
                               checkpoint=ck)
        ck.add_result(key, result)
        return result

    # -- policy x tolerance sweeps -------------------------------------------

    def _task_payload(self, spec, prior, *, collect: bool,
                      shared) -> dict:
        """The JSON-able task message executors ship (see ``run_payload``:
        self-describing, so a remote worker reconstructs the exact study
        from it and its own (space, backend))."""
        payload = {"policy": asdict(self._policy(spec[0], spec[1])),
                   "seed": spec[2], "allocation": spec[3],
                   "search": self.search, "trials": self.trials,
                   "search_options": self.search_options,
                   "prior": prior.to_json() if prior is not None else None,
                   "collect": collect, "shared": shared}
        fps = getattr(self.backend, "point_fingerprints", None)
        if fps is not None:
            # structural fingerprints of the points this task will measure:
            # a worker holding a program under the same fingerprint replays
            # it instead of re-recording, and a worker computing a
            # DIFFERENT fingerprint for the same point name refuses the
            # task loudly (geometry drift between dispatcher and worker)
            fps = fps(self.space)
            if fps:
                payload["program_fingerprints"] = fps
        return payload

    def _select_executor(self, workers: int, n_tasks: int) -> Executor:
        if workers > 1 and n_tasks > 1 and fork_available() \
                and getattr(self.backend, "parallel_safe", True):
            return ForkExecutor(min(workers, n_tasks))
        # jax/wall-clock backends measure serially regardless of workers
        return InProcessExecutor()

    def sweep(self, *, policies: Optional[Sequence[str]] = None,
              tolerances: Optional[Sequence[float]] = None,
              seeds: Sequence[int] = (0,),
              allocations: Sequence[int] = (0,),
              workers: int = 1,
              checkpoint: Optional[str] = None,
              executor: Optional[Executor] = None,
              share_stats: bool = False,
              deterministic: bool = False,
              max_retries: int = 0,
              retry_backoff: float = 0.25,
              on_failure: str = "raise",
              driver: Optional[str] = None) -> List[StudyResult]:
        """The paper's measurement grid (§VI.A): one independent study per
        (policy, tolerance, seed, allocation), scheduled as tasks on an
        executor (``workers`` forks; pass ``executor=`` for remote
        workers) and merged in grid order.

        ``share_stats=True`` streams completed tasks' statistics banks
        into a shared prior seeding later-dispatched tasks mid-sweep;
        ``deterministic=True`` defers that sharing to checkpoint
        boundaries (tasks only warm-start from banks a *previous*
        invocation persisted to the checkpoint), keeping each invocation
        bit-identical to the serial driver under the same seed bank.

        ``driver`` overrides the session's search for this sweep only
        (``sweep(driver="model_guided")``): sampled-candidate sweeps ride
        the same checkpointing, mid-sweep statistics sharing, and
        fork/remote executors as exhaustive ones — the sampler seed ships
        in each task payload and its post-selection RNG state is journaled
        with the study, so killed-and-resumed or fork-dispatched sweeps
        stay bit-identical to the serial driver.

        Failure semantics (fleet sweeps): a failed sweep point (worker
        death, task deadline, task exception) is retried up to
        ``max_retries`` times with exponential backoff
        (``retry_backoff * 2**(n-1)`` seconds); the retried task's payload
        is rebuilt at re-dispatch, so deterministic sweeps stay
        bit-identical to the serial driver.  When retries are exhausted,
        ``on_failure="raise"`` (default) raises ``SchedulerError`` with
        the full attempt history, while ``on_failure="skip"`` leaves that
        grid slot ``None`` in the returned list and journals the failure
        (with its attempt history) into the checkpoint — a later
        invocation with the same checkpoint re-attempts exactly the
        failed points.  Every recovery event (retry, worker loss/join,
        deadline, heartbeat timeout) is journaled into the checkpoint's
        ``events`` list and kept on ``self.last_sweep_events``; a result
        that needed retries carries them in
        ``StudyResult.extra["recovery"]``, so downstream drift analysis
        can attribute anomalies to infrastructure."""
        if driver is not None and driver != self.search:
            # sweep-scoped search override (sweep(driver="model_guided")):
            # the study key and task payloads both read self.search, so
            # rebind it (and re-normalize options for the new driver) for
            # the duration of this sweep only
            if driver not in _DRIVERS:
                raise ValueError(f"unknown search {driver!r}; "
                                 f"want one of {tuple(_DRIVERS)}")
            prev, prev_opts = self.search, self.search_options
            self.search = driver
            self.search_options = _search.normalize_options(
                driver, dict(prev_opts))
            try:
                return self.sweep(
                    policies=policies, tolerances=tolerances, seeds=seeds,
                    allocations=allocations, workers=workers,
                    checkpoint=checkpoint, executor=executor,
                    share_stats=share_stats, deterministic=deterministic,
                    max_retries=max_retries, retry_backoff=retry_backoff,
                    on_failure=on_failure)
            finally:
                self.search, self.search_options = prev, prev_opts
        policies = list(policies) if policies is not None \
            else [self._base_policy.name]
        tolerances = list(tolerances) if tolerances is not None \
            else [self._base_policy.tolerance]
        grid = list(itertools.product(policies, tolerances, seeds,
                                      allocations))
        ck = _Checkpoint(checkpoint) if checkpoint else None
        shared = _SharedStats(self, ck, frozen=deterministic) \
            if share_stats else None
        shared_mode = False if not share_stats \
            else ("deterministic" if deterministic else True)
        # mid-sweep sharing needs every task to harvest a bank; the bank is
        # stripped from results again unless the caller asked for it
        collect = self.collect_stats or share_stats

        results: List[Optional[StudyResult]] = [None] * len(grid)
        keys: List[dict] = []
        todo: List[Tuple[int, tuple]] = []
        for i, spec in enumerate(grid):
            pol = self._policy(spec[0], spec[1])
            key = self._key(pol, spec[2], spec[3],
                            collect=collect, shared=shared_mode)
            keys.append(key)
            done = ck.result_for(key) if ck else None
            if done is not None:
                results[i] = done
            else:
                todo.append((i, spec))

        if executor is None:
            executor = self._select_executor(workers, len(todo))
        # serial in-process execution journals inside each study too
        # (per-config records survive a kill mid-study); forked/remote
        # workers cannot share the journal file, so those checkpoint whole
        # points; _run_one additionally refuses partial journaling for
        # live-shared tasks (the re-dispatch prior may differ)
        inflight_ck = ck if isinstance(executor, InProcessExecutor) \
            else None

        def prepare(task: Task) -> dict:
            _, spec = task.spec
            prior = shared.current() if shared else self.prior
            return self._task_payload(spec, prior, collect=collect,
                                      shared=shared_mode)

        def runner(payload: dict) -> dict:
            return run_payload(self.space, self.backend, payload,
                               checkpoint=inflight_ck,
                               session=self)

        events: List[dict] = []

        def on_event(ev: dict) -> None:
            events.append(ev)
            if ck:
                ck.add_event(ev)

        def on_done(task: Task) -> None:
            i, _ = task.spec
            res = task.result
            pc = res.get("extra", {}).get("program_cache")
            if pc:
                # journal the task's program-cache counters: summing
                # ``recordings`` across a sweep's events shows how many
                # structural passes actually ran (the record-once-per-
                # geometry acceptance counter: N tasks -> N_unique)
                on_event({"event": "program_cache", "task": i,
                          "hits": pc.get("hits", 0),
                          "misses": pc.get("misses", 0),
                          "recordings": pc.get("recordings", 0)})
            bank_json = res.get("extra", {}).get("kernel_stats")
            if shared is not None:
                shared.add(bank_json)
            if collect and not self.collect_stats and bank_json:
                res["extra"].pop("kernel_stats", None)
            if task.attempts:
                # infrastructure provenance: this point only succeeded
                # after recovery — surfaced so drift analysis can tell
                # fleet trouble from protocol change
                res.setdefault("extra", {})["recovery"] = {
                    "retries": len(task.attempts),
                    "attempts": task.attempts}
            results[i] = StudyResult.from_json(res)
            if ck:
                ck.add_result(keys[i], results[i])

        done = Scheduler(executor, runner, max_retries=max_retries,
                         retry_backoff=retry_backoff,
                         on_failure=on_failure,
                         on_event=on_event).run(todo, prepare=prepare,
                                                on_done=on_done)
        # on_failure="skip": exhausted points stay None in the merged list
        # and their attempt histories are journaled, so a resumed sweep
        # re-attempts exactly these
        for task in done:
            if task.state == FAILED:
                i, _ = task.spec
                if ck:
                    ck.add_failure(keys[i], task.attempts)
        self.last_sweep_events = events
        return list(results)


# ------------------------------------------------------------ task runner

def run_payload(space: SearchSpace, backend: Backend, payload: dict, *,
                checkpoint: Optional["_Checkpoint"] = None,
                session: Optional[AutotuneSession] = None) -> dict:
    """Execute one scheduler task payload (``AutotuneSession._task_payload``
    shape) against a (space, backend) pair, returning the study-result
    JSON.  This is the single task-execution entry point shared by the
    in-process/fork runners (which pass their live ``session``) and the
    remote worker (which builds a fresh, equivalent session from the
    payload — it is self-describing: full policy fields, search, trials,
    prior bank, transfer flags)."""
    pol = Policy(**payload["policy"])
    sent = payload.get("program_fingerprints")
    if sent:
        # geometry-drift guard: the dispatcher's structural fingerprints
        # must match what this (space, backend) computes for the same
        # point names — a mismatch means the two sides hold different
        # geometries under one name, and a cached program replayed across
        # that divide would be silently wrong
        mine = getattr(backend, "point_fingerprints", lambda s: None)(space)
        if mine:
            drift = {name: (fp, mine[name]) for name, fp in sent.items()
                     if name in mine and mine[name] != fp}
            if drift:
                detail = ", ".join(
                    f"{name}: dispatcher {theirs} vs worker {ours}"
                    for name, (theirs, ours) in sorted(drift.items())[:4])
                raise ValueError(
                    f"program fingerprint mismatch on {len(drift)} "
                    f"point(s) of space {space.name!r} ({detail}); "
                    f"refusing to measure a drifted geometry")
    if session is None:
        session = AutotuneSession(
            space, backend, policy=pol,
            search=payload.get("search", "exhaustive"),
            trials=payload.get("trials", 3),
            search_options=payload.get("search_options"))
    prior = None
    if payload.get("prior"):
        from .transfer import StatisticsBank
        bank = StatisticsBank.from_json(payload["prior"])
        prior = bank if len(bank) else None
    return session._run_one(
        pol, payload["seed"], payload["allocation"], checkpoint=checkpoint,
        prior=prior, collect=payload.get("collect", False),
        shared=payload.get("shared", False)).to_json()


class _SharedStats:
    """Mid-sweep statistics sharing: the accumulator completed tasks feed
    and later dispatches seed from.

    ``add`` merges a completed task's harvested bank into the running
    accumulator and persists it to the checkpoint (``shared_bank`` entry),
    so a killed sweep resumes with the shared prior rebuilt.  ``current``
    assembles the dispatch prior: the accumulator — filtered by the
    session's ``prior_max_cv`` and weakened by its ``prior_discount``,
    exactly like a static ``prior=`` bank — merged over the session's own
    static prior.  With ``frozen=True`` (``deterministic`` sweeps) the
    dispatch prior is pinned to the accumulator state loaded at
    construction (the checkpoint boundary); completions still accumulate
    and persist, but only seed the *next* invocation."""

    def __init__(self, session: AutotuneSession,
                 ck: Optional["_Checkpoint"], *, frozen: bool):
        from .transfer import StatisticsBank
        self._session = session
        self._ck = ck
        self._frozen = frozen
        loaded = ck.shared_bank() if ck else None
        self._acc = loaded if loaded is not None else StatisticsBank()
        self._seed_prior = self._assemble(self._acc)

    def _assemble(self, bank):
        s = self._session
        if not bank:
            return s.prior
        if s.prior_max_cv is not None:
            bank = bank.filtered(max_cv=s.prior_max_cv)
        bank = bank.discounted(s.prior_discount)
        if not bank:
            return s.prior
        return s.prior.merge(bank) if s.prior is not None else bank

    def current(self):
        """The prior a task dispatched right now seeds from."""
        return self._seed_prior if self._frozen else self._assemble(
            self._acc)

    def add(self, bank_json: Optional[dict]) -> None:
        if not bank_json:
            return                  # task harvested nothing (e.g. dry run)
        from .transfer import StatisticsBank
        self._acc = self._acc.merge(StatisticsBank.from_json(bank_json))
        if self._ck is not None:
            self._ck.set_shared_bank(self._acc)


# ----------------------------------------------------------------- journal

class _Checkpoint:
    """JSON journal of completed studies / configuration records.

    One file holds a dict keyed by the study key's canonical JSON:
    ``{"results": {key: result_json},
       "records": {key: {"recs": [record_json], "carry": state}},
       "search_state": {key: selection_json},
       "shared_bank": bank_json,
       "failures": {key: {"attempts": [...]}},
       "events": [event, ...]}`` — ``search_state`` is a model-guided
    study's journaled candidate selection (survivor set, roofline prunes,
    post-selection sampler RNG state, space order fingerprint), cleared
    when the study's result lands; ``shared_bank`` is the accumulated
    mid-sweep statistics bank of ``share_stats`` sweeps, so a resumed
    sweep restores the shared prior its killed predecessor had earned;
    ``failures`` are sweep points whose retries were exhausted under
    ``on_failure="skip"`` (kept with their attempt history; a completed
    re-attempt supersedes the entry) and ``events`` is the recovery
    journal (retries, worker loss/join/restart, timeouts).

    Writes are crash-safe: each flush serializes into a uniquely-named
    temp file in the destination directory, fsyncs it, and atomically
    ``os.replace``s it into place — a worker/driver killed mid-write can
    never leave a truncated journal that blocks resume, and concurrent
    flushers cannot trample each other's temp file.
    """

    def __init__(self, path: str):
        self.path = path
        self._data: Dict[str, Any] = {"results": {}, "records": {}}
        if os.path.exists(path):
            with open(path) as f:
                loaded = json.load(f)
            if not isinstance(loaded, dict) or "results" not in loaded:
                raise ValueError(f"{path}: not a session checkpoint file")
            self._data = loaded
            self._data.setdefault("records", {})

    @staticmethod
    def _k(key: dict) -> str:
        # one canonical identity string per key (shared with bank
        # fingerprints); tolerates tuples/NumPy scalars in key values
        return dumps_canonical(key)

    def _flush(self) -> None:
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(
            prefix=os.path.basename(self.path) + ".", suffix=".tmp", dir=d)
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self._data, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def result_for(self, key: dict) -> Optional[StudyResult]:
        got = self._data["results"].get(self._k(key))
        return StudyResult.from_json(got) if got is not None else None

    def add_result(self, key: dict, result: StudyResult) -> None:
        k = self._k(key)
        self._data["results"][k] = result.to_json()
        self._data["records"].pop(k, None)   # subsumed by the full result
        self._data.get("search_state", {}).pop(k, None)
        # a completed re-attempt supersedes a journaled failure
        self._data.get("failures", {}).pop(k, None)
        self._flush()

    def add_failure(self, key: dict, attempts: List[dict]) -> None:
        """Journal an exhausted-retries sweep point (``on_failure="skip"``)
        with its full attempt history; the point is NOT treated as done —
        a resumed sweep re-attempts it."""
        self._data.setdefault("failures", {})[self._k(key)] = {
            "attempts": attempts}
        self._flush()

    def failure_for(self, key: dict) -> Optional[dict]:
        """The journaled failure entry for a sweep point, or ``None``."""
        return self._data.get("failures", {}).get(self._k(key))

    def add_event(self, event: dict) -> None:
        """Append one recovery event (retry, worker loss/join/restart,
        heartbeat/deadline timeout) to the sweep's journal."""
        self._data.setdefault("events", []).append(event)
        self._flush()

    def events(self) -> List[dict]:
        return list(self._data.get("events", []))

    def partial(self, key: dict):
        """(records-so-far, carry-state-after-the-last-one)."""
        from .result import ConfigRecord
        got = self._data["records"].get(self._k(key))
        if not got:
            return [], None
        return ([ConfigRecord.from_json(r) for r in got["recs"]],
                got.get("carry"))

    def add_record(self, key: dict, record, carry=None) -> None:
        entry = self._data["records"].setdefault(
            self._k(key), {"recs": [], "carry": None})
        entry["recs"].append(record.to_json())
        entry["carry"] = carry
        self._flush()

    def search_state(self, key: dict) -> Optional[dict]:
        """The journaled model-guided candidate selection (survivor set +
        post-selection sampler RNG + space order fingerprint), or
        ``None``.  Cleared when the study's full result lands."""
        return self._data.get("search_state", {}).get(self._k(key))

    def add_search_state(self, key: dict, state: dict) -> None:
        self._data.setdefault("search_state", {})[self._k(key)] = state
        self._flush()

    def shared_bank(self):
        """The accumulated mid-sweep statistics bank, or ``None``."""
        got = self._data.get("shared_bank")
        if not got:
            return None
        from .transfer import StatisticsBank
        return StatisticsBank.from_json(got)

    def set_shared_bank(self, bank) -> None:
        self._data["shared_bank"] = bank.to_json()
        self._flush()
