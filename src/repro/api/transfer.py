"""Cross-study statistics transfer: warm-started sessions.

The paper's entire speed-up comes from per-kernel statistical profiles
crossing the predictability threshold; a fresh ``AutotuneSession`` rebuilds
every profile from zero even when a prior study on a neighboring problem
size, tolerance, or policy already measured the same kernel signatures.
This module closes that loop:

1. a completed study exports its per-kernel ``KernelStats`` posteriors
   (``AutotuneSession(..., collect_stats=True)`` attaches them to
   ``StudyResult.extra["kernel_stats"]``);
2. a ``StatisticsBank`` holds those posteriors keyed by *structural
   signature keys* (``core.signatures.structural_key``) — world-independent
   identities, so a bank recorded at one processor count matches
   signatures interned by a different world;
3. ``AutotuneSession(..., prior=bank)`` seeds the backend's statistical
   state so already-confident kernels start in the skip regime: eager
   sessions switch them off machine-wide outright, once-per-iteration
   policies skip every occurrence after the mandatory first execution —
   from trial one instead of after ``min_samples`` rebuild executions.

Trust control:

- ``bank.discounted(f)`` (applied by the session's ``prior_discount``)
  keeps each transferred mean/variance but carries only ``f`` of the
  evidence, widening the CI so stale banks re-earn confidence;
- ``bank.remapped(target)`` is a Gaussian-copula-style quantile remap
  between the source and target sample distributions (the
  transfer-learning direction of Randall et al.): a monotone CDF map with
  Gaussian marginals reduces to the z-score affine map, so kernels
  measured in BOTH banks adopt the target's marginal while pooling both
  banks' evidence, and source-only kernels are rescaled through a global
  log-space fit of the matched pairs — transferring across machines or
  allocations whose timings differ by a systematic factor.

Banks merge (``StatisticsBank.merge``), round-trip losslessly through
JSON (``to_json``/``from_json``, ``save``/``load``), and fingerprint into
session checkpoint keys so warm results are never replayed as cold ones.

``CopulaModel`` turns the same quantile machinery generative: per-kernel
Gaussian marginals fitted over one or more banks, joined by an empirical
equicorrelation structure (the one-factor Gaussian copula), with a seeded
``sample(n, rng)`` — the candidate model behind the ``model_guided``
search driver (``repro.api.search``).
"""

from __future__ import annotations

import json
import math
import os
import tempfile
import zlib
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.signatures import Signature, structural_key
from repro.core.stats import KernelStats

from .serialize import dumps_canonical

BANK_VERSION = 1
COPULA_VERSION = 1


class Harvest:
    """Accumulates a backend run's measured kernel statistics into bank
    form, across model resets, without re-banking a seeded prior.

    ``add`` folds a pooled per-signature table in (two signatures may
    share one structural key — e.g. two sub-communicators of the same
    relative shape — and Chan-merge).  When the run was warm-started, each
    seeded kernel's table entry is ``merge(prior, new samples)``; ``add``
    strips the prior via ``KernelStats.minus`` so that repeated harvests
    (one per reset) bank only the *measured* evidence — the prior itself
    re-enters the exported payload exactly once, keeping chained
    warm-starts from compounding transferred confidence.  (Under eager
    cross-rank aggregation the subtraction is approximate: merged tables
    carry one prior copy per participant, matching eager's per-rank
    counting of real samples.)
    """

    def __init__(self, world_size: int, prior: "StatisticsBank" = None):
        self.world_size = world_size
        self._prior = prior.entries if prior else {}
        self._acc: Dict[str, KernelStats] = {}

    def add(self, pooled: Dict[Signature, KernelStats],
            into: Optional[Dict[str, KernelStats]] = None) -> None:
        acc = self._acc if into is None else into
        for sig, st in pooled.items():
            if st.n == 0:
                continue
            key = structural_key(sig, self.world_size)
            p = self._prior.get(key)
            if p is not None:
                st = st.minus(p)
                if st is None:         # nothing beyond the seeded prior
                    continue
            got = acc.get(key)
            if got is None:
                acc[key] = st.copy()
            else:
                got.merge(st)

    def payload(self, pooled_now: Dict[Signature, KernelStats]) -> dict:
        """Bank JSON of everything harvested so far plus the live table,
        with the seeded prior folded back in once."""
        out = {k: v.copy() for k, v in self._acc.items()}
        self.add(pooled_now, into=out)
        for key, p in self._prior.items():
            got = out.get(key)
            if got is None:
                out[key] = p.copy()
            else:
                got.merge(p)
        return StatisticsBank(out).to_json()


class StatisticsBank:
    """Per-kernel ``KernelStats`` posteriors keyed by structural keys."""

    def __init__(self, entries: Optional[Dict[str, KernelStats]] = None,
                 *, meta: Optional[List[dict]] = None):
        self.entries: Dict[str, KernelStats] = dict(entries or {})
        #: provenance rows ({study, policy, tolerance, world_size, ...});
        #: informational only — never consulted by matching
        self.meta: List[dict] = list(meta or [])

    def __len__(self) -> int:
        return len(self.entries)

    def __bool__(self) -> bool:        # an empty bank is a no-op prior
        return bool(self.entries)

    # -- construction --------------------------------------------------------

    @classmethod
    def from_result(cls, result) -> "StatisticsBank":
        """Extract the bank a ``collect_stats=True`` session attached to a
        ``StudyResult`` (raises KeyError when the study did not collect)."""
        payload = result.extra["kernel_stats"]
        bank = cls.from_json(payload)
        if not bank.meta:
            bank.meta = [{"study": result.study, "policy": result.policy,
                          "tolerance": result.tolerance,
                          "backend": result.backend}]
        return bank

    def merge(self, other: "StatisticsBank") -> "StatisticsBank":
        """Key-wise Chan-merged union of two banks (new bank; sources
        untouched).  Structural keys are world-independent, so banks from
        different machine geometries merge directly."""
        out: Dict[str, KernelStats] = {k: v.copy()
                                       for k, v in self.entries.items()}
        for k, st in other.entries.items():
            acc = out.get(k)
            if acc is None:
                out[k] = st.copy()
            else:
                acc.merge(st)
        return StatisticsBank(out, meta=self.meta + other.meta)

    def discounted(self, factor: float) -> "StatisticsBank":
        """Evidence-discounted copy (see ``KernelStats.discounted``);
        entries whose discounted sample count reaches zero are dropped."""
        if factor >= 1.0:
            return self
        out = {}
        for k, st in self.entries.items():
            d = st.discounted(factor)
            if d.n > 0:
                out[k] = d
        return StatisticsBank(
            out, meta=self.meta + [{"discount": factor}])

    # -- evidence age (fleet-store support) ----------------------------------

    def stamp(self, now: float, *, only_unstamped: bool = True) -> None:
        """Stamp entries with ``now`` as their evidence time (in place).
        By default only unstamped entries are touched, so merging a freshly
        harvested bank then stamping records *when the fleet learned it*
        without rejuvenating older evidence."""
        for st in self.entries.values():
            if st.last_updated is None or not only_unstamped:
                st.last_updated = now

    def discount_by_age(self, now: float, half_life: float, *,
                        ttl: Optional[float] = None) -> "StatisticsBank":
        """Wall-clock decay view of the bank (new bank; source untouched):
        each stamped entry keeps its mean/variance but halves its evidence
        every ``half_life`` seconds of age (``KernelStats.discount_by_age``),
        and entries older than ``ttl`` seconds — or decayed to zero samples
        — are dropped outright.  Unstamped entries never age."""
        out: Dict[str, KernelStats] = {}
        for k, st in self.entries.items():
            if ttl is not None and st.last_updated is not None \
                    and now - st.last_updated > ttl:
                continue
            d = st.discount_by_age(now, half_life)
            if d.n > 0:
                out[k] = d
        return StatisticsBank(
            out, meta=self.meta + [{"age_discount": {
                "now": now, "half_life": half_life, "ttl": ttl}}])

    def filtered(self, *, max_cv: float,
                 min_samples: int = 2) -> "StatisticsBank":
        """Per-key quality filter: drop entries whose coefficient of
        variation (std / mean) exceeds ``max_cv``.

        Structural keys deliberately coarsen kernel identity — byte
        bucketing pools nearby message sizes, world-relative geometry pools
        sub-grids — so a bank recorded across several configurations can
        hold *mixture* distributions: high-dispersion entries whose wide CI
        never crosses the predictability threshold, yet whose seeded
        presence delays the target study's own (much tighter) per-config
        statistics from doing so (``KernelStats.merge`` pools the prior
        with the fresh samples).  Dropping them lets those kernels start
        cold and converge fast, while low-dispersion entries — the ones
        transfer actually pays off for — seed as usual.  Entries with
        fewer than ``min_samples`` samples have no defined variance and
        are dropped too (they carry no skippable confidence).  Applied at
        ``prior=`` seeding via ``AutotuneSession(prior_max_cv=...)``."""
        out = {}
        for k, st in self.entries.items():
            if st.n < min_samples or st.mean <= 0.0:
                continue
            if st.std / st.mean <= max_cv:
                out[k] = st.copy()
        return StatisticsBank(
            out, meta=self.meta + [{"filter_max_cv": max_cv}])

    # -- Gaussian-copula-style quantile remap --------------------------------

    def remapped(self, target: "StatisticsBank", *,
                 min_matches: int = 3) -> "StatisticsBank":
        """Remap this (source) bank onto ``target``'s sample distributions.

        For each kernel present in both banks, the source distribution is
        pushed through the monotone quantile map source-CDF -> uniform ->
        target-CDF.  With Gaussian marginals that map is the affine z-score
        transform, so the remapped kernel carries the TARGET's marginal
        (mean/variance/extremes) while pooling both banks' sample counts —
        the copula transfer: confidence structure from the source, marginal
        from the target.

        Source-only kernels are rescaled through a global log-space
        least-squares fit ``log t_target = a * log t_source + b`` over the
        matched pairs' means (a plain median mean-ratio below
        ``min_matches`` pairs; identity with no matches), then
        evidence-kept via ``KernelStats.scaled``.  Target-only kernels pass
        through unchanged.
        """
        src, tgt = self.entries, target.entries
        matched = [k for k in src if k in tgt
                   and src[k].mean > 0 and tgt[k].mean > 0]
        out: Dict[str, KernelStats] = {}
        for k in matched:
            s, t = src[k], tgt[k]
            n = t.n + s.n
            var = t.variance
            if not math.isfinite(var):
                # target too thin for a variance: borrow the source's
                # relative spread at the target's location
                svar = s.variance
                var = svar * (t.mean / s.mean) ** 2 \
                    if math.isfinite(svar) else 0.0
            out[k] = KernelStats.from_moments(n, t.mean, var,
                                              min(t.min_t, t.mean),
                                              max(t.max_t, t.mean))
        a, b = _fit_loglinear([(src[k].mean, tgt[k].mean) for k in matched],
                              min_matches)
        for k, s in src.items():
            if k in out:
                continue
            scale = math.exp(a * math.log(s.mean) + b) / s.mean \
                if s.mean > 0 else 1.0
            out[k] = s.scaled(scale)
        for k, t in tgt.items():
            if k not in out:
                out[k] = t.copy()
        return StatisticsBank(out, meta=self.meta + target.meta +
                              [{"remap": {"a": a, "b": b,
                                          "matched": len(matched)}}])

    # -- session-side resolution ---------------------------------------------

    def resolver(self, world_size: int
                 ) -> Callable[[Signature], Optional[KernelStats]]:
        """A ``Signature -> KernelStats-or-None`` lookup for a target study
        at ``world_size`` ranks.  Every hit returns a fresh copy (two
        signatures may resolve to one entry and must not share state)."""
        entries = self.entries
        memo: Dict[Signature, Optional[KernelStats]] = {}

        def lookup(sig: Signature) -> Optional[KernelStats]:
            st = memo.get(sig, False)
            if st is False:
                st = memo[sig] = entries.get(structural_key(sig, world_size))
            return st.copy() if st is not None else None

        return lookup

    # -- serialization -------------------------------------------------------

    def to_json(self) -> dict:
        return {"version": BANK_VERSION,
                "entries": {k: self.entries[k].to_json()
                            for k in sorted(self.entries)},
                "meta": self.meta}

    @classmethod
    def from_json(cls, d: dict) -> "StatisticsBank":
        if d.get("version", BANK_VERSION) != BANK_VERSION:
            raise ValueError(
                f"statistics bank version {d.get('version')!r} "
                f"unsupported (want {BANK_VERSION})")
        return cls({k: KernelStats.from_json(v)
                    for k, v in d["entries"].items()},
                   meta=list(d.get("meta", [])))

    def save(self, path: str) -> None:
        """Durably replace ``path`` with this bank: write to a same-
        directory mkstemp file, fsync, then atomically rename — a crash at
        any point leaves either the old bank or the new one, never a
        truncated hybrid (the daemon persists the fleet bank on a timer)."""
        d = os.path.dirname(os.path.abspath(path))
        fd, tmp = tempfile.mkstemp(
            prefix=os.path.basename(path) + ".", suffix=".tmp", dir=d)
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.to_json(), f, indent=1, sort_keys=True)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    @classmethod
    def load(cls, path: str) -> "StatisticsBank":
        with open(path) as f:
            return cls.from_json(json.load(f))

    def fingerprint(self) -> str:
        """Content hash for session checkpoint keys: a journaled result
        produced under one prior must never be replayed under another."""
        payload = dumps_canonical(
            {"entries": {k: v.to_json() for k, v in self.entries.items()}})
        return f"bank:{zlib.crc32(payload.encode()):08x}:{len(self.entries)}"


def _fit_loglinear(pairs: List[Tuple[float, float]],
                   min_matches: int) -> Tuple[float, float]:
    """log-space least squares through (source mean, target mean) pairs;
    degrades to a median-ratio shift, then to identity.  The slope is
    clamped to be non-negative: the remap must stay a monotone quantile
    map (a negative fitted slope — possible on adversarial matched pairs —
    would invert the source ordering, which no CDF->CDF map can do)."""
    if not pairs:
        return 1.0, 0.0
    logs = [(math.log(s), math.log(t)) for s, t in pairs]
    if len(logs) < max(min_matches, 2):
        ratios = sorted(lt - ls for ls, lt in logs)
        return 1.0, ratios[len(ratios) // 2]
    n = len(logs)
    mx = sum(ls for ls, _ in logs) / n
    my = sum(lt for _, lt in logs) / n
    sxx = sum((ls - mx) ** 2 for ls, _ in logs)
    if sxx <= 0.0:
        return 1.0, my - mx
    sxy = sum((ls - mx) * (lt - my) for ls, lt in logs)
    a = max(sxy / sxx, 0.0)
    return a, my - a * mx


# ------------------------------------------------- Gaussian-copula sampler

def _norm_ppf(q: float) -> float:
    """Standard-normal inverse CDF (Acklam's rational approximation,
    |relative error| < 1.15e-9 — far inside the marginals' own CI width).
    Dependency-free so the sampler needs nothing beyond numpy."""
    if not 0.0 < q < 1.0:
        if q == 0.0:
            return -math.inf
        if q == 1.0:
            return math.inf
        raise ValueError(f"quantile level {q!r} outside [0, 1]")
    a = (-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00)
    b = (-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00)
    d = (7.784695709041462e-03, 3.224671290700398e-01,
         2.445134137142996e+00, 3.754408661907416e+00)
    lo, hi = 0.02425, 1.0 - 0.02425
    if q < lo:
        u = math.sqrt(-2.0 * math.log(q))
        return (((((c[0] * u + c[1]) * u + c[2]) * u + c[3]) * u + c[4])
                * u + c[5]) / ((((d[0] * u + d[1]) * u + d[2]) * u
                                + d[3]) * u + 1.0)
    if q > hi:
        u = math.sqrt(-2.0 * math.log(1.0 - q))
        return -(((((c[0] * u + c[1]) * u + c[2]) * u + c[3]) * u + c[4])
                 * u + c[5]) / ((((d[0] * u + d[1]) * u + d[2]) * u
                                 + d[3]) * u + 1.0)
    u = q - 0.5
    r = u * u
    return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4])
            * r + a[5]) * u / (((((b[0] * r + b[1]) * r + b[2]) * r
                                 + b[3]) * r + b[4]) * r + 1.0)


def _equicorrelation(banks: Sequence["StatisticsBank"],
                     keys: Sequence[str]) -> float:
    """Estimate the one-factor (equicorrelated) Gaussian-copula dependence
    across kernels from per-bank log-mean observations.

    Each bank contributes one observation of the per-kernel mean vector;
    after standardizing every kernel's log-mean across banks, the variance
    of the per-bank cross-kernel average identifies rho (for standardized
    equicorrelated z's, Var[mean_k z_k] = (1 + (K-1) rho) / K).  A single
    bank — one observation — carries no dependence evidence: rho = 0,
    independent marginals."""
    if len(banks) < 2:
        return 0.0
    common = [k for k in keys
              if all(k in b.entries and b.entries[k].mean > 0
                     for b in banks)]
    if len(common) < 2:
        return 0.0
    x = np.log([[b.entries[k].mean for k in common] for b in banks])
    sd = x.std(axis=0)
    ok = sd > 0
    if int(ok.sum()) < 2:
        return 0.0
    z = (x[:, ok] - x[:, ok].mean(axis=0)) / sd[ok]
    k = z.shape[1]
    v = float(np.mean(z.mean(axis=1) ** 2))
    rho = (k * v - 1.0) / (k - 1.0)
    return float(min(max(rho, 0.0), 0.99))


class CopulaModel:
    """Seeded generative view of recorded banks: per-kernel Gaussian
    marginals joined by a one-factor Gaussian copula.

    ``fit`` Chan-merges one or more ``StatisticsBank``s into per-key
    (mean, std) marginals — the same moments the quantile remap maps
    between — and estimates a single empirical equicorrelation ``rho``
    from the banks' per-kernel mean vectors (machines/allocations whose
    kernels are all systematically fast or slow together).  ``sample``
    draws joint kernel-time vectors: a shared factor ``g`` plus
    independent noise, pushed through each marginal's quantile transform
    (Gaussian marginals: the affine z-score map — ``quantile`` exposes the
    per-key inverse CDF), clipped at zero since times are nonnegative.

    Degenerate inputs degrade, never raise: an empty bank yields a falsy
    model whose ``sample`` returns shape ``(n, 0)`` (callers fall back to
    uniform candidate sampling); a single kernel gets one marginal;
    zero-variance or single-sample entries get ``std = 0`` — constant
    draws at the mean.  Round-trips losslessly through JSON and
    fingerprints for checkpoint identity like the banks it came from.
    """

    def __init__(self, keys: Sequence[str], mean, std, n, rho: float = 0.0,
                 *, meta: Optional[List[dict]] = None):
        self.keys: List[str] = list(keys)
        self.mean = np.asarray(mean, dtype=float)
        self.std = np.asarray(std, dtype=float)
        self.n = np.asarray(n, dtype=int)
        self.rho = float(rho)
        self.meta: List[dict] = list(meta or [])

    def __len__(self) -> int:
        return len(self.keys)

    def __bool__(self) -> bool:
        return bool(self.keys)

    @classmethod
    def fit(cls, banks: Sequence["StatisticsBank"]) -> "CopulaModel":
        """Fit marginals over the Chan-merged union of ``banks`` and the
        cross-bank equicorrelation (0 with fewer than two banks)."""
        banks = [b if isinstance(b, StatisticsBank)
                 else StatisticsBank.from_json(b) for b in banks]
        merged = StatisticsBank()
        for b in banks:
            merged = merged.merge(b)
        keys = sorted(k for k, st in merged.entries.items() if st.mean > 0)
        mean, std, nobs = [], [], []
        for k in keys:
            st = merged.entries[k]
            var = st.variance
            mean.append(st.mean)
            std.append(math.sqrt(var)
                       if st.n >= 2 and math.isfinite(var) else 0.0)
            nobs.append(st.n)
        return cls(keys, mean, std, nobs, _equicorrelation(banks, keys),
                   meta=[m for b in banks for m in b.meta])

    def quantile(self, key: str, q: float) -> float:
        """Per-key marginal inverse CDF (monotone non-decreasing in ``q``;
        ``quantile(key, 0.5)`` is the key's mean — the remap machinery's
        marginal-preservation, pointwise)."""
        i = self.keys.index(key)
        return max(float(self.mean[i] + self.std[i] * _norm_ppf(q)), 0.0)

    def sample(self, n: int, rng) -> np.ndarray:
        """``(n, len(keys))`` joint kernel-time draws.  ``rng`` is a
        ``numpy.random.Generator`` or an int seed; the same seed yields
        the same draws on any process — the determinism the model-guided
        checkpoint carry relies on."""
        if not isinstance(rng, np.random.Generator):
            rng = np.random.default_rng(int(rng))
        k = len(self.keys)
        if k == 0:
            return np.zeros((int(n), 0))
        g = rng.standard_normal((int(n), 1))
        e = rng.standard_normal((int(n), k))
        z = math.sqrt(self.rho) * g + math.sqrt(1.0 - self.rho) * e
        return np.maximum(self.mean + self.std * z, 0.0)

    # -- serialization -------------------------------------------------------

    def to_json(self) -> dict:
        return {"version": COPULA_VERSION, "keys": list(self.keys),
                "mean": [float(v) for v in self.mean],
                "std": [float(v) for v in self.std],
                "n": [int(v) for v in self.n],
                "rho": self.rho, "meta": self.meta}

    @classmethod
    def from_json(cls, d: dict) -> "CopulaModel":
        if d.get("version", COPULA_VERSION) != COPULA_VERSION:
            raise ValueError(
                f"copula model version {d.get('version')!r} unsupported "
                f"(want {COPULA_VERSION})")
        return cls(d["keys"], d["mean"], d["std"], d["n"], d["rho"],
                   meta=list(d.get("meta", [])))

    def fingerprint(self) -> str:
        payload = dumps_canonical(
            {k: v for k, v in self.to_json().items() if k != "meta"})
        return f"copula:{zlib.crc32(payload.encode()):08x}:{len(self)}"
