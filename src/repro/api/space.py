"""Declarative tuning spaces.

A ``SearchSpace`` is the backend-agnostic description of WHAT is being
tuned: named configuration points (each carrying display params and an
opaque backend payload — a simmpi program factory, a ``StepKnobs``, a
dry-run ``SearchPoint``), plus the study-level protocol switches the paper
distinguishes (whether kernel statistics reset between configurations) and
sizing hints for the virtual-machine backend.

Space constructors for the repo's concrete studies live next to their
payloads: ``repro.linalg.studies.search_space`` (sim),
``repro.tune.lm_study.LMStudy.search_space`` (wall clock),
``repro.api.backends.dryrun_space`` (dry run).
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass, field, replace
from typing import Any, Iterator, List, Optional

#: reset_between_configs value meaning "follow the policy": reset unless
#: the policy keeps persistent models (eager's cross-config reuse) — the
#: convention of the measured LM studies.
RESET_POLICY = "policy"


@dataclass(frozen=True)
class ConfigPoint:
    """One point of a tuning space."""

    name: str
    params: dict = field(default_factory=dict)
    payload: Any = None     # backend-specific configuration object


@dataclass
class SearchSpace:
    """A named list of configuration points sharing one measurement
    substrate (one virtual machine / one model under timing / one mesh)."""

    name: str
    points: List[ConfigPoint]
    # paper §VI.A: SLATE/CANDMC reset kernel statistics between
    # configurations; Capital does not (eager reuses models across
    # configs).  RESET_POLICY defers the choice to the policy.
    reset_between_configs: Any = True
    # sim-backend sizing hints (ignored by other backends)
    world_size: int = 0
    machine: Any = None

    def __post_init__(self):
        # The points list IS the enumeration contract: checkpoints journal
        # per-configuration records by position, and the model-guided
        # driver selects candidates by sampled index — both replayed
        # across processes and resume boundaries.  Enumeration order is
        # therefore pinned to construction order (list order; never
        # re-sorted), and names must be unambiguous since records and
        # journal entries key on them.
        seen = set()
        for p in self.points:
            if p.name in seen:
                raise ValueError(
                    f"space {self.name!r} enumerates point {p.name!r} "
                    "twice; point names key records and checkpoint "
                    "journal entries and must be unique")
            seen.add(p.name)

    def __iter__(self) -> Iterator[ConfigPoint]:
        return iter(self.points)

    def __len__(self) -> int:
        return len(self.points)

    def order_fingerprint(self) -> str:
        """Stable identity of the point enumeration *order* (crc over the
        name sequence — process-independent by construction).  Journaled
        with the model-guided sampler state; resume refuses to map a
        checkpointed candidate selection onto a space that enumerates
        differently."""
        names = "\x1f".join(p.name for p in self.points)
        return f"order:{zlib.crc32(names.encode()):08x}:{len(self.points)}"

    def subset(self, n: Optional[int]) -> "SearchSpace":
        """First-n-points view (same substrate), for fast CI passes."""
        if n is None or n >= len(self.points):
            return self
        return replace(self, points=self.points[:n])

    def should_reset(self, policy) -> bool:
        """Resolve reset_between_configs against a concrete policy."""
        if self.reset_between_configs == RESET_POLICY:
            return not policy.persistent_models
        return bool(self.reset_between_configs)
