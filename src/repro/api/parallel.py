"""Process-parallel execution of independent sweep points.

Sweep points (one (policy, tolerance, seed, allocation) study each) share
nothing — every point builds its own virtual machine / timer state — so
they parallelize perfectly, and the sim engine is seeded-deterministic
per point regardless of which process runs it (the cost model's
allocation bias is crc32-keyed, not ``hash()``-keyed).  The pool uses
``os.fork`` rather than ``multiprocessing`` because study spaces carry
closures (program factories) that do not pickle, and a forked child
inherits them — plus the parent's warm imports — for free.

Children return results as JSON over a pipe (length-unframed: the child
writes once and closes; the parent reads to EOF via ``selectors`` so
pipe-buffer backpressure cannot deadlock the pool), and the parent merges
them in task order, never completion order, so the merged report is
deterministic regardless of scheduling.

On platforms without ``fork`` the pool degrades to serial execution.
"""

from __future__ import annotations

import json
import os
import selectors
import sys
import traceback
import warnings
from typing import Any, Callable, Dict, List, Sequence


def fork_available() -> bool:
    return hasattr(os, "fork")


def run_tasks(tasks: Sequence[Any], runner: Callable[[Any], dict], *,
              workers: int = 1,
              on_result: Callable[[int, dict], None] = None) -> List[dict]:
    """Run ``runner(task) -> json-able dict`` over every task, ``workers``
    at a time, returning results in task order.  ``on_result(index, res)``
    fires as each result lands (checkpoint hook)."""
    tasks = list(tasks)
    if workers <= 1 or len(tasks) <= 1 or not fork_available():
        out = []
        for i, t in enumerate(tasks):
            res = runner(t)
            if on_result is not None:
                on_result(i, res)
            out.append(res)
        return out

    results: List[Any] = [None] * len(tasks)
    sel = selectors.DefaultSelector()
    pending = list(enumerate(tasks))
    live: Dict[int, dict] = {}          # read-fd -> {index, pid, buf}

    def spawn(index: int, task: Any) -> None:
        rfd, wfd = os.pipe()
        # jax warns on any fork once imported anywhere in the process;
        # backends that actually touch jax declare parallel_safe=False and
        # never reach this pool, so the warning is noise here
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message=r".*os\.fork\(\).*",
                category=RuntimeWarning)
            pid = os.fork()
        if pid == 0:                     # child
            os.close(rfd)
            code = 0
            try:
                payload = {"ok": runner(task)}
            except BaseException:
                payload = {"err": traceback.format_exc()}
                code = 1
            try:
                with os.fdopen(wfd, "w") as w:
                    json.dump(payload, w)
                sys.stdout.flush()
                sys.stderr.flush()
            finally:
                os._exit(code)           # skip parent atexit/finalizers
        os.close(wfd)
        os.set_blocking(rfd, False)
        live[rfd] = {"index": index, "pid": pid, "buf": bytearray()}
        sel.register(rfd, selectors.EVENT_READ)

    while pending and len(live) < max(workers, 1):
        spawn(*pending.pop(0))

    try:
        while live:
            for key, _ in sel.select():
                rfd = key.fd
                st = live[rfd]
                while True:
                    try:
                        chunk = os.read(rfd, 1 << 16)
                    except BlockingIOError:
                        break
                    if not chunk:        # EOF: child wrote and closed
                        sel.unregister(rfd)
                        os.close(rfd)
                        del live[rfd]
                        os.waitpid(st["pid"], 0)
                        idx = st["index"]
                        raw = bytes(st["buf"])
                        if not raw:
                            raise RuntimeError(
                                f"sweep worker for task {idx} died "
                                "without a result")
                        payload = json.loads(raw)
                        if "err" in payload:
                            raise RuntimeError(
                                f"sweep worker for task {idx} failed:\n"
                                f"{payload['err']}")
                        results[idx] = payload["ok"]
                        if on_result is not None:
                            on_result(idx, payload["ok"])
                        if pending:
                            spawn(*pending.pop(0))
                        break
                    st["buf"] += chunk
    finally:
        for st in live.values():
            try:
                os.kill(st["pid"], 9)
                os.waitpid(st["pid"], 0)
            except OSError:
                pass
    return results
