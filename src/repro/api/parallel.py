"""Process-parallel execution of independent sweep points — legacy shim.

.. deprecated::
    The fork pool moved into ``repro.api.scheduler`` (``ForkExecutor``
    behind the ``Scheduler`` work queue, which also adds in-process and
    socket-remote executors plus explicit task state).  ``run_tasks`` and
    ``fork_available`` are re-exported here unchanged for existing
    callers; new code should target the scheduler directly.
"""

from __future__ import annotations

from .scheduler import fork_available, run_tasks

__all__ = ["fork_available", "run_tasks"]
