"""Remote sweep worker: ``python -m repro.api.worker``.

Serves scheduler task payloads (see ``repro.api.scheduler.RemoteExecutor``)
over a TCP socket, executing them against a locally-constructed
(space, backend) pair — study spaces carry closures that cannot cross a
wire, so each worker builds its own from an import spec and the scheduler
ships only JSON task descriptions::

    python -m repro.api.worker \\
        --spec 'repro.linalg.studies:search_space' \\
        --spec-args '{"name": "slate-cholesky", "scale": "ci"}' \\
        --port 0

``--spec`` names ``module:function``; called with the ``--spec-args`` JSON
object as keyword arguments it must return a ``SearchSpace`` (measured by a
default ``SimBackend``), a ``(space, backend)`` tuple, or a ``{"space": ...,
"backend": ...}`` dict.  ``--port 0`` binds an ephemeral port; the worker
prints one ``WORKER_READY <host> <port>`` line to stdout once listening,
which launchers (CI smoke, cluster scripts) parse to build the
``RemoteExecutor`` address list.

Protocol (newline-delimited JSON, one request per line):

- ``{"op": "hello"}``              -> worker identity (space name, point
                                      count, backend fingerprint) — the
                                      executor refuses mismatched workers;
- ``{"op": "run", "id", "task"}``  -> ``{"id", "ok": result_json}`` or
                                      ``{"id", "err": traceback}``;
- ``{"op": "shutdown"}``           -> ``{"ok": "bye"}``, then the worker
                                      exits.

The worker serves connections sequentially (one task in flight per worker
is the scheduler's contract; run several workers for parallelism) and
keeps serving after a scheduler disconnects unless ``--once`` is given.
"""

from __future__ import annotations

import argparse
import importlib
import json
import socket
import sys
import traceback
from typing import Tuple

from .space import SearchSpace


def resolve_spec(spec: str, spec_args: dict) -> Tuple[SearchSpace, object]:
    """Import ``module:function``, call it with ``spec_args``, normalize
    the result to (space, backend)."""
    mod_name, _, fn_name = spec.partition(":")
    if not fn_name:
        raise ValueError(f"--spec must be 'module:function', got {spec!r}")
    fn = getattr(importlib.import_module(mod_name), fn_name)
    got = fn(**spec_args)
    if isinstance(got, SearchSpace):
        from .backends import SimBackend
        return got, SimBackend()
    if isinstance(got, dict):
        return got["space"], got["backend"]
    space, backend = got
    return space, backend


def identity(space: SearchSpace, backend) -> dict:
    return {"space": space.name, "n_points": len(space),
            "backend": backend.fingerprint()}


def serve(space: SearchSpace, backend, *, host: str = "127.0.0.1",
          port: int = 0, once: bool = False,
          ready_out=None) -> None:
    """Accept scheduler connections and execute task payloads forever
    (or until a ``shutdown`` request / ``once`` connection closes)."""
    from .session import run_payload

    srv = socket.create_server((host, port))
    bound_host, bound_port = srv.getsockname()[:2]
    out = ready_out or sys.stdout
    print(f"WORKER_READY {bound_host} {bound_port}", file=out, flush=True)

    def handle(conn) -> bool:
        """One connection; returns True when asked to shut down."""
        buf = bytearray()
        with conn:
            while True:
                chunk = conn.recv(1 << 16)
                if not chunk:
                    return False
                buf += chunk
                while b"\n" in buf:
                    line, _, rest = bytes(buf).partition(b"\n")
                    buf[:] = rest
                    try:
                        msg = json.loads(line)
                    except ValueError:
                        conn.sendall(json.dumps(
                            {"err": "malformed request"}).encode() + b"\n")
                        continue
                    op = msg.get("op")
                    if op == "hello":
                        reply = {"ok": identity(space, backend)}
                    elif op == "shutdown":
                        conn.sendall(json.dumps(
                            {"ok": "bye"}).encode() + b"\n")
                        return True
                    elif op == "run":
                        try:
                            reply = {"id": msg.get("id"),
                                     "ok": run_payload(space, backend,
                                                       msg["task"])}
                        except BaseException:
                            reply = {"id": msg.get("id"),
                                     "err": traceback.format_exc()}
                    else:
                        reply = {"err": f"unknown op {op!r}"}
                    conn.sendall(json.dumps(reply).encode() + b"\n")

    with srv:
        while True:
            conn, _ = srv.accept()
            stop = handle(conn)
            if stop or once:
                return


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.api.worker",
        description="remote sweep worker for repro.api.scheduler")
    ap.add_argument("--spec", required=True,
                    help="module:function returning the space (or "
                         "(space, backend)) this worker serves")
    ap.add_argument("--spec-args", default="{}",
                    help="JSON object of keyword arguments for --spec")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 binds an ephemeral port (printed on the "
                         "WORKER_READY line)")
    ap.add_argument("--once", action="store_true",
                    help="exit after the first scheduler disconnects")
    args = ap.parse_args(argv)
    space, backend = resolve_spec(args.spec, json.loads(args.spec_args))
    serve(space, backend, host=args.host, port=args.port, once=args.once)


if __name__ == "__main__":
    main()
