"""Remote sweep worker: ``python -m repro.api.worker``.

Serves scheduler task payloads (see ``repro.api.scheduler.RemoteExecutor``)
over a TCP socket, executing them against a locally-constructed
(space, backend) pair — study spaces carry closures that cannot cross a
wire, so each worker builds its own from an import spec and the scheduler
ships only JSON task descriptions::

    python -m repro.api.worker \\
        --spec 'repro.linalg.studies:search_space' \\
        --spec-args '{"name": "slate-cholesky", "scale": "ci"}' \\
        --port 0

``--spec`` names ``module:function``; called with the ``--spec-args`` JSON
object as keyword arguments it must return a ``SearchSpace`` (measured by a
default ``SimBackend``), a ``(space, backend)`` tuple, or a ``{"space": ...,
"backend": ...}`` dict.  ``--port 0`` binds an ephemeral port; the worker
prints one ``WORKER_READY <host> <port>`` line to stdout once listening,
which launchers (CI smoke, ``repro.api.supervisor.WorkerPool``) parse to
build the ``RemoteExecutor`` address list.

``--connect host:port`` inverts the topology for *elastic join*: instead
of listening, the worker dials a ``RemoteExecutor(listen=...)`` and serves
that single connection (printing ``WORKER_READY connect <addr>``), so
capacity can be added — or supervisor-restarted back — mid-sweep.  The
dial retries until the scheduler starts accepting; when the scheduler
hangs up, the worker exits 0 (a clean end of service, which a supervisor
does not restart).

Protocol (newline-delimited JSON, one request per line):

- ``{"op": "hello"}``              -> worker identity (space name, point
                                      count, backend fingerprint) — the
                                      executor refuses mismatched workers;
- ``{"op": "ping"}``               -> ``{"ok": "pong"}`` (liveness
                                      heartbeat);
- ``{"op": "run", "id", "task"}``  -> ``{"id", "ok": result_json}`` or
                                      ``{"id", "err": traceback}``;
- ``{"op": "shutdown"}``           -> ``{"ok": "bye"}``, then the worker
                                      exits.

The worker serves connections sequentially (one task in flight per worker
is the scheduler's contract; run several workers for parallelism) and
keeps serving after a scheduler disconnects — including a disconnect that
breaks mid-reply (``BrokenPipeError``/``ConnectionResetError`` are
per-connection events, not worker deaths) — unless ``--once`` is given.
Task errors are caught as ``Exception``; ``KeyboardInterrupt`` and
``SystemExit`` terminate the worker itself.

``--faults '<json>'`` arms a ``repro.api.faults.FaultPlan`` for chaos
testing: die or wedge on the Nth task, delay / drop / corrupt replies on
a deterministic schedule.
"""

from __future__ import annotations

import argparse
import importlib
import json
import socket
import sys
import time
import traceback
from typing import Tuple

from .space import SearchSpace


def resolve_spec(spec: str, spec_args: dict) -> Tuple[SearchSpace, object]:
    """Import ``module:function``, call it with ``spec_args``, normalize
    the result to (space, backend)."""
    mod_name, _, fn_name = spec.partition(":")
    if not fn_name:
        raise ValueError(f"--spec must be 'module:function', got {spec!r}")
    fn = getattr(importlib.import_module(mod_name), fn_name)
    got = fn(**spec_args)
    if isinstance(got, SearchSpace):
        from .backends import SimBackend
        return got, SimBackend()
    if isinstance(got, dict):
        return got["space"], got["backend"]
    space, backend = got
    return space, backend


def arm_program_cache(backend, mode: str) -> None:
    """Give a sim backend a worker-scoped event-program cache
    (``repro.simmpi.program.ProgramCache``), so the structural recording
    pass runs once per unique geometry across ALL tasks this worker
    serves, not once per task.  ``mode`` is ``"mem"`` (in-process LRU) or
    a directory path (crash-atomic on-disk store, sharable between
    workers and across restarts).  No-op for backends without a
    ``program_cache`` attribute (non-sim) or with one already configured
    by the ``--spec`` factory.

    Replay is bit-identical to re-recording (the engine's identity gate),
    which is why the cache never appears in ``identity()`` or the backend
    fingerprint: a cached worker and an uncached one are interchangeable."""
    if getattr(backend, "program_cache", "absent") is None:
        from repro.simmpi.program import ProgramCache
        backend.program_cache = ProgramCache(
            None if mode == "mem" else mode)


def identity(space: SearchSpace, backend) -> dict:
    return {"space": space.name, "n_points": len(space),
            "backend": backend.fingerprint()}


def _handle(conn, space: SearchSpace, backend, run_payload,
            faults=None) -> bool:
    """Serve one connection; returns True when asked to shut down."""
    buf = bytearray()
    with conn:
        while True:
            chunk = conn.recv(1 << 16)
            if not chunk:
                return False
            buf += chunk
            while b"\n" in buf:
                line, _, rest = bytes(buf).partition(b"\n")
                buf[:] = rest
                try:
                    msg = json.loads(line)
                except ValueError:
                    conn.sendall(json.dumps(
                        {"err": "malformed request"}).encode() + b"\n")
                    continue
                op = msg.get("op")
                if op == "hello":
                    reply = {"ok": identity(space, backend)}
                elif op == "ping":
                    reply = {"ok": "pong"}
                elif op == "shutdown":
                    conn.sendall(json.dumps(
                        {"ok": "bye"}).encode() + b"\n")
                    return True
                elif op == "run":
                    if faults is not None:
                        faults.before_task()    # may kill/wedge this worker
                    # Exception, not BaseException: a task failure is a
                    # reply; Ctrl-C / SystemExit must stop the worker
                    try:
                        reply = {"id": msg.get("id"),
                                 "ok": run_payload(space, backend,
                                                   msg["task"])}
                    except Exception:
                        reply = {"id": msg.get("id"),
                                 "err": traceback.format_exc()}
                else:
                    reply = {"err": f"unknown op {op!r}"}
                raw = json.dumps(reply).encode() + b"\n"
                if faults is not None and op == "run":
                    raw = faults.transform_reply(raw)
                    if raw is None:
                        continue                # chaos: reply dropped
                    if not raw.endswith(b"\n"):
                        raw += b"\n"
                conn.sendall(raw)


def serve(space: SearchSpace, backend, *, host: str = "127.0.0.1",
          port: int = 0, once: bool = False,
          ready_out=None, faults=None) -> None:
    """Accept scheduler connections and execute task payloads forever
    (or until a ``shutdown`` request / ``once`` connection closes).

    A connection that breaks mid-exchange (scheduler killed while a reply
    is in flight) is dropped and the worker keeps serving — losing the
    whole worker to one broken socket is exactly the capacity leak the
    fleet scheduler exists to avoid."""
    from .session import run_payload

    srv = socket.create_server((host, port))
    bound_host, bound_port = srv.getsockname()[:2]
    out = ready_out or sys.stdout
    print(f"WORKER_READY {bound_host} {bound_port}", file=out, flush=True)

    with srv:
        while True:
            conn, _ = srv.accept()
            try:
                stop = _handle(conn, space, backend, run_payload, faults)
            except (BrokenPipeError, ConnectionResetError, OSError) as e:
                print(f"WORKER_CONN_ERROR {type(e).__name__}: {e}",
                      file=sys.stderr, flush=True)
                stop = False
            if stop or once:
                return


def serve_connect(space: SearchSpace, backend, address: str, *,
                  retry_s: float = 0.25, connect_timeout: float = 30.0,
                  ready_out=None, faults=None) -> None:
    """Elastic-join mode: dial a ``RemoteExecutor(listen=...)`` and serve
    that single connection.  Retries the dial until the scheduler accepts
    (a supervisor may launch workers before the sweep starts); exits
    cleanly when the scheduler hangs up."""
    from .session import run_payload

    host, _, port = address.rpartition(":")
    host = host or "127.0.0.1"
    deadline = time.monotonic() + connect_timeout
    while True:
        try:
            conn = socket.create_connection((host, int(port)),
                                            timeout=retry_s + 1.0)
            # the dial timeout must not outlive the dial: a connected
            # worker blocks in recv indefinitely between tasks
            conn.settimeout(None)
            break
        except OSError:
            if time.monotonic() >= deadline:
                raise SystemExit(
                    f"could not connect to {address} within "
                    f"{connect_timeout}s")
            time.sleep(retry_s)
    out = ready_out or sys.stdout
    print(f"WORKER_READY connect {address}", file=out, flush=True)
    try:
        _handle(conn, space, backend, run_payload, faults)
    except (BrokenPipeError, ConnectionResetError, OSError) as e:
        # the scheduler vanished mid-exchange: end of service, exit clean
        print(f"WORKER_CONN_ERROR {type(e).__name__}: {e}",
              file=sys.stderr, flush=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.api.worker",
        description="remote sweep worker for repro.api.scheduler")
    ap.add_argument("--spec", required=True,
                    help="module:function returning the space (or "
                         "(space, backend)) this worker serves")
    ap.add_argument("--spec-args", default="{}",
                    help="JSON object of keyword arguments for --spec")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 binds an ephemeral port (printed on the "
                         "WORKER_READY line)")
    ap.add_argument("--once", action="store_true",
                    help="exit after the first scheduler disconnects")
    ap.add_argument("--connect", default=None, metavar="HOST:PORT",
                    help="dial a listening RemoteExecutor instead of "
                         "binding a port (elastic join)")
    ap.add_argument("--connect-timeout", type=float, default=30.0,
                    help="give up dialing --connect after this many "
                         "seconds")
    ap.add_argument("--faults", default=None, metavar="JSON",
                    help="chaos-testing FaultPlan (repro.api.faults)")
    ap.add_argument("--program-cache", default="mem", metavar="MODE",
                    help='event-program cache for sim backends: "mem" '
                         "(default: in-process LRU shared across every "
                         'task this worker serves), "off", or a directory '
                         "path for the crash-atomic on-disk store "
                         "(sharable between workers and across restarts)")
    args = ap.parse_args(argv)
    faults = None
    if args.faults:
        from .faults import FaultPlan
        faults = FaultPlan.from_json(json.loads(args.faults))
    space, backend = resolve_spec(args.spec, json.loads(args.spec_args))
    if args.program_cache != "off":
        arm_program_cache(backend, args.program_cache)
    if args.connect:
        serve_connect(space, backend, args.connect,
                      connect_timeout=args.connect_timeout, faults=faults)
    else:
        serve(space, backend, host=args.host, port=args.port,
              once=args.once, faults=faults)


if __name__ == "__main__":
    main()
