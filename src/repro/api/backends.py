"""Pluggable execution backends for the session front-end.

A ``Backend`` answers one question — "what does configuration X cost?" —
through whichever measurement substrate it owns:

- ``SimBackend``       virtual-machine studies: the simmpi ``Runtime``
                       driving ``Critter`` interception over a schedule
                       program (the paper's evaluation vehicle);
- ``WallClockBackend`` real timing of jitted-closure kernel sequences via
                       ``SelectiveTimer`` (the paper's technique on the LM
                       framework itself);
- ``DryRunBackend``    compiled HLO/jaxpr roofline cost on the production
                       mesh (no execution at all — each "measurement" is a
                       lowering).

A backend is a lightweight, reusable factory; ``open(space, policy, ...)``
builds the per-(study, policy) execution context (``BackendRun``) holding
all mutable state, so one backend object can serve many sweep points, each
deterministic and independent — the property the parallel sweep relies on.

The run protocol mirrors the paper's per-configuration measurement
sequence (§VI.A), which the search drivers orchestrate:

- ``run_reference``  full execution, models untouched (error reference);
- ``run_offline``    full execution that FEEDS the models (the a-priori
                     policy's charged offline pass);
- ``run_trial``      one selective execution;
- ``reset_models``   forget kernel statistics (between configurations).

Cross-study transfer (``repro.api.transfer``): ``open(..., prior=bank)``
seeds the run's statistical state from a ``StatisticsBank`` so confident
kernels start in the skip regime (re-seeded after every model reset), and
``export_stats()`` harvests the run's accumulated per-kernel posteriors —
including statistics gathered before resets — as a bank payload.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.policies import Policy

from .space import ConfigPoint, SearchSpace


@dataclass
class Measurement:
    """One execution's outcome, backend-agnostic.

    ``time`` is what the run actually took (the full-execution reference
    time when forced); ``cost`` is the wall time charged to the autotuning
    budget; ``predicted`` the selective estimate of the configuration's
    time; ``comp`` the critical-path computation component (0 when the
    backend has no path decomposition).
    """

    predicted: float
    time: float
    cost: float
    comp: float = 0.0
    executed: int = 0
    skipped: int = 0
    extra: dict = field(default_factory=dict)


class BackendRun:
    """Per-(study, policy) execution context.  Subclasses own all mutable
    measurement state; the base class only fixes the interface."""

    def carry_state(self) -> Optional[dict]:
        """JSON-able state that survives a model reset and must carry into
        the next configuration for a resumed study to be bit-identical to
        an uninterrupted one (the sim backend's RNG stream).  ``None``
        when the backend has no such state."""
        return None

    def restore_carry(self, state: Optional[dict]) -> None:
        if state is not None:
            raise NotImplementedError(
                f"{type(self).__name__} cannot restore carry state")

    def export_stats(self) -> Optional[dict]:
        """Bank payload (``StatisticsBank.to_json`` shape) of every kernel
        statistic this run accumulated, pooled across ranks and across
        model resets.  ``None`` when the backend keeps no statistics."""
        return None

    def reset_models(self) -> None:
        raise NotImplementedError

    def run_reference(self, point: ConfigPoint) -> Measurement:
        raise NotImplementedError

    def run_offline(self, point: ConfigPoint) -> Measurement:
        raise NotImplementedError(
            "this backend has no offline pass; the 'apriori' policy "
            "requires SimBackend")

    def run_trial(self, point: ConfigPoint) -> Measurement:
        raise NotImplementedError

    # -- model-guided search hooks (repro.api.search.model_guided) -----------

    def kernel_profile(self, point: ConfigPoint) -> Optional[Dict]:
        """Structural kernel-occurrence profile of one configuration:
        ``{structural_key: per-rank occurrence counts}``, obtained WITHOUT
        consuming measurement state (the sim backend uses the RNG-free
        recording pass), so profiling every candidate leaves the run
        bit-identical to one that never profiled.  ``None`` when the
        backend cannot see kernel structure — the model-guided driver then
        falls back to uniform candidate sampling."""
        return None

    def cache_info(self) -> Optional[dict]:
        """Event-program cache observability for ``StudyResult.extra``:
        per-point structural fingerprints plus hit/miss/recording counters
        (see ``repro.simmpi.program``).  ``None`` when the backend has no
        program cache — the common case for non-sim backends and for sim
        runs opened without one."""
        return None

    def cost_lower_bound(self, point: ConfigPoint) -> Optional[float]:
        """Analytic lower bound on the configuration's step time (roofline:
        no schedule can beat its compute at peak flops / memory
        bandwidth), used to prune provably-dominated candidates before any
        dispatch.  ``None`` when no machine model is available — nothing
        is pruned."""
        return None


class Backend:
    """Backend factory protocol: stateless description + ``open``."""

    name: str = "?"
    #: False for backends whose runs touch JAX/XLA (forked children can
    #: deadlock on runtime locks) or measure real wall clock (forked
    #: siblings contend for cores and corrupt timings) — sweeps over such
    #: backends are forced serial regardless of ``workers``.
    parallel_safe: bool = True

    def fingerprint(self) -> dict:
        """JSON-able identity of this backend's measurement configuration,
        part of the session checkpoint key: results journaled under one
        configuration must not be replayed as another's."""
        return {"name": self.name}

    def open(self, space: SearchSpace, policy: Policy, *,
             seed: int = 0, allocation: int = 0,
             prior=None) -> BackendRun:
        """Build the per-(study, policy) execution context.  ``prior`` is
        an optional ``repro.api.transfer.StatisticsBank`` (already
        discounted by the session); backends without statistical state
        (dry run) ignore it."""
        raise NotImplementedError


# --------------------------------------------------------------------- sim

class SimBackend(Backend):
    """Virtual-machine measurement: simmpi ``Runtime`` + ``Critter``.

    Point payloads are program factories ``make_program(world) ->
    program_factory(rank, world)`` (the ``Configuration.make_program``
    convention of the linalg studies).
    """

    name = "sim"

    def __init__(self, *, machine=None, timer: Optional[Callable] = None,
                 cost_model=None, overhead: float = 1e-6,
                 program_cache=None):
        self.machine = machine
        self.timer = timer
        self.cost_model = cost_model
        self.overhead = overhead
        # cross-run event-program cache (repro.simmpi.program.ProgramCache):
        # pass an instance to share one across backends, a directory path
        # for the crash-atomic on-disk store, or "mem" for a process-local
        # LRU.  All runs this backend opens share it — the recording pass
        # then executes once per unique geometry across the whole sweep.
        if isinstance(program_cache, str):
            from repro.simmpi.program import ProgramCache
            program_cache = ProgramCache(
                None if program_cache == "mem" else program_cache)
        self.program_cache = program_cache

    def fingerprint(self) -> dict:
        # custom timing callables cannot be fingerprinted beyond their
        # presence; "custom" still prevents the worst confusion (replaying
        # a deterministic-timer journal as a default-cost-model study).
        # The program cache is deliberately absent: cache-hit replay is
        # bit-identical to re-recording, so it must not split checkpoint
        # identity.
        return {"name": self.name, "overhead": self.overhead,
                "machine": getattr(self.machine, "name", None),
                "timer": "custom" if self.timer is not None else "default",
                "cost_model": "custom" if self.cost_model is not None
                else "default"}

    def point_fingerprints(self, space: SearchSpace) -> Optional[Dict]:
        """Structural fingerprints of every point in ``space`` — what task
        payloads advertise so remote dispatch knows which programs a worker
        already holds.  ``None`` when no program cache is configured."""
        if self.program_cache is None:
            return None
        from repro.simmpi.program import structural_fingerprint
        return {p.name: structural_fingerprint(space.name, p.name, p.params,
                                               space.world_size)
                for p in space.points}

    def open(self, space: SearchSpace, policy: Policy, *,
             seed: int = 0, allocation: int = 0,
             prior=None) -> "SimRun":
        return SimRun(space, policy, machine=self.machine,
                      timer=self.timer, cost_model=self.cost_model,
                      overhead=self.overhead, seed=seed,
                      allocation=allocation, prior=prior,
                      program_cache=self.program_cache)


class SimRun(BackendRun):
    def __init__(self, space: SearchSpace, policy: Policy, *, machine,
                 timer, cost_model, overhead, seed: int, allocation: int,
                 prior=None, program_cache=None):
        # local imports keep repro.api importable without the sim stack
        from repro.core.critter import Critter
        from repro.simmpi.comm import World
        from repro.simmpi.costmodel import CostModel, KNL_STAMPEDE2
        from repro.simmpi.runtime import Runtime

        if not space.world_size:
            raise ValueError(f"space {space.name!r} has no world_size; "
                             "SimBackend needs a virtual machine size")
        from repro.api.transfer import Harvest

        self.policy = policy
        self.world = World(space.world_size)
        self.critter = Critter(self.world, policy)
        if prior:
            self.critter.set_prior(prior.resolver(self.world.size))
        # transfer harvest: measured statistics accumulated across model
        # resets, prior-deduplicated (see transfer.Harvest)
        self._harvest = Harvest(self.world.size, prior)
        cm = cost_model
        if timer is None:
            if cm is None:
                cm = CostModel(machine or space.machine or KNL_STAMPEDE2,
                               allocation=allocation, seed=seed)
            timer = cm.sample
        elif cm is None:
            # a bound CostModel.sample still reveals its machine spec; a
            # fully opaque timer leaves no spec and cost_lower_bound then
            # declines to prune
            owner = getattr(timer, "__self__", None)
            cm = owner if isinstance(owner, CostModel) else None
        self._spec = cm.spec if cm is not None else None
        self.runtime = Runtime(self.world, self.critter, timer,
                               seed=seed + 17 * allocation,
                               overhead=overhead,
                               program_cache=program_cache)
        self._space_name = space.name
        # one program factory per configuration payload, created on first
        # use — its identity keys the runtime's event-trace cache.  Keyed
        # by the payload callable (not the point name) so an ad-hoc point
        # that reuses a study point's name still measures its own program.
        # With a program cache configured, factories are ALSO stamped with
        # their structural fingerprint (``program_key``), switching the
        # runtime to the fingerprint-keyed path: equal geometries share one
        # recording, in-process and across runs — the opt-in trades the
        # payload-identity property for the (name, params)-determine-
        # structure contract of repro.simmpi.program.
        self._progs: Dict[Any, Any] = {}
        self._cached = program_cache is not None
        # point name -> structural fingerprint, for StudyResult.extra
        self._fps: Dict[str, str] = {}
        # structural profiles per payload (see _structure)
        self._structures: Dict[Any, tuple] = {}

    def _prog(self, point: ConfigPoint):
        prog = self._progs.get(point.payload)
        if prog is None:
            prog = self._progs[point.payload] = point.payload(self.world)
            if self._cached:
                from repro.simmpi.program import structural_fingerprint
                fp = structural_fingerprint(self._space_name, point.name,
                                            point.params, self.world.size)
                self._fps[point.name] = prog.program_key = fp
        return prog

    @staticmethod
    def _measure(res) -> Measurement:
        return Measurement(predicted=res.predicted_time,
                           time=res.wall_time, cost=res.wall_time,
                           comp=res.crit_comp, executed=res.executed,
                           skipped=res.skipped)

    def carry_state(self) -> dict:
        # the lognormal sampling stream runs continuously across
        # configurations; a resumed study must pick it up where the
        # interrupted one left off
        return {"rng": self.runtime._rng.bit_generator.state}

    def restore_carry(self, state: Optional[dict]) -> None:
        if state is not None:
            self.runtime._rng.bit_generator.state = state["rng"]

    def export_stats(self) -> dict:
        return self._harvest.payload(self.critter.pooled_kbar())

    def reset_models(self) -> None:
        # bank measured statistics before they are forgotten
        self._harvest.add(self.critter.pooled_kbar())
        self.critter.reset_models()

    def run_reference(self, point: ConfigPoint) -> Measurement:
        res = self.runtime.run(self._prog(point), force_execute=True,
                               update_stats=False)
        return self._measure(res)

    def run_offline(self, point: ConfigPoint) -> Measurement:
        res = self.runtime.run(self._prog(point), force_execute=True,
                               update_stats=True)
        self.critter.snapshot_apriori_counts()
        return self._measure(res)

    def run_trial(self, point: ConfigPoint) -> Measurement:
        return self._measure(self.runtime.run(self._prog(point)))

    # -- model-guided search hooks -------------------------------------------

    def _structure(self, point: ConfigPoint) -> tuple:
        """Structural profile of one configuration via the RNG-free
        recording pass (``Runtime._record`` matches communication without
        touching the Critter protocol or the sampling RNG, so profiling
        any number of candidates leaves measurement state bit-identical):
        per-structural-key per-rank occurrence counts, plus per-rank
        computation flop/byte totals for the roofline bound.  Collectives
        are charged to every participant rank, point-to-points (including
        matched isends) to both endpoints — the per-rank attribution that
        makes ``max`` over ranks a critical-path surrogate."""
        got = self._structures.get(point.payload)
        if got is not None:
            return got
        from repro.core.signatures import (bytes_of, flops_of,
                                           structural_key)
        from repro.simmpi.runtime import (EV_BLOCK, EV_COLL, EV_COMP,
                                          EV_IMATCH, EV_P2P)
        w = self.world.size
        sigs = self.world.interner.sigs
        keys: Dict[int, str] = {}
        counts: Dict[str, np.ndarray] = {}
        flops = np.zeros(w)
        nbytes = np.zeros(w)

        def key_of(sid):
            key = keys.get(sid)
            if key is None:
                key = keys[sid] = structural_key(sigs[sid], w)
            return key

        def bump(key, ranks):
            arr = counts.get(key)
            if arr is None:
                arr = counts[key] = np.zeros(w)
            arr[ranks] += 1.0

        def comp(r, sid):
            bump(key_of(sid), r)
            sig = sigs[sid]
            flops[r] += flops_of(sig)
            nbytes[r] += bytes_of(sig)

        # the COMPILED program, not a raw re-recording: profiling shares
        # the runtime's program map (and the cross-run cache when one is
        # configured), so the model-guided driver scoring the full grid
        # records each unique geometry at most once — and a surviving
        # candidate's later measurement reuses the scorer's program
        for ev in self.runtime._get_program(self._prog(point)).events:
            kind = ev[0]
            if kind == EV_COMP:
                comp(ev[1], ev[2])
            elif kind == EV_BLOCK:
                r = ev[1]
                for sid in ev[2].sids:
                    comp(r, sid)
            elif kind == EV_COLL:
                _, sid, comm = ev
                bump(key_of(sid), comm.ranks_np)
            elif kind == EV_P2P:
                _, src, dst, sid = ev
                key = key_of(sid)
                bump(key, src)
                bump(key, dst)
            elif kind == EV_IMATCH:
                key = key_of(ev[3])
                bump(key, ev[1])
                bump(key, ev[2])
        got = (counts, flops, nbytes)
        self._structures[point.payload] = got
        return got

    def kernel_profile(self, point: ConfigPoint) -> Dict[str, np.ndarray]:
        return self._structure(point)[0]

    def cost_lower_bound(self, point: ConfigPoint) -> Optional[float]:
        if self._spec is None:
            return None
        _, flops, nbytes = self._structure(point)
        per_rank = np.maximum(flops / self._spec.peak_flops,
                              nbytes / self._spec.mem_bw)
        # computation-only: communication at any bandwidth only adds time,
        # so the slowest rank's roofline is a valid lower bound
        return float(per_rank.max()) if per_rank.size else 0.0

    def cache_info(self) -> Optional[dict]:
        if not self._cached:
            return None
        rt = self.runtime
        info = {"fingerprints": dict(self._fps),
                "hits": rt.cache_hits, "misses": rt.cache_misses,
                "recordings": rt.recordings}
        if rt.program_cache is not None:
            info["store"] = rt.program_cache.stats()
        return info


# --------------------------------------------------------------- wall clock

class WallClockBackend(Backend):
    """Real wall-clock timing of recurring kernels via ``SelectiveTimer``.

    ``kernels_of(point) -> [(Signature, thunk, freq)]`` resolves a point to
    its step's kernel occurrence list (thunks pre-compiled, so timing sees
    only execution); ``freq`` is the kernel's per-step occurrence count
    (the paper's alpha).  ``LMStudy.kernels_of`` is the canonical provider.
    """

    name = "wallclock"
    parallel_safe = False     # real timing + jitted closures: serial only

    def __init__(self, kernels_of: Callable[[ConfigPoint], Sequence[Tuple]],
                 *, clock: Optional[Callable[[], float]] = None):
        self.kernels_of = kernels_of
        self.clock = clock

    def fingerprint(self) -> dict:
        return {"name": self.name,
                "clock": "custom" if self.clock is not None else "default"}

    def open(self, space: SearchSpace, policy: Policy, *,
             seed: int = 0, allocation: int = 0,
             prior=None) -> "WallClockRun":
        return WallClockRun(self.kernels_of, policy, clock=self.clock,
                            prior=prior)


class WallClockRun(BackendRun):
    def __init__(self, kernels_of, policy: Policy, *, clock=None,
                 prior=None):
        from repro.api.transfer import Harvest
        from repro.tune.selective import SelectiveTimer
        self.policy = policy
        # wall-clock studies are single-process compute-kernel streams:
        # structural keys carry no communicator geometry, so the bank
        # resolves (and harvests) against a world of 1
        self.timer = SelectiveTimer(
            policy, clock=clock,
            prior_lookup=prior.resolver(1) if prior else None)
        self.kernels_of = kernels_of
        self._harvest = Harvest(1, prior)

    def export_stats(self) -> dict:
        return self._harvest.payload(self.timer.kbar)

    def reset_models(self) -> None:
        self._harvest.add(self.timer.kbar)
        self.timer.reset_models()

    def run_reference(self, point: ConfigPoint) -> Measurement:
        clock = self.timer.clock
        total = 0.0
        n = 0
        for sig, thunk, freq in self.kernels_of(point):
            t0 = clock()
            thunk()
            total += clock() - t0
            n += 1
        # the reference is not charged to the tuning budget (the driver
        # accounts full_cost = full_time x trials, as the paper does)
        return Measurement(predicted=total, time=total, cost=0.0,
                           executed=n)

    def run_trial(self, point: ConfigPoint) -> Measurement:
        timer = self.timer
        timer.begin_iteration()
        for sig, thunk, freq in self.kernels_of(point):
            timer.time_kernel(sig, thunk, freq)
        rep = timer.report()
        return Measurement(predicted=rep.predicted_time,
                           time=rep.measured_time, cost=rep.measured_time,
                           executed=rep.executed, skipped=rep.skipped)


# ------------------------------------------------------------------ dry run

class DryRunBackend(Backend):
    """Compile-and-score: ranks configurations by the dominant roofline
    term of their lowered HLO on the production mesh (``tune.dryrun_search``
    machinery).  Deterministic — use ``trials=1``; the "full" and
    "selective" times coincide, so a DryRunBackend study degenerates to a
    ranked table with speedup 1, which is exactly what a cost-model search
    is.  Point payloads are ``tune.dryrun_search.SearchPoint``s.
    """

    name = "dryrun"
    parallel_safe = False     # XLA compiles deadlock in forked children

    def __init__(self, arch: str, shape: str, *, multi_pod: bool = False,
                 cache_dir: Optional[str] = None):
        self.arch = arch
        self.shape = shape
        self.multi_pod = multi_pod
        self.cache_dir = cache_dir

    def fingerprint(self) -> dict:
        return {"name": self.name, "arch": self.arch, "shape": self.shape,
                "multi_pod": self.multi_pod}

    def open(self, space: SearchSpace, policy: Policy, *,
             seed: int = 0, allocation: int = 0,
             prior=None) -> "DryRunRun":
        # a pure cost model keeps no kernel statistics: priors are inert
        return DryRunRun(self)


class DryRunRun(BackendRun):
    def __init__(self, backend: DryRunBackend):
        self.b = backend
        self._recs: Dict[str, dict] = {}

    def reset_models(self) -> None:
        pass                        # nothing accumulates across configs

    def _evaluate(self, point: ConfigPoint) -> dict:
        rec = self._recs.get(point.name)
        if rec is None:
            from repro.tune.dryrun_search import evaluate_point
            try:
                rec = evaluate_point(self.b.arch, self.b.shape,
                                     point.payload,
                                     multi_pod=self.b.multi_pod,
                                     cache_dir=self.b.cache_dir)
            except Exception as e:   # lowering failures are search results
                rec = {"error": repr(e)}
            self._recs[point.name] = rec
        return rec

    def _measure(self, rec: dict) -> Measurement:
        if "error" in rec:
            return Measurement(predicted=float("inf"), time=float("inf"),
                               cost=0.0, extra=dict(rec))
        t = float(rec["roofline"]["step_s"])
        # "full" and "selective" coincide for a pure cost model: each trial
        # charges the modeled step time, so full_cost == selective_cost and
        # the study degenerates to a ranked table with speedup exactly 1
        # (the compile time itself stays available in extra["compile_s"])
        return Measurement(predicted=t, time=t, cost=t, extra=dict(rec))

    def run_reference(self, point: ConfigPoint) -> Measurement:
        return self._measure(self._evaluate(point))

    def run_trial(self, point: ConfigPoint) -> Measurement:
        return self._measure(self._evaluate(point))

    def cost_lower_bound(self, point: ConfigPoint) -> float:
        """The dry-run roofline IS an analytic lower bound: the lowered
        HLO's dominant roofline term at peak rates.  A lowering failure is
        ``+inf`` — dominated by any measured incumbent."""
        rec = self._evaluate(point)
        if "error" in rec:
            return float("inf")
        return float(rec["roofline"]["step_s"])


def dryrun_space(arch: str, shape: str, points) -> SearchSpace:
    """Wrap ``tune.dryrun_search.SearchPoint``s for the session API."""
    return SearchSpace(
        name=f"dryrun-{arch}-{shape}",
        points=[ConfigPoint(name=p.name, params=dict(p.__dict__),
                            payload=p) for p in points],
        reset_between_configs=False)
