"""repro.api — the supported autotuning front-end.

Session-based access to the paper's confidence-interval-gated selective
execution over pluggable measurement backends::

    from repro.api import AutotuneSession, SimBackend
    from repro.linalg.studies import search_space

    result = AutotuneSession(search_space("slate-cholesky"),
                             backend=SimBackend(), policy="online",
                             tolerance=0.25).run()
    print(result.speedup, result.chosen.name)

Pieces:

- ``SearchSpace`` / ``ConfigPoint``   what is tuned (``space``);
- ``Backend``: ``SimBackend``, ``WallClockBackend``, ``DryRunBackend``
  — how a configuration is measured (``backends``);
- searches ``"exhaustive"`` and ``"racing"`` (``search``);
- ``StudyResult`` / ``ConfigRecord``  uniform, JSON-lossless results
  (``result``, ``serialize``);
- ``AutotuneSession.sweep``  checkpoint/resumable policy x tolerance
  grids scheduled as explicit-state tasks over pluggable executors —
  in-process, fork-pool, socket-remote workers — with optional mid-sweep
  statistics sharing (``session``, ``scheduler``; workers launch via
  ``python -m repro.api.worker``);
- fault tolerance: per-task retries with backoff and attempt history,
  heartbeats/deadlines for wedged workers, elastic mid-sweep worker
  join, ``WorkerPool`` supervision with crash restarts
  (``supervisor``), and a seeded chaos harness — ``FaultPlan`` /
  ``FaultInjector`` (``faults``).
"""

from .backends import (Backend, BackendRun, DryRunBackend, Measurement,
                       SimBackend, WallClockBackend, dryrun_space)
from .daemon import (BackgroundTuner, DaemonCheckpoint, DaemonConfig,
                     DriftDetector, FleetStore, TuningDaemon)
from .faults import FaultInjector, FaultPlan
from .result import ConfigRecord, StudyResult
from .scheduler import (Executor, ForkExecutor, InProcessExecutor,
                        RemoteExecutor, Scheduler, SchedulerError, Task,
                        fork_available)
from .search import (SEARCHES, exhaustive, measure_config, model_guided,
                     racing)
from .serialize import dumps_canonical, from_jsonable, to_jsonable
from .session import AutotuneSession, run_payload
from .space import RESET_POLICY, ConfigPoint, SearchSpace
from .supervisor import WorkerPool, WorkerSpec
from .transfer import CopulaModel, StatisticsBank

__all__ = [
    "AutotuneSession", "Backend", "BackendRun", "BackgroundTuner",
    "ConfigPoint", "ConfigRecord", "CopulaModel", "DaemonCheckpoint",
    "DaemonConfig",
    "DriftDetector", "DryRunBackend", "Executor", "FaultInjector",
    "FaultPlan", "FleetStore", "ForkExecutor", "InProcessExecutor",
    "Measurement", "RESET_POLICY", "RemoteExecutor", "SEARCHES",
    "Scheduler", "SchedulerError", "SearchSpace", "SimBackend",
    "StatisticsBank", "StudyResult", "Task", "TuningDaemon",
    "WallClockBackend", "WorkerPool", "WorkerSpec", "dryrun_space",
    "dumps_canonical", "exhaustive", "fork_available", "from_jsonable",
    "measure_config", "model_guided", "racing", "run_payload",
    "to_jsonable",
]
