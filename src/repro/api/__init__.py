"""repro.api — the supported autotuning front-end.

Session-based access to the paper's confidence-interval-gated selective
execution over pluggable measurement backends::

    from repro.api import AutotuneSession, SimBackend
    from repro.linalg.studies import search_space

    result = AutotuneSession(search_space("slate-cholesky"),
                             backend=SimBackend(), policy="online",
                             tolerance=0.25).run()
    print(result.speedup, result.chosen.name)

Pieces:

- ``SearchSpace`` / ``ConfigPoint``   what is tuned (``space``);
- ``Backend``: ``SimBackend``, ``WallClockBackend``, ``DryRunBackend``
  — how a configuration is measured (``backends``);
- searches ``"exhaustive"`` and ``"racing"`` (``search``);
- ``StudyResult`` / ``ConfigRecord``  uniform, JSON-lossless results
  (``result``, ``serialize``);
- ``AutotuneSession.sweep``  process-parallel, checkpoint/resumable
  policy x tolerance grids (``session``, ``parallel``).
"""

from .backends import (Backend, BackendRun, DryRunBackend, Measurement,
                       SimBackend, WallClockBackend, dryrun_space)
from .result import ConfigRecord, StudyResult
from .search import SEARCHES, exhaustive, measure_config, racing
from .serialize import dumps_canonical, from_jsonable, to_jsonable
from .session import AutotuneSession
from .space import RESET_POLICY, ConfigPoint, SearchSpace
from .transfer import StatisticsBank

__all__ = [
    "AutotuneSession", "Backend", "BackendRun", "ConfigPoint",
    "ConfigRecord", "DryRunBackend", "Measurement", "RESET_POLICY",
    "SEARCHES", "SearchSpace", "SimBackend", "StatisticsBank",
    "StudyResult", "WallClockBackend", "dryrun_space", "dumps_canonical",
    "exhaustive", "from_jsonable", "measure_config", "racing",
    "to_jsonable",
]
