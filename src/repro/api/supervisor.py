"""Worker fleet supervision: launch, monitor, restart.

``WorkerPool`` owns a fleet of ``python -m repro.api.worker`` subprocesses
described by ``WorkerSpec``s: it launches them, waits for their
``WORKER_READY`` lines, and — the fault-tolerance half — watches for
crashes and relaunches crashed workers with exponential backoff, so fleet
capacity recovers instead of monotonically shrinking.

Two topologies:

- **listen-mode** workers (``WorkerSpec(connect=None)``) bind their own
  ports; ``pool.addresses`` (parsed from the ready lines) feeds
  ``RemoteExecutor(addresses)``.  A restarted listen-mode worker binds a
  *new* ephemeral port, which an already-running executor will not find —
  use this mode for static fleets launched before the sweep.
- **connect-mode** workers (``connect="host:port"``) dial a
  ``RemoteExecutor(listen=...)``; a restarted worker simply re-dials, so
  the executor re-admits it mid-sweep (elastic rejoin).  This is the
  fault-tolerant pairing::

      ex = RemoteExecutor(listen="127.0.0.1:0", join_timeout=60)
      specs = [WorkerSpec(spec="repro.linalg.studies:search_space",
                          spec_args={"name": "slate-cholesky",
                                     "scale": "ci"},
                          connect=ex.listen_address)] * 4
      with WorkerPool(specs) as pool:
          results = session.sweep(executor=ex, max_retries=3)

Restart policy: only *nonzero* exits are restarted — a worker exiting 0
ended service deliberately (scheduler hangup in connect mode, ``shutdown``
op) and relaunching it would just churn dials against a closed executor.
Each slot gets ``max_restarts`` relaunches with delay
``restart_backoff * 2**n``; every restart is journaled in ``pool.events``
(and through ``on_event``), so a sweep checkpoint can attribute anomalies
to infrastructure.

Worker stdout/stderr go to per-slot log files (``pool.log_dir``) rather
than pipes — a chatty worker can never deadlock the supervisor on a full
pipe buffer, and crash forensics survive the process.
"""

from __future__ import annotations

import json
import os
import re
import subprocess
import sys
import tempfile
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Union

READY_RE = re.compile(r"WORKER_READY (\S+) (\S+)")


@dataclass
class WorkerSpec:
    """How to launch one ``python -m repro.api.worker`` process."""

    spec: str                                 # module:function space spec
    spec_args: dict = field(default_factory=dict)
    host: str = "127.0.0.1"                   # listen mode bind host
    port: int = 0                             # listen mode port (0 = any)
    connect: Optional[str] = None             # RemoteExecutor listen addr
    once: bool = False
    faults: Optional[dict] = None             # chaos FaultPlan JSON
    env: Optional[dict] = None                # extra environment entries
    python: str = sys.executable

    def argv(self) -> List[str]:
        cmd = [self.python, "-m", "repro.api.worker",
               "--spec", self.spec,
               "--spec-args", json.dumps(self.spec_args)]
        if self.connect:
            cmd += ["--connect", self.connect]
        else:
            cmd += ["--host", self.host, "--port", str(self.port)]
            if self.once:
                cmd += ["--once"]
        if self.faults:
            cmd += ["--faults", json.dumps(self.faults)]
        return cmd


class WorkerPool:
    """Launch and supervise a fleet of worker subprocesses.

    ``specs`` is one ``WorkerSpec`` per worker (or a single spec and
    ``n=`` copies of it).  ``start()`` launches every worker and blocks
    until each prints ``WORKER_READY`` (``ready_timeout``); a monitor
    thread then restarts crashed workers until ``stop()`` (also the
    context-manager exit).  ``addresses`` lists the listen-mode workers'
    ``host:port`` endpoints."""

    def __init__(self, specs: Union[WorkerSpec, Sequence[WorkerSpec]],
                 n: Optional[int] = None, *,
                 ready_timeout: float = 30.0, max_restarts: int = 3,
                 restart_backoff: float = 0.25,
                 on_event: Optional[Callable[[dict], None]] = None,
                 log_dir: Optional[str] = None):
        if isinstance(specs, WorkerSpec):
            specs = [specs] * (n if n is not None else 1)
        elif n is not None and len(specs) != n:
            raise ValueError(f"got {len(specs)} specs but n={n}")
        if not specs:
            raise ValueError("WorkerPool needs at least one WorkerSpec")
        self.ready_timeout = ready_timeout
        self.max_restarts = int(max_restarts)
        self.restart_backoff = float(restart_backoff)
        self.on_event = on_event
        self.log_dir = log_dir
        self.events: List[dict] = []
        self._slots = [{"spec": s, "proc": None, "logf": None, "log": None,
                        "pos": 0, "restarts": 0, "address": None}
                       for s in specs]
        self._stop = threading.Event()
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- events

    def _emit(self, event: dict) -> None:
        self.events.append(event)
        if self.on_event is not None:
            self.on_event(event)

    # ----------------------------------------------------------- lifecycle

    def start(self) -> "WorkerPool":
        if self.log_dir is None:
            self.log_dir = tempfile.mkdtemp(prefix="repro-worker-pool-")
        os.makedirs(self.log_dir, exist_ok=True)
        for i in range(len(self._slots)):
            self._launch(i)
        for i in range(len(self._slots)):
            self._wait_ready(i)
        self._thread = threading.Thread(target=self._monitor, daemon=True,
                                        name="repro-worker-pool")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None
        with self._lock:
            for slot in self._slots:
                proc = slot["proc"]
                if proc is not None and proc.poll() is None:
                    proc.terminate()
                    try:
                        proc.wait(timeout=10)
                    except subprocess.TimeoutExpired:
                        proc.kill()
                        proc.wait(timeout=10)
                if slot["logf"] is not None:
                    slot["logf"].close()
                    slot["logf"] = None

    def __enter__(self) -> "WorkerPool":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------ internals

    def _launch(self, i: int) -> None:
        slot = self._slots[i]
        spec: WorkerSpec = slot["spec"]
        if slot["logf"] is not None:
            slot["logf"].close()
        log_path = os.path.join(self.log_dir, f"worker-{i}.log")
        logf = open(log_path, "ab")
        slot["log"] = log_path
        slot["logf"] = logf
        slot["pos"] = logf.tell()     # this incarnation's output starts here
        env = dict(os.environ)
        if spec.env:
            env.update(spec.env)
        slot["proc"] = subprocess.Popen(
            spec.argv(), stdout=logf, stderr=logf, env=env)

    def _scan_ready(self, slot: dict) -> Optional[re.Match]:
        with open(slot["log"], "rb") as f:
            f.seek(slot["pos"])
            data = f.read().decode(errors="replace")
        return READY_RE.search(data)

    def _tail(self, slot: dict, n: int = 20) -> str:
        try:
            with open(slot["log"], "rb") as f:
                data = f.read().decode(errors="replace")
            return "\n".join(data.splitlines()[-n:])
        except OSError:
            return "<no log>"

    def _wait_ready(self, i: int) -> None:
        slot = self._slots[i]
        deadline = time.monotonic() + self.ready_timeout
        while time.monotonic() < deadline:
            m = self._scan_ready(slot)
            if m is not None:
                host, second = m.group(1), m.group(2)
                slot["address"] = None if host == "connect" \
                    else f"{host}:{second}"
                return
            if slot["proc"].poll() is not None:
                raise RuntimeError(
                    f"worker {i} exited (code {slot['proc'].returncode}) "
                    f"before WORKER_READY:\n{self._tail(slot)}")
            time.sleep(0.05)
        raise RuntimeError(
            f"worker {i} not ready within {self.ready_timeout}s:\n"
            f"{self._tail(slot)}")

    def _monitor(self) -> None:
        while not self._stop.is_set():
            for i, slot in enumerate(self._slots):
                proc = slot["proc"]
                if proc is None or proc.poll() is None:
                    continue
                code = proc.returncode
                if code == 0:
                    # clean exit = deliberate end of service; no restart
                    slot["proc"] = None
                    self._emit({"event": "worker_done", "slot": i})
                    continue
                if slot["restarts"] >= self.max_restarts:
                    slot["proc"] = None
                    self._emit({"event": "worker_gave_up", "slot": i,
                                "exit": code,
                                "restarts": slot["restarts"]})
                    continue
                delay = self.restart_backoff * (2 ** slot["restarts"])
                slot["restarts"] += 1
                self._emit({"event": "worker_restart", "slot": i,
                            "exit": code, "attempt": slot["restarts"],
                            "delay_s": round(delay, 3)})
                if self._stop.wait(delay):
                    return
                with self._lock:
                    if self._stop.is_set():
                        return
                    self._launch(i)
            if self._stop.wait(0.1):
                return

    # ------------------------------------------------------------- queries

    @property
    def addresses(self) -> List[str]:
        """``host:port`` endpoints of the listen-mode workers (ready-line
        parsed; connect-mode workers have no address — they dial in)."""
        return [s["address"] for s in self._slots
                if s["address"] is not None]

    @property
    def alive(self) -> int:
        """Number of currently-running worker processes."""
        return sum(1 for s in self._slots
                   if s["proc"] is not None and s["proc"].poll() is None)

    def restarts(self) -> int:
        """Total restarts performed across all slots."""
        return sum(s["restarts"] for s in self._slots)
