"""Always-on autotuning daemon: continuous selective tuning of live traffic.

One-shot studies assume the workload is known up front; a serving fleet is
the opposite — request *shapes* (batch, sequence bucket, architecture)
arrive over time, recur at wildly different rates, and the machine's
timing behaviour slowly drifts underneath them.  This module turns the
session machinery into a long-lived service around four pieces:

- **shape router** (``TuningDaemon.route``): every request shape maps to a
  study key in the world-independent structural-key namespace
  (``core.signatures.structural_key`` — the same identity space the
  statistics bank uses).  An unknown shape opens a per-shape
  ``AutotuneSession`` supplied by the *provider*; a tuned shape serves
  with its winning configuration.
- **fleet profile store** (``FleetStore``): one shared, persistent
  ``StatisticsBank`` absorbing every completed study's harvest.  Entries
  carry ``KernelStats.last_updated`` stamps; the warm-start prior handed
  to new studies is an age-decayed view (``discount_by_age``: evidence
  halves every ``half_life`` seconds, entries beyond ``evidence_ttl`` are
  dropped), so stale fleet knowledge re-earns confidence instead of being
  trusted forever.
- **drift detector** (``DriftDetector``): serving keeps charging live
  per-kernel timings through ``SelectiveTimer`` in shadow mode (every
  ``shadow_every``-th serving step force-executes each kernel once, even
  in the skip regime).  When a kernel's live mean exits its stored confidence
  interval (configurable ``drift_z`` / ``drift_min_samples``), the paper's
  predictability verdict has failed in reverse — the evidence is stale:
  the entry is evicted and every shape whose winner depends on that
  kernel is re-armed for tuning.
- **background re-tunes** (``BackgroundTuner``): studies run off the
  serve loop, each through ``repro.api.scheduler`` (``Scheduler`` +
  pluggable executor — in-process, fork, or remote — with the retry /
  heartbeat machinery), and completed winners are atomically swapped into
  the router by ``pump``.  Serving never stops: a re-tuning shape keeps
  serving its previous winner until the new one lands.

The daemon is generic over a *provider* object binding it to a concrete
study family (duck-typed):

- ``session_for(key, meta, prior) -> AutotuneSession`` — the per-shape
  study (``collect_stats=True`` so its harvest feeds the fleet store);
- ``kernels_for(key, meta, winner_name) -> [(Signature, thunk, freq)]``
  — the winner's serving-side kernel occurrence list;
- ``kernel_keys(key, meta, winner_name) -> [str]`` — the structural keys
  the winner depends on (drift re-arm fan-out), computable without
  compiling.

``repro.serve.tuner`` is the LM-serving binding.  Daemon state (winners,
fleet bank, event journal, in-flight studies) checkpoints atomically and
restores across restarts.
"""

from __future__ import annotations

import math
import os
import queue as _queue
import tempfile
import threading
import time
import traceback
import json
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.core.policies import Policy, policy as make_policy
from repro.core.signatures import Signature, structural_key
from repro.core.stats import KernelStats

from .result import StudyResult
from .scheduler import Executor, InProcessExecutor, Scheduler
from .session import AutotuneSession, run_payload
from .transfer import StatisticsBank

DAEMON_VERSION = 1

#: shape lifecycle states (``TuningDaemon.state``)
MISS = "miss"            # never seen (transient; returned by route only)
TUNING = "tuning"        # first study in flight, serving untuned
TUNED = "tuned"          # winner installed
RETUNING = "retuning"    # drift re-tune in flight, serving the old winner


@dataclass
class DaemonConfig:
    """Daemon-level knobs (study-level knobs live on the provider)."""

    #: serving-side selective policy; eager pre-switches banked-confident
    #: kernels off machine-wide, so a tuned shape's second occurrence runs
    #: zero kernels for banked signatures
    serve_policy: str = "eager"
    serve_tolerance: float = 0.25
    serve_min_samples: int = 2
    #: fleet evidence half-life (seconds) for the age-decayed prior view
    half_life: float = 3600.0
    #: drop fleet entries older than this many seconds (None = never)
    evidence_ttl: Optional[float] = None
    #: every Nth serving step of a shape is a shadow step force-executing
    #: one occurrence of each kernel; 0 disables shadow sampling (and
    #: with it drift detection)
    shadow_every: int = 8
    #: drift verdict: live mean outside z * stored-std/sqrt(n), after at
    #: least min_samples live shadow samples; window bounds the live run
    drift_z: float = 4.0
    drift_min_samples: int = 4
    drift_window: int = 64
    #: background-study retry policy (``repro.api.scheduler``)
    max_retries: int = 1
    retry_backoff: float = 0.05
    #: run studies inline inside ``submit`` (deterministic tests) instead
    #: of on the background thread — same Scheduler path either way
    synchronous: bool = False


# ---------------------------------------------------------------- fleet store

class FleetStore:
    """The fleet-wide kernel profile store: one ``StatisticsBank`` shared
    by every shape's study, with wall-clock evidence aging.

    ``absorb`` merges a completed study's harvest (stamping new evidence
    with the current time); ``record`` accrues a single live shadow
    sample; ``prior`` is the age-decayed warm-start view handed to new
    studies; ``evict`` drops entries the drift detector has invalidated.
    Persistence goes through ``StatisticsBank.save`` (mkstemp + fsync +
    atomic replace), so a crash mid-flush can never corrupt the bank.
    """

    def __init__(self, bank: Optional[StatisticsBank] = None, *,
                 clock: Callable[[], float] = time.time,
                 half_life: float = 3600.0, ttl: Optional[float] = None):
        self.bank = bank if bank is not None else StatisticsBank()
        self.clock = clock
        self.half_life = half_life
        self.ttl = ttl
        self._lock = threading.Lock()

    def __len__(self) -> int:
        return len(self.bank)

    def prior(self) -> StatisticsBank:
        """Age-decayed warm-start view (a new bank; the store unchanged)."""
        with self._lock:
            return self.bank.discount_by_age(self.clock(), self.half_life,
                                             ttl=self.ttl)

    def absorb(self, bank: Optional[StatisticsBank]) -> int:
        """Merge a harvest in, stamping its unstamped entries with now."""
        if not bank:
            return 0
        inc = StatisticsBank({k: v.copy() for k, v in bank.entries.items()},
                             meta=list(bank.meta))
        inc.stamp(self.clock())
        with self._lock:
            self.bank = self.bank.merge(inc)
        return len(inc)

    def record(self, key: str, t: float) -> None:
        """Accrue one live shadow sample into the store (fresh stamp)."""
        with self._lock:
            st = self.bank.entries.get(key)
            if st is None:
                st = self.bank.entries[key] = KernelStats()
            st.update(t)
            st.last_updated = self.clock()

    def reference(self, key: str) -> Optional[KernelStats]:
        with self._lock:
            st = self.bank.entries.get(key)
            return st.copy() if st is not None else None

    def evict(self, keys: Sequence[str]) -> int:
        with self._lock:
            n = 0
            for k in keys:
                if self.bank.entries.pop(k, None) is not None:
                    n += 1
            return n

    def save(self, path: str) -> None:
        with self._lock:
            self.bank.save(path)

    def load(self, path: str) -> None:
        bank = StatisticsBank.load(path)
        with self._lock:
            self.bank = bank


# -------------------------------------------------------------- drift detector

class DriftDetector:
    """The predictability verdict run in reverse: evidence going stale.

    Per kernel key, live shadow samples accumulate in a window whose
    reference — the stored mean and a ``z * std / sqrt(n)`` half-width —
    is snapshotted from the fleet store when the window opens.  Once the
    window holds ``min_samples`` live samples, a live mean outside the
    reference interval is drift; the window also recycles after
    ``window`` samples so the reference tracks accepted evidence.
    """

    def __init__(self, store: FleetStore, *, z: float = 4.0,
                 min_samples: int = 4, window: int = 64):
        self.store = store
        self.z = z
        self.min_samples = max(int(min_samples), 1)
        self.window = max(int(window), self.min_samples)
        self._ref: Dict[str, Tuple[float, float]] = {}
        self._live: Dict[str, KernelStats] = {}

    def reset(self, key: str) -> None:
        self._ref.pop(key, None)
        self._live.pop(key, None)

    def observe(self, key: str, t: float) -> bool:
        """Fold one live sample; True exactly when drift is declared."""
        ref = self._ref.get(key)
        if ref is None:
            st = self.store.reference(key)
            if st is None or st.n < 2:
                return False            # nothing stored to drift from
            hw = self.z * st.std / math.sqrt(st.n)
            if not math.isfinite(hw):
                return False
            ref = self._ref[key] = (st.mean, hw)
            self._live[key] = KernelStats()
        live = self._live[key]
        live.update(t)
        if live.n < self.min_samples:
            return False
        drifted = abs(live.mean - ref[0]) > ref[1]
        if drifted or live.n >= self.window:
            self.reset(key)             # next sample opens a fresh window
        return drifted


# ------------------------------------------------------------------ checkpoint

class DaemonCheckpoint:
    """Atomic JSON snapshot of daemon state — the ``_Checkpoint._flush``
    durability discipline (same-directory mkstemp, fsync, ``os.replace``):
    a daemon killed mid-save leaves either the old snapshot or the new
    one, never a truncated hybrid."""

    @staticmethod
    def save(path: str, data: dict) -> None:
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(
            prefix=os.path.basename(path) + ".", suffix=".tmp", dir=d)
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(data, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    @staticmethod
    def load(path: str) -> dict:
        with open(path) as f:
            data = json.load(f)
        if not isinstance(data, dict) \
                or data.get("version") != DAEMON_VERSION:
            raise ValueError(f"{path}: not a daemon checkpoint "
                             f"(want version {DAEMON_VERSION})")
        return data


# ------------------------------------------------------------ background tuner

class BackgroundTuner:
    """Runs per-shape studies off the serve loop, each through the
    scheduler subsystem (retries/backoff, recovery events, pluggable
    executors — ``executor_factory`` builds a fresh executor per study, so
    fork pools and remote fleets plug in unchanged).

    ``submit`` enqueues; a single worker thread drains jobs (one study at
    a time — wall-clock backends measure serially); ``drain`` returns
    completed ``(key, tag, result_json | None, error | None)`` tuples for
    the daemon's ``pump`` to apply.  ``synchronous=True`` runs the study
    inline inside ``submit`` through the *same* Scheduler path
    (deterministic tests, fork-vs-in-process parity checks).
    """

    def __init__(self, *, executor_factory: Optional[
                     Callable[[], Executor]] = None,
                 max_retries: int = 1, retry_backoff: float = 0.05,
                 on_event: Optional[Callable[[dict], None]] = None,
                 synchronous: bool = False):
        self.executor_factory = executor_factory or InProcessExecutor
        self.max_retries = max_retries
        self.retry_backoff = retry_backoff
        self.on_event = on_event
        self.synchronous = synchronous
        self._jobs: _queue.Queue = _queue.Queue()
        self._done: _queue.Queue = _queue.Queue()
        self._thread: Optional[threading.Thread] = None
        self._closed = False

    def submit(self, key: str, session: AutotuneSession, *,
               tag: str = "tune") -> None:
        job = (key, session, self._payload(session), tag)
        if self.synchronous:
            self._run(job)
            return
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._loop, name="repro-daemon-tuner", daemon=True)
            self._thread.start()
        self._jobs.put(job)

    @staticmethod
    def _payload(session: AutotuneSession) -> dict:
        pol = session._policy()
        return session._task_payload(
            (pol.name, pol.tolerance, session.seed, session.allocation),
            session.prior, collect=True, shared=False)

    def _run(self, job) -> None:
        key, session, payload, tag = job
        executor = self.executor_factory()

        def runner(p: dict) -> dict:
            return run_payload(session.space, session.backend, p,
                               session=session)

        try:
            tasks = Scheduler(executor, runner,
                              max_retries=self.max_retries,
                              retry_backoff=self.retry_backoff,
                              on_failure="raise",
                              on_event=self.on_event).run(
                [(0, key)], prepare=lambda task: payload)
            self._done.put((key, tag, tasks[0].result, None))
        except Exception:
            self._done.put((key, tag, None, traceback.format_exc()))

    def _loop(self) -> None:
        while True:
            job = self._jobs.get()
            if job is None:
                return
            self._run(job)

    def drain(self) -> List[Tuple[str, str, Optional[dict], Optional[str]]]:
        out = []
        while True:
            try:
                out.append(self._done.get_nowait())
            except _queue.Empty:
                return out

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._thread is not None:
            self._jobs.put(None)
            self._thread.join(timeout=30.0)


# ------------------------------------------------------------ per-shape server

class _ShapeServer:
    """Serving-side selective timer for one tuned shape.

    Kernels run through a ``SelectiveTimer`` seeded from the fleet prior
    (eager serving pre-switches banked-confident kernels off), except
    that every ``shadow_every``-th serving step is a *shadow step*: the
    first occurrence of each kernel in it is force-executed — a real
    measured sample that keeps live evidence flowing to the drift
    detector after the skip regime is reached, while non-shadow steps
    (including a tuned shape's first and second) run banked kernels zero
    times.
    """

    def __init__(self, kernels, policy: Policy, prior: StatisticsBank,
                 clock: Callable[[], float], shadow_every: int):
        from repro.tune.selective import SelectiveTimer
        self.kernels = list(kernels)
        self.shadow_every = int(shadow_every)
        self.timer = SelectiveTimer(
            policy, clock=clock,
            prior_lookup=prior.resolver(1) if prior else None)
        self.banked: Set[str] = set(prior.entries) if prior else set()
        self._steps = 0
        self._keys: Dict[Signature, str] = {}

    def _key(self, sig: Signature) -> str:
        k = self._keys.get(sig)
        if k is None:
            k = self._keys[sig] = structural_key(sig, 1)
        return k

    def step(self) -> dict:
        t = self.timer
        t.begin_iteration()
        self._steps += 1
        shadow = self.shadow_every > 0 \
            and self._steps % self.shadow_every == 0
        seen: Set[Signature] = set()
        samples: List[Tuple[str, float]] = []
        forced = 0
        cold_banked = 0
        for sig, thunk, freq in self.kernels:
            force = shadow and sig not in seen
            seen.add(sig)
            before = t._nexec
            charged = t.time_kernel(sig, thunk, freq, force=force)
            if t._nexec > before:       # really executed: charged == sample
                key = self._key(sig)
                samples.append((key, charged))
                if force:
                    forced += 1
                elif key in self.banked:
                    cold_banked += 1    # a banked kernel re-ran cold
        rep = t.report()
        return {"executed": rep.executed, "skipped": rep.skipped,
                "forced": forced, "cold_banked": cold_banked,
                "charged": rep.predicted_time, "samples": samples}


# ----------------------------------------------------------------- the daemon

class TuningDaemon:
    """The always-on tuning service: route -> warm-start -> serve ->
    drift -> re-tune (see the module docstring for the architecture)."""

    def __init__(self, provider, *, clock: Callable[[], float] = time.time,
                 config: Optional[DaemonConfig] = None,
                 fleet: Optional[FleetStore] = None,
                 checkpoint: Optional[str] = None,
                 executor_factory: Optional[Callable[[], Executor]] = None):
        self.provider = provider
        self.clock = clock
        self.cfg = config or DaemonConfig()
        self.checkpoint_path = checkpoint
        self.fleet = fleet if fleet is not None else FleetStore(
            clock=clock, half_life=self.cfg.half_life,
            ttl=self.cfg.evidence_ttl)
        self.drift = DriftDetector(
            self.fleet, z=self.cfg.drift_z,
            min_samples=self.cfg.drift_min_samples,
            window=self.cfg.drift_window)
        self.tuner = BackgroundTuner(
            executor_factory=executor_factory,
            max_retries=self.cfg.max_retries,
            retry_backoff=self.cfg.retry_backoff,
            on_event=self._scheduler_event,
            synchronous=self.cfg.synchronous)
        self._serve_policy = make_policy(
            self.cfg.serve_policy, tolerance=self.cfg.serve_tolerance,
            min_samples=self.cfg.serve_min_samples)
        self._lock = threading.RLock()
        #: shape key -> lifecycle state (TUNING/TUNED/RETUNING)
        self.state: Dict[str, str] = {}
        #: shape key -> installed winner {"name", "params", "predicted",
        #: "kernels": [structural keys]}
        self.winners: Dict[str, dict] = {}
        #: shape key -> the JSON-able meta route() was given
        self.meta: Dict[str, dict] = {}
        #: kernel structural key -> shape keys whose winner depends on it
        self.deps: Dict[str, Set[str]] = {}
        #: the event journal (every route/tune/drift/recovery event)
        self.events: List[dict] = []
        self.counters: Dict[str, int] = {
            "hits": 0, "misses": 0, "warm_starts": 0, "cold_starts": 0,
            "retunes": 0, "drifts": 0, "forced": 0, "cold_banked_exec": 0}
        self._servers: Dict[str, _ShapeServer] = {}
        if checkpoint and os.path.exists(checkpoint):
            self._restore(DaemonCheckpoint.load(checkpoint))

    # -- journal -------------------------------------------------------------

    def _journal(self, event: str, **fields) -> None:
        with self._lock:
            entry = {"seq": len(self.events), "t": self.clock(),
                     "event": event}
            entry.update(fields)
            self.events.append(entry)

    def _scheduler_event(self, ev: dict) -> None:
        """Recovery events (retries, worker loss, deadlines) from the
        background scheduler, folded into the daemon journal."""
        self._journal("scheduler", **{k: v for k, v in ev.items()
                                      if k != "event"},
                      kind=ev.get("event"))

    # -- shape router --------------------------------------------------------

    def route(self, key: str, meta: dict) -> Tuple[str, Optional[dict]]:
        """Resolve a request shape: ``(state, winner-or-None)``.  A never-
        seen shape opens its study (returning ``("miss", None)``); a shape
        mid-study serves untuned; a tuned (or re-tuning) shape serves its
        installed winner."""
        with self._lock:
            st = self.state.get(key)
            if st in (TUNED, RETUNING):
                return st, self.winners[key]
            if st == TUNING:
                return TUNING, None
            self.counters["misses"] += 1
            self.meta[key] = dict(meta)
            self._open_study(key, tag="tune")
            return MISS, None

    def _open_study(self, key: str, *, tag: str) -> None:
        prior = self.fleet.prior()
        warm = len(prior) > 0
        if tag == "tune":
            self.counters["warm_starts" if warm else "cold_starts"] += 1
        session = self.provider.session_for(key, self.meta[key],
                                            prior if warm else None)
        self.state[key] = TUNING if tag == "tune" else RETUNING
        self._journal(f"{tag}_started", shape=key, warm=warm,
                      prior_entries=len(prior))
        self.tuner.submit(key, session, tag=tag)

    # -- study completion ----------------------------------------------------

    def pump(self) -> int:
        """Apply completed background studies: absorb harvests into the
        fleet store, atomically swap winners into the router, rebuild the
        dependency fan-out.  Returns how many results were applied.  Call
        from the serve loop (cheap when nothing completed)."""
        applied = 0
        for key, tag, result_json, err in self.tuner.drain():
            with self._lock:
                if err is not None:
                    self._journal("study_failed", shape=key, tag=tag,
                                  error=err.strip().splitlines()[-1])
                    # forget the in-flight state: the next request (or
                    # drift verdict) re-opens the study
                    if self.state.get(key) == TUNING:
                        self.state.pop(key, None)
                    elif self.state.get(key) == RETUNING:
                        self.state[key] = TUNED
                    continue
                self._apply(key, tag, StudyResult.from_json(result_json))
                applied += 1
        if applied and self.checkpoint_path:
            self.save_checkpoint()
        return applied

    def _apply(self, key: str, tag: str, result: StudyResult) -> None:
        rec = result.chosen
        old = self.winners.get(key)
        kernels = sorted(self.provider.kernel_keys(key, self.meta[key],
                                                   rec.name))
        self.fleet.absorb(result.stats_bank())
        self.winners[key] = {"name": rec.name, "params": rec.params,
                             "predicted": rec.predicted, "kernels": kernels}
        self.state[key] = TUNED
        for kk in kernels:
            self.deps.setdefault(kk, set()).add(key)
        self._servers.pop(key, None)   # rebind serving to the new winner
        if tag == "retune":
            self.counters["retunes"] += 1
        self._journal(f"{tag}_complete", shape=key, winner=rec.name,
                      previous=old["name"] if old else None,
                      executed=sum(r.executed for r in result.records),
                      skipped=sum(r.skipped for r in result.records))

    # -- serving -------------------------------------------------------------

    def serve(self, key: str, meta: dict) -> dict:
        """One serving step for a request shape: route it, and — when a
        winner is installed — run the winner's kernels through the
        shadow-mode selective timer, feeding forced samples to the drift
        detector and the fleet store."""
        state, winner = self.route(key, meta)
        info = {"shape": key, "state": state,
                "winner": winner["name"] if winner else None,
                "executed": 0, "skipped": 0, "forced": 0,
                "cold_banked": 0, "charged": 0.0}
        if winner is None:
            return info
        with self._lock:
            self.counters["hits"] += 1
            srv = self._servers.get(key)
            if srv is None:
                srv = self._servers[key] = _ShapeServer(
                    self.provider.kernels_for(key, self.meta[key],
                                              winner["name"]),
                    self._serve_policy, self.fleet.prior(), self.clock,
                    self.cfg.shadow_every)
        out = srv.step()
        samples = out.pop("samples")
        info.update(out)
        with self._lock:
            self.counters["forced"] += out["forced"]
            self.counters["cold_banked_exec"] += out["cold_banked"]
        for kkey, t in samples:
            self._observe(kkey, t)
        return info

    def _observe(self, kernel_key: str, t: float) -> None:
        """Fold one live kernel sample: drift verdict first (against the
        stored reference), then fleet accrual."""
        drifted = self.drift.observe(kernel_key, t)
        if not drifted:
            self.fleet.record(kernel_key, t)
            return
        with self._lock:
            self.counters["drifts"] += 1
            dependents = sorted(self.deps.get(kernel_key, ()))
            self._journal("drift_detected", kernel=kernel_key,
                          shapes=dependents)
            # stale evidence: the re-tune must measure this kernel fresh
            self.fleet.evict([kernel_key])
            for skey in dependents:
                # the stale-timed server must not keep charging old means
                self._servers.pop(skey, None)
                if self.state.get(skey) == TUNED:
                    self._open_study(skey, tag="retune")

    # -- checkpoint / restore ------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-able daemon state: winners, fleet bank, journal, and the
        in-flight studies (resubmitted on restore)."""
        with self._lock:
            pending = [[k, "tune" if v == TUNING else "retune"]
                       for k, v in self.state.items()
                       if v in (TUNING, RETUNING)]
            return {"version": DAEMON_VERSION,
                    "winners": {k: dict(v) for k, v in self.winners.items()},
                    "meta": {k: dict(v) for k, v in self.meta.items()},
                    "pending": pending,
                    "bank": self.fleet.bank.to_json(),
                    "events": list(self.events),
                    "counters": dict(self.counters)}

    def save_checkpoint(self, path: Optional[str] = None) -> None:
        path = path or self.checkpoint_path
        if not path:
            raise ValueError("no checkpoint path configured")
        DaemonCheckpoint.save(path, self.snapshot())

    def _restore(self, data: dict) -> None:
        self.fleet.bank = StatisticsBank.from_json(data["bank"])
        self.winners = {k: dict(v) for k, v in data["winners"].items()}
        self.meta = {k: dict(v) for k, v in data.get("meta", {}).items()}
        self.events = list(data.get("events", []))
        self.counters.update(data.get("counters", {}))
        for k, w in self.winners.items():
            self.state[k] = TUNED
            for kk in w.get("kernels", ()):
                self.deps.setdefault(kk, set()).add(k)
        self._journal("restored", winners=len(self.winners),
                      bank_entries=len(self.fleet.bank),
                      pending=len(data.get("pending", ())))
        # studies that were in flight at the kill are resubmitted; their
        # warm-start prior is rebuilt from the restored fleet bank
        for k, tag in data.get("pending", ()):
            if k in self.meta and self.state.get(k) != TUNING:
                if tag == "retune" and k in self.winners:
                    self._open_study(k, tag="retune")
                elif k not in self.winners:
                    self._open_study(k, tag="tune")

    # -- lifecycle -----------------------------------------------------------

    def ratios(self) -> Dict[str, float]:
        """Hit/miss summary for dashboards and the CI smoke stage."""
        c = self.counters
        total = c["hits"] + c["misses"]
        opened = c["warm_starts"] + c["cold_starts"]
        return {"hit_ratio": c["hits"] / total if total else 0.0,
                "warm_start_ratio":
                    c["warm_starts"] / opened if opened else 0.0,
                **{k: float(v) for k, v in c.items()}}

    def close(self, *, checkpoint: bool = True) -> None:
        self.tuner.close()
        self.pump()
        if checkpoint and self.checkpoint_path:
            self.save_checkpoint()
