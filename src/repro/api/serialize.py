"""Lossless JSON encoding for study artifacts.

Study results carry values JSON cannot represent natively — tuples inside
``ConfigRecord.params`` (signature dims, grid shapes), NumPy scalars from
vectorized reductions, and infinities from unbounded CIs.  ``to_jsonable``
/ ``from_jsonable`` give them a tagged, round-trip-exact encoding shared
by session checkpoints, ``StudyResult.to_json`` and the
``benchmarks/results/`` writers:

- tuples   -> {"__tuple__": [...]}            (lists stay lists)
- inf/nan  -> {"__float__": "inf"|"-inf"|"nan"}
- np ints/floats/bools -> their Python equivalents (value-lossless)

Everything else must already be JSON-native; unknown objects raise rather
than silently degrading to ``str``.
"""

from __future__ import annotations

import json
import math
from typing import Any

import numpy as np

_FLOAT_TAGS = {"inf": math.inf, "-inf": -math.inf, "nan": math.nan}


def dumps_canonical(v: Any) -> str:
    """One canonical JSON string per value: tagged encoding, sorted keys.
    This is the identity form shared by session checkpoint keys and
    statistics-bank fingerprints — two values compare equal iff their
    canonical strings do.  The separators are json.dumps's defaults ON
    PURPOSE: for JSON-native values this reproduces the historical
    ``json.dumps(key, sort_keys=True)`` checkpoint-key format byte for
    byte, so journals written before this helper existed keep
    resolving."""
    return json.dumps(to_jsonable(v), sort_keys=True)


def to_jsonable(v: Any) -> Any:
    if isinstance(v, bool) or v is None or isinstance(v, str):
        return v
    if isinstance(v, (np.bool_,)):
        return bool(v)
    if isinstance(v, (int, np.integer)):
        return int(v)
    if isinstance(v, (float, np.floating)):
        v = float(v)
        if math.isinf(v):
            return {"__float__": "inf" if v > 0 else "-inf"}
        if math.isnan(v):
            return {"__float__": "nan"}
        return v
    if isinstance(v, tuple):
        return {"__tuple__": [to_jsonable(x) for x in v]}
    if isinstance(v, (list, np.ndarray)):
        return [to_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): to_jsonable(x) for k, x in v.items()}
    raise TypeError(f"cannot serialize {type(v).__name__}: {v!r}")


def from_jsonable(v: Any) -> Any:
    if isinstance(v, dict):
        if "__tuple__" in v and len(v) == 1:
            return tuple(from_jsonable(x) for x in v["__tuple__"])
        if "__float__" in v and len(v) == 1:
            return _FLOAT_TAGS[v["__float__"]]
        return {k: from_jsonable(x) for k, x in v.items()}
    if isinstance(v, list):
        return [from_jsonable(x) for x in v]
    return v
