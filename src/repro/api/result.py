"""Uniform study results for every tuning path.

``ConfigRecord`` is the per-configuration measurement row and
``StudyResult`` the study-level report, shared by all backends (virtual
machine, wall clock, dry run) and both search drivers.  They carry the
paper's §VI.A quantities — relative prediction error, autotuning speedup,
optimum selection quality — plus backend/search provenance, and round-trip
losslessly through JSON (``to_json``/``from_json``), which is what session
checkpointing, the parallel sweep's result pipes, and the
``benchmarks/results/`` writers all rely on.

``repro.core.tuner`` re-exports these under their historical names
(``ConfigRecord``, ``StudyReport``) for pinned tests; new code should
import from ``repro.api``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List

import numpy as np

from .serialize import from_jsonable, to_jsonable


@dataclass
class ConfigRecord:
    """One configuration's measurements (identical across backends)."""

    name: str
    params: dict
    full_time: float          # full-execution reference performed just prior
    predicted: float          # selective-execution estimate (last trial)
    rel_error: float
    comp_error: float
    selective_cost: float     # wall time paid for this config's trials
    full_cost: float          # what full execution would have paid
    executed: int
    skipped: int
    predictions: List[float] = field(default_factory=list)
    extra: dict = field(default_factory=dict)   # backend-specific payload

    def to_json(self) -> dict:
        return {
            "name": self.name, "params": to_jsonable(self.params),
            "full_time": to_jsonable(self.full_time),
            "predicted": to_jsonable(self.predicted),
            "rel_error": to_jsonable(self.rel_error),
            "comp_error": to_jsonable(self.comp_error),
            "selective_cost": to_jsonable(self.selective_cost),
            "full_cost": to_jsonable(self.full_cost),
            "executed": int(self.executed), "skipped": int(self.skipped),
            "predictions": to_jsonable(self.predictions),
            "extra": to_jsonable(self.extra),
        }

    @classmethod
    def from_json(cls, d: dict) -> "ConfigRecord":
        return cls(
            name=d["name"], params=from_jsonable(d["params"]),
            full_time=from_jsonable(d["full_time"]),
            predicted=from_jsonable(d["predicted"]),
            rel_error=from_jsonable(d["rel_error"]),
            comp_error=from_jsonable(d["comp_error"]),
            selective_cost=from_jsonable(d["selective_cost"]),
            full_cost=from_jsonable(d["full_cost"]),
            executed=d["executed"], skipped=d["skipped"],
            predictions=from_jsonable(d["predictions"]),
            extra=from_jsonable(d.get("extra", {})))


@dataclass
class StudyResult:
    """What one (study, policy, tolerance) tuning run produced."""

    study: str
    policy: str
    tolerance: float
    records: List[ConfigRecord]
    full_tuning_time: float
    selective_tuning_time: float
    backend: str = ""
    search: str = "exhaustive"
    seed: int = 0
    allocation: int = 0
    wall_s: float = 0.0
    extra: dict = field(default_factory=dict)   # search-specific artifacts

    @property
    def speedup(self) -> float:
        if self.full_tuning_time <= 0:
            # no full-execution reference (racing never measures one):
            # a full/selective ratio is undefined, not zero
            return math.nan
        if self.selective_tuning_time <= 0:
            return math.inf
        return self.full_tuning_time / self.selective_tuning_time

    @property
    def mean_error(self) -> float:
        return float(np.mean([r.rel_error for r in self.records]))

    @property
    def mean_comp_error(self) -> float:
        return float(np.mean([r.comp_error for r in self.records]))

    @property
    def chosen(self) -> ConfigRecord:
        return min(self.records, key=lambda r: r.predicted)

    @property
    def true_best(self) -> ConfigRecord:
        return min(self.records, key=lambda r: r.full_time)

    @property
    def optimum_quality(self) -> float:
        """full-execution time of the truly-best config divided by that of
        the chosen config (1.0 = optimal choice; paper reports >= 0.99).
        NaN when the study has no full-execution reference (racing)."""
        chosen = self.chosen.full_time
        if chosen <= 0:
            return math.nan
        return self.true_best.full_time / chosen

    def stats_bank(self):
        """The per-kernel statistics bank a ``collect_stats=True`` session
        attached to this result (``None`` when the study did not collect)
        — feed it to a later session as ``prior=`` (see
        ``repro.api.transfer``)."""
        if "kernel_stats" not in self.extra:
            return None
        from .transfer import StatisticsBank
        return StatisticsBank.from_result(self)

    def row(self) -> dict:
        return {
            "study": self.study, "policy": self.policy,
            "tolerance": self.tolerance, "speedup": self.speedup,
            "mean_error": self.mean_error,
            "mean_comp_error": self.mean_comp_error,
            "optimum_quality": self.optimum_quality,
            "full_time": self.full_tuning_time,
            "selective_time": self.selective_tuning_time,
        }

    def to_json(self) -> dict:
        return {
            "study": self.study, "policy": self.policy,
            "tolerance": to_jsonable(self.tolerance),
            "records": [r.to_json() for r in self.records],
            "full_tuning_time": to_jsonable(self.full_tuning_time),
            "selective_tuning_time":
                to_jsonable(self.selective_tuning_time),
            "backend": self.backend, "search": self.search,
            "seed": int(self.seed), "allocation": int(self.allocation),
            "wall_s": to_jsonable(self.wall_s),
            "extra": to_jsonable(self.extra),
        }

    @classmethod
    def from_json(cls, d: dict) -> "StudyResult":
        return cls(
            study=d["study"], policy=d["policy"],
            tolerance=from_jsonable(d["tolerance"]),
            records=[ConfigRecord.from_json(r) for r in d["records"]],
            full_tuning_time=from_jsonable(d["full_tuning_time"]),
            selective_tuning_time=from_jsonable(
                d["selective_tuning_time"]),
            backend=d.get("backend", ""),
            search=d.get("search", "exhaustive"),
            seed=d.get("seed", 0), allocation=d.get("allocation", 0),
            wall_s=from_jsonable(d.get("wall_s", 0.0)),
            extra=from_jsonable(d.get("extra", {})))
