"""3D matrix multiplication (Agarwal et al. / ACS) in shard_map.

C(x,y) = sum_z A(x,z) . B(z,y): each of the p^{1/3} 'z' layers computes a
rank-K/p^{1/3} partial product from its A column-block and B row-block; the
reduction over 'z' is the single psum — broadcast-free because the inputs
are *distributed* over (x,z)/(z,y) planes rather than replicated.  This is
exactly the product kernel of Capital's Cholesky (paper §V.A): "broadcasts
along two dimensions of the processor grid, and a reduction along the
third" — in the shard_map formulation the broadcasts become the implicit
resharding of the operands' layouts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.compat import AxisType, make_mesh


def make_3d_mesh(c: int) -> Mesh:
    """c x c x c mesh with axes (x, y, z) over c^3 devices."""
    return make_mesh((c, c, c), ("x", "y", "z"),
                     axis_types=(AxisType.Auto,) * 3)


def matmul_3d(a, b, mesh: Mesh):
    """a: (M, K) laid out P('x', 'z'); b: (K, N) laid out P('z', 'y');
    returns c: (M, N) laid out P('x', 'y') (replicated over z)."""

    def body(al, bl):
        c_part = jnp.dot(al, bl, preferred_element_type=jnp.float32)
        return jax.lax.psum(c_part, "z").astype(al.dtype)

    fn = compat.shard_map(body, mesh=mesh,
                       in_specs=(P("x", "z"), P("z", "y")),
                       out_specs=P("x", "y"), check_vma=False)
    return fn(a, b)
