"""Capital's recursive Cholesky (+ triangular inverse) on the 3D mesh.

    [A11      ]   [L11     ] [L11^T L21^T]
    [A21  A22 ] = [L21  L22] [      L22^T]

Base case (paper strategy 2): the sub-block is gathered (replicated
sharding constraint) and factorized redundantly on every device —
all-gather + redundant potrf/trtri.  Products L21 = A21 L11^{-T} and
S = A22 - L21 L21^T run through the 3D matmul kernel.  The inverse is
maintained through the recursion (Capital's inverse-based formulation):

    inv([L11 0; L21 L22]) = [Linv11 0; -Linv22 L21 Linv11, Linv22]

The block-size trade-off (few large base cases vs many small ones +
more 3D products) is the latency/bandwidth knob the autotuning study
sweeps (simmpi reproduces the cost side; this module proves the schedule
is a real runnable JAX program).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .matmul3d import matmul_3d


def _constrain(x, mesh, spec):
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _base(a, mesh):
    """Replicated base-case factorization: L, L^{-1} (strategy 2)."""
    a = _constrain(a, mesh, P())        # all-gather, factor redundantly
    l = jnp.linalg.cholesky(a)
    linv = jax.scipy.linalg.solve_triangular(
        l, jnp.eye(a.shape[0], dtype=a.dtype), lower=True)
    return l, linv


def cholesky_3d(a, mesh: Mesh, block: int):
    """a: (n, n) SPD, laid out P('x', 'y').  Returns (L, Linv) in the same
    layout.  n and block must be powers of two with block | n."""
    n = a.shape[0]
    if n <= block:
        l, linv = _base(a, mesh)
        return (_constrain(l, mesh, P("x", "y")),
                _constrain(linv, mesh, P("x", "y")))
    h = n // 2
    a11 = a[:h, :h]
    a21 = a[h:, :h]
    a22 = a[h:, h:]

    l11, linv11 = cholesky_3d(a11, mesh, block)
    # L21 <- A21 . L11^{-T}           (3D product)
    a21_xz = _constrain(a21, mesh, P("x", "z"))
    linv11t_zy = _constrain(linv11.T, mesh, P("z", "y"))
    l21 = matmul_3d(a21_xz, linv11t_zy, mesh)
    # S <- A22 - L21 . L21^T          (3D symmetric update)
    l21_xz = _constrain(l21, mesh, P("x", "z"))
    l21t_zy = _constrain(l21.T, mesh, P("z", "y"))
    s = a22 - matmul_3d(l21_xz, l21t_zy, mesh)
    l22, linv22 = cholesky_3d(s, mesh, block)
    # Linv21 <- -Linv22 . L21 . Linv11
    t = matmul_3d(_constrain(l21, mesh, P("x", "z")),
                  _constrain(linv11, mesh, P("z", "y")), mesh)
    linv21 = -matmul_3d(_constrain(linv22, mesh, P("x", "z")),
                        _constrain(t, mesh, P("z", "y")), mesh)

    zero = jnp.zeros((h, h), a.dtype)
    l = jnp.block([[l11, zero], [l21, l22]])
    linv = jnp.block([[linv11, zero], [linv21, linv22]])
    return (_constrain(l, mesh, P("x", "y")),
            _constrain(linv, mesh, P("x", "y")))
