"""jaxdist — the paper's dense linear algebra as real JAX shard_map
programs (the simmpi layer *simulates* the schedules for the autotuning
study; this package *executes* them on a device mesh).

- matmul3d: Agarwal/ACS 3D matmul — broadcast along two mesh axes, reduce
  along the third (the communication pattern of Capital's Cholesky products)
- cholesky3d: Capital's recursive Cholesky(+inverse) over the 3D mesh with
  replicated base-case factorization (base strategy 2 of the paper)
- tsqr: communication-avoiding tall-skinny QR over the row axis (CANDMC's
  panel kernel)
"""

from .matmul3d import matmul_3d, make_3d_mesh
from .cholesky3d import cholesky_3d
from .tsqr import tsqr

__all__ = ["matmul_3d", "make_3d_mesh", "cholesky_3d", "tsqr"]
