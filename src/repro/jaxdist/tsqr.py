"""Communication-avoiding tall-skinny QR (CANDMC's panel kernel).

One-level CAQR over the 'row' axis: local householder QR of each row block,
all-gather of the p (n x n) R factors, redundant QR of the stacked (p·n, n)
matrix, and a local product to recover this block's slice of Q.  Wire
traffic is p·n² (the R stack) instead of the m·n a gather-based panel
factorization would move — the communication-avoiding trade the paper's
QR studies tune around.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat


def tsqr(a, mesh: Mesh, axis: str = "x"):
    """a: (m, n) with m row-sharded over ``axis`` (m % p == 0, m/p >= n).
    Returns (Q (m, n) row-sharded, R (n, n) replicated over ``axis``)."""
    p = mesh.shape[axis]
    n = a.shape[1]

    def body(al):
        al = al[0] if al.ndim == 3 else al       # (m/p, n)
        q1, r1 = jnp.linalg.qr(al, mode="reduced")
        stack = jax.lax.all_gather(r1, axis, axis=0, tiled=False)
        q2, r = jnp.linalg.qr(stack.reshape(p * n, n), mode="reduced")
        i = jax.lax.axis_index(axis)
        q2_mine = jax.lax.dynamic_slice_in_dim(q2, i * n, n, axis=0)
        q = q1 @ q2_mine
        return q, r

    other = [ax for ax in mesh.axis_names if ax != axis]
    fn = compat.shard_map(
        body, mesh=mesh,
        in_specs=P(axis, None),
        out_specs=(P(axis, None), P(*[None] * 2)),
        check_vma=False)
    return fn(a)
