"""Blocked MXU matmul Pallas kernel.

Tiling: C (M,N) is produced in (bm, bn) VMEM tiles; the K dimension is the
innermost grid axis so each (i, j) tile accumulates over K-steps into a VMEM
scratch accumulator in f32 (MXU-native accumulation), writing C once at the
final K step.  Tile sizes default to 128/256 multiples — MXU systolic array
alignment (128x128) and lane width (128) — and are clamped to the problem.

Grid iteration order (k innermost) keeps the C tile resident in VMEM across
K steps: A and B tiles stream HBM->VMEM, C writes once — the standard
TPU matmul blocking (HBM traffic ~ MK + KN + MN instead of O(MNK/bk)).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, c_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        c_ref[...] = acc_ref[...].astype(c_ref.dtype)


def _clamp(b, n):
    b = min(b, n)
    while n % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul_pallas(a, b, *, bm: int = 256, bn: int = 256, bk: int = 512,
                  interpret: bool = False):
    """a: (M, K), b: (K, N) -> (M, N) in a.dtype; f32 accumulation."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = _clamp(bm, M), _clamp(bn, N), _clamp(bk, K)
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
