"""Fused RMS-norm Pallas kernel.

One grid step normalizes a (rows_block, D) tile: mean-of-squares reduction,
rsqrt, scale by (1 + w) — all in one VMEM pass (the unfused jnp version
reads x three times from HBM; fused reads once, writes once).  D stays
whole in the lane dimension (norm axis must be resident); rows block to a
multiple of 8 (f32 sublane) to fill the VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * (1.0 + w_ref[...].astype(jnp.float32))) \
        .astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_pallas(x, w, *, eps: float = 1e-5, block_rows: int = 256,
                   interpret: bool = False):
    """x: (..., D); w: (D,)."""
    orig_shape = x.shape
    D = x.shape[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    br = min(block_rows, R)
    while R % br:
        br -= 1
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(R // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(xf, w)
    return out.reshape(orig_shape)
