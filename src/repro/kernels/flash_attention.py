"""Causal GQA flash attention Pallas kernel.

Grid (B, H, Sq/bq, Skv/bk), KV innermost.  Each (b, h, iq) owns an online-
softmax state (m, l, acc) in VMEM scratch that survives across KV steps —
scores for one (bq, bk) tile exist only in VMEM/VREGs, never in HBM (the
jnp reference path materializes (B, H, Sq, bk) per chunk in HBM; this
kernel is the memory-term fix identified in EXPERIMENTS.md §Perf).

GQA is handled in the index map: KV head = h // (H // KVH), so KV tiles are
re-streamed for the query heads of one group (VMEM-friendly; an alternative
blocking over grouped heads is a tuning knob left to the autotuner).

Tile defaults 128x128: MXU-aligned in both the q-row and kv-row dims.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_k: int, bq: int, bk: int, scale: float, causal: bool,
                  q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        # queries align to the END of the KV sequence (suffix semantics:
        # Sq < Skv means the queries are the last Sq positions)
        qpos = q_offset + iq * bq + \
            jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(qpos >= kpos, s, _NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    v = v_ref[0, 0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, bq: int = 128,
                           bk: int = 128, interpret: bool = False):
    """q: (B, H, Sq, d); k/v: (B, KVH, Skv, d) -> (B, H, Sq, d)."""
    B, H, Sq, d = q.shape
    KVH, Skv = k.shape[1], k.shape[2]
    G = H // KVH
    bq = min(bq, Sq)
    while Sq % bq:
        bq -= 1
    bk = min(bk, Skv)
    while Skv % bk:
        bk -= 1
    n_k = Skv // bk
    grid = (B, H, Sq // bq, n_k)
    scale = 1.0 / math.sqrt(d)
    return pl.pallas_call(
        functools.partial(_flash_kernel, n_k=n_k, bq=bq, bk=bk,
                          scale=scale, causal=causal, q_offset=Skv - Sq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
