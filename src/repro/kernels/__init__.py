"""kernels — Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships three artifacts:
  <name>.py   pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py      jit'd dispatch wrappers (kernel on TPU / interpret elsewhere)
  ref.py      pure-jnp oracles the tests assert against

Kernels present:
  matmul          blocked MXU matmul (128-aligned tiles, f32 accumulator)
  flash_attention causal GQA flash attention (online softmax over KV tiles)
  rmsnorm         fused RMS-norm

These correspond to the recurring kernel signatures the paper's technique
models (gemm-like and normalization routines dominate the LM step's
critical path, exactly as BLAS kernels dominate the paper's factorization
schedules).
"""

from .ops import matmul, flash_attention, rmsnorm

__all__ = ["matmul", "flash_attention", "rmsnorm"]
