"""Pure-jnp oracles for every Pallas kernel (tests assert against these)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def matmul_ref(a, b):
    return jnp.dot(a.astype(jnp.float32),
                   b.astype(jnp.float32)).astype(a.dtype)


def rmsnorm_ref(x, w, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: (B, Sq, H, d); k/v: (B, Skv, KVH, d) — GQA naive attention."""
    B, Sq, H, d = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qg = q.reshape(B, Sq, KVH, G, d).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    s = s / math.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, k.shape[1]), bool),
                        k.shape[1] - Sq)
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, d).astype(q.dtype)
