"""jit'd dispatch wrappers: Pallas kernel on TPU, interpret mode elsewhere.

The model layer can swap these in for the jnp reference path (a ModelKnobs
choice); tests sweep shapes/dtypes asserting allclose against ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas
from .matmul import matmul_pallas
from .rmsnorm import rmsnorm_pallas
from . import ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def matmul(a, b, **kw):
    return matmul_pallas(a, b, interpret=_interpret(), **kw)


def rmsnorm(x, w, *, eps: float = 1e-5, **kw):
    return rmsnorm_pallas(x, w, eps=eps, interpret=_interpret(), **kw)


def flash_attention(q, k, v, *, causal: bool = True, **kw):
    """(B, Sq, H, d) layout (model-native); transposes into kernel layout."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    ot = flash_attention_pallas(qt, kt, vt, causal=causal,
                                interpret=_interpret(), **kw)
    return ot.transpose(0, 2, 1, 3)
