"""Critter: the paper's online selective-execution profiler.

This module implements the interception protocol of Figure 2 — the logic the
real tool runs inside PMPI wrappers — as methods invoked by the simmpi
runtime at each kernel event:

- ``on_comp``  — local computation kernel (BLAS/LAPACK interception);
- ``on_coll``  — blocking collective (MPI_Bcast et al. interception):
  internal allreduce of (exec_time, execute-vote, keys, freqs), max-path
  winner adoption, selective execution, ``update_statistics`` and — for
  eager propagation — ``aggregate_statistics`` across the channel;
- ``on_p2p``   — blocking Send/Recv (MPI_Recv interception: internal
  PMPI_Sendrecv, max of the two paths, OR of execute votes);
- ``on_isend_post`` / ``on_isend_match`` — nonblocking p2p (MPI_Isend /
  MPI_Wait interception: decision made from sender-local state, statistics
  updated at completion).

The five selective-execution policies of §IV.B are parameterized by
``core.policies.Policy``; the aggregate-channel closure used by eager
propagation lives in ``core.channels``.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .channels import ChannelRegistry
from .models import Extrapolator
from .pathset import RankState
from .policies import Policy
from .signatures import Signature
from .stats import KernelStats


class IterationReport:
    """Everything the tuner wants to know about one configuration run."""

    __slots__ = ("predicted_time", "wall_time", "crit_comp", "crit_comm",
                 "measured_time", "max_measured_comp", "executed", "skipped",
                 "events")

    def __init__(self, predicted_time, wall_time, crit_comp, crit_comm,
                 measured_time, max_measured_comp, executed, skipped, events):
        self.predicted_time = predicted_time
        self.wall_time = wall_time
        self.crit_comp = crit_comp
        self.crit_comm = crit_comm
        self.measured_time = measured_time
        self.max_measured_comp = max_measured_comp
        self.executed = executed
        self.skipped = skipped
        self.events = events

    def __repr__(self):
        return (f"IterationReport(pred={self.predicted_time:.4g}s, "
                f"wall={self.wall_time:.4g}s, exec={self.executed}, "
                f"skip={self.skipped})")


class Critter:
    """Shared profiler state across tuning iterations.

    One instance per (policy, study); owns the per-rank Critter state, the
    channel registry (via the World), the eager global switch-off set, and
    the a-priori critical-path count snapshots.
    """

    def __init__(self, world, policy: Policy):
        self.world = world
        self.registry: ChannelRegistry = world.registry
        self.policy = policy
        self.ranks: List[RankState] = [RankState(r) for r in range(world.size)]
        # eager propagation: signatures switched off machine-wide, and the
        # globally-agreed statistics used to predict them
        self.global_off: set = set()
        self.global_stats: Dict[Signature, KernelStats] = {}
        # apriori: frozen critical-path execution counts from the offline pass
        self.apriori_counts: Optional[List[Dict[Signature, int]]] = None
        # beyond-paper: per-op-family input-size extrapolation (§VIII);
        # fitted from the pooled kernel statistics at iteration start
        self.extrapolator: Optional[Extrapolator] = \
            Extrapolator(max_rel_err=policy.tolerance) \
            if policy.extrapolate else None
        # runtime-facing mode flags (set per run by the tuner/runtime)
        self.force_execute = False
        self.update_stats = True

    # ------------------------------------------------------------------ state

    def begin_iteration(self, *, force_execute=False, update_stats=True):
        for st in self.ranks:
            st.reset_iteration()
        self.force_execute = force_execute
        self.update_stats = update_stats
        if self.extrapolator is not None:
            pooled: Dict[Signature, KernelStats] = {}
            for st in self.ranks:
                for sig, stats in st.kbar.items():
                    if sig not in pooled:
                        pooled[sig] = stats
            # family models PERSIST across configurations (unlike the
            # per-signature statistics, which the paper's protocol resets):
            # a model fitted on one configuration's kernel sizes predicts
            # another configuration's different sizes — the cross-config
            # generalization per-signature modeling cannot provide
            if pooled:
                self.extrapolator.refit(pooled)

    def snapshot_apriori_counts(self):
        """Freeze the current per-rank critical-path counts (after a full
        offline pass) for immediate use by the 'apriori' policy."""
        self.apriori_counts = [
            {sig: info.freq for sig, info in st.ktilde.items() if info.freq}
            for st in self.ranks]

    def reset_models(self):
        """Paper §VI.A: reset kernel statistics between configurations
        (SLATE/CANDMC studies); eager persists models across configs."""
        for st in self.ranks:
            st.reset_models()
        self.global_off = set()
        self.global_stats = {}
        self.apriori_counts = None

    # -------------------------------------------------------------- decisions

    def _freq(self, st: RankState, sig: Signature) -> int:
        """The execution count used to shrink the CI (policy-dependent)."""
        p = self.policy
        if p.name == "conditional" or p.name == "eager":
            return 1
        if p.name == "apriori" and self.apriori_counts is not None:
            return max(self.apriori_counts[st.rank].get(sig, 0), 1)
        # local / online: current sub-critical-path running count
        info = st.ktilde.get(sig)
        return max(info.freq, 1) if info is not None else 1

    def _extrapolatable(self, sig: Signature) -> bool:
        """Beyond-paper: a kernel NEVER executed may be skipped when its
        family model's validation error meets the tolerance (§VIII)."""
        if self.extrapolator is None:
            return False
        pred = self.extrapolator.predict(sig)
        return pred is not None and pred[1] <= self.policy.tolerance

    def predictable(self, st: RankState, sig: Signature) -> bool:
        if sig in self.global_off:
            return True
        stats = st.kbar.get(sig)
        if stats is None or stats.n < self.policy.min_samples:
            return self._extrapolatable(sig)
        return stats.is_predictable(self.policy.tolerance,
                                    self._freq(st, sig),
                                    self.policy.min_samples)

    def _predicted_mean(self, st: RankState, sig: Signature) -> float:
        g = self.global_stats.get(sig)
        if g is not None:
            return g.mean
        stats = st.kbar.get(sig)
        if stats is not None and stats.n:
            return stats.mean
        if self.extrapolator is not None:
            pred = self.extrapolator.predict(sig)
            if pred is not None:
                return pred[0]
        return 0.0

    def _never_ran(self, st: RankState, sig: Signature) -> bool:
        stats = st.kbar.get(sig)
        return stats is None or stats.n == 0

    def _should_execute_local(self, st: RankState, sig: Signature) -> bool:
        if self.force_execute:
            return True
        if sig in self.global_off:
            return False
        if self.policy.name == "eager":
            # eager skips only once the kernel is switched off globally
            # (predictable on some rank AND propagated machine-wide)
            return True
        if self.policy.once_per_iteration and sig not in st.iter_executed:
            # beyond-paper: never-executed kernels with a validated family
            # model may be skipped outright (§VIII extrapolation)
            if not (self._never_ran(st, sig) and self._extrapolatable(sig)):
                return True
        return not self.predictable(st, sig)

    # ----------------------------------------------------------- comp kernels

    def on_comp(self, rank: int, sig: Signature, sampler) -> float:
        """BLAS/LAPACK interception.  Computation kernel execution decisions
        are made independently per processor (default policy, §III.B).
        Returns the wall-clock time the rank spends (0 when skipped)."""
        st = self.ranks[rank]
        path = st.path
        if self._should_execute_local(st, sig):
            t = sampler(sig)
            if self.update_stats:
                st.stats(sig).update(t)
            st.iter_executed.add(sig)
            st.clock += t
            st.measured_time += t
            st.measured_comp += t
            st.executed_kernels += 1
            wall = t
        else:
            t = self._predicted_mean(st, sig)
            st.skipped_kernels += 1
            wall = 0.0
        path.exec_time += t
        path.comp_time += t
        path.kernel_count += 1
        info = st.info(sig)
        info.freq += 1
        return wall

    # ------------------------------------------------------------ collectives

    def on_coll(self, sig: Signature, comm, sampler,
                overhead: float = 0.0) -> float:
        """Blocking-collective interception (Figure 2, MPI_Bcast et al.).

        1. internal PMPI_Allreduce over the channel: max path time wins, the
           winner's K-tilde keys/freqs are adopted by dominated ranks
           ('online' policy), execute votes are OR-reduced;
        2. clocks synchronize (the internal allreduce is itself a barrier);
        3. the user collective is selectively executed; every participant
           invokes update_statistics on a real execution;
        4. eager propagation invokes aggregate_statistics across the channel
           and may switch the kernel off globally once the aggregate-channel
           closure covers the world communicator.

        Returns the post-completion clock shared by all participants.
        """
        ranks = comm.ranks
        states = self.ranks
        policy = self.policy

        # -- internal allreduce: longest path wins ---------------------------
        winner = None
        max_path = -1.0
        max_clock = 0.0
        for r in ranks:
            st = states[r]
            if st.path.exec_time > max_path:
                max_path = st.path.exec_time
                winner = st
            if st.clock > max_clock:
                max_clock = st.clock
        for r in ranks:
            st = states[r]
            if st is not winner:
                if policy.propagates_counts:
                    st.adopt_freqs(winner)
                st.path.adopt(winner.path)

        # -- execute vote (OR-reduced across the channel) --------------------
        if self.force_execute:
            execute = True
        elif sig in self.global_off:
            execute = False
        elif policy.name == "eager":
            execute = True   # until switched off by global propagation
        else:
            n_pred = 0
            must = False
            for r in ranks:
                st = states[r]
                if policy.once_per_iteration \
                        and sig not in st.iter_executed \
                        and not (self._never_ran(st, sig)
                                 and self._extrapolatable(sig)):
                    must = True
                    break
                if self.predictable(st, sig):
                    n_pred += 1
            execute = must or (n_pred < policy.comm_vote_fraction * len(ranks))

        # -- selective execution + statistics update -------------------------
        max_clock += overhead  # internal-allreduce profiling cost
        if execute:
            t = sampler(sig)
            new_clock = max_clock + t
            for r in ranks:
                st = states[r]
                if self.update_stats:
                    st.stats(sig).update(t)
                st.iter_executed.add(sig)
                st.clock = new_clock
                st.measured_time += t
                st.executed_kernels += 1
                st.path.exec_time += t
                st.path.comm_time += t
                st.path.kernel_count += 1
                st.info(sig).freq += 1
        else:
            new_clock = max_clock
            for r in ranks:
                st = states[r]
                t = self._predicted_mean(st, sig)
                st.clock = new_clock
                st.skipped_kernels += 1
                st.path.exec_time += t
                st.path.comm_time += t
                st.path.kernel_count += 1
                st.info(sig).freq += 1

        # -- eager: aggregate_statistics across the channel ------------------
        if policy.name == "eager" and comm.channel is not None:
            self._aggregate_statistics(comm)
        return new_clock

    def _aggregate_statistics(self, comm):
        """Figure 2's kernel-aggregation loop at blocking collectives: every
        kernel in the participants' local sets that is deemed predictable and
        has not yet been propagated along this channel has its statistics
        merged and installed on all participants, and the channel is recorded
        in the kernel's propagated set (K[i].agg_channels).  A kernel is
        switched off globally once its propagated channels contain an
        aggregate spanning the world communicator."""
        states = self.ranks
        ranks = comm.ranks
        chash = comm.channel.hash_id
        tol, ms = self.policy.tolerance, self.policy.min_samples
        # candidate kernels: predictable on >= 1 participant, not yet
        # propagated along this channel everywhere
        cands = {}
        for r in ranks:
            st = states[r]
            for sig, stats in st.kbar.items():
                if sig in self.global_off or sig in cands:
                    continue
                info = st.ktilde.get(sig)
                if info is not None and chash in info.agg_channels:
                    continue
                if stats.is_predictable(tol, 1, ms):
                    cands[sig] = True
        for sig in cands:
            merged = KernelStats()
            for r in ranks:
                stats = states[r].kbar.get(sig)
                if stats is not None:
                    merged.merge(stats)
            covered = False
            for r in ranks:
                st = states[r]
                st.kbar[sig] = merged.copy()
                info = st.info(sig)
                info.agg_channels.add(chash)
                info.is_pred = True
                if not covered:
                    covered = self.registry.covers_world(info.agg_channels)
            if covered or comm.size == self.world.size:
                self.global_off.add(sig)
                self.global_stats[sig] = merged

    # ---------------------------------------------------------- point-to-point

    def p2p_vote(self, rank: int, sig: Signature) -> bool:
        """The sender-or-receiver-local execute vote (int_msg.execute)."""
        st = self.ranks[rank]
        if self.force_execute:
            return True
        if sig in self.global_off:
            return False
        if self.policy.once_per_iteration and sig not in st.iter_executed:
            if not (self._never_ran(st, sig) and self._extrapolatable(sig)):
                return True
        return not self.predictable(st, sig)

    def on_p2p(self, src: int, dst: int, sig: Signature, sampler,
               src_vote: bool, overhead: float = 0.0) -> float:
        """Complete a matched BLOCKING Send/Recv pair (MPI_Recv interception:
        internal PMPI_Sendrecv of int_msgs, max of the two paths, OR of the
        execute votes).  Both clocks synchronize (rendezvous).

        Returns the shared post-completion clock."""
        states = self.ranks
        s_st, r_st = states[src], states[dst]
        execute = src_vote or self.p2p_vote(dst, sig)

        # longest path wins
        winner = s_st if s_st.path.exec_time > r_st.path.exec_time else r_st
        loser = r_st if winner is s_st else s_st
        if self.policy.propagates_counts:
            loser.adopt_freqs(winner)
        loser.path.adopt(winner.path)

        base = max(s_st.clock, r_st.clock) + overhead
        if execute:
            t = sampler(sig)
            done = base + t
            for st in (s_st, r_st):
                if self.update_stats:
                    st.stats(sig).update(t)
                st.iter_executed.add(sig)
                st.measured_time += t
                st.executed_kernels += 1
                self._charge_comm(st, sig, t)
        else:
            done = base
            for st in (s_st, r_st):
                st.skipped_kernels += 1
                self._charge_comm(st, sig, self._predicted_mean(st, sig))
        s_st.clock = done
        r_st.clock = done
        return done

    def on_isend_match(self, src: int, dst: int, sig: Signature, sampler,
                       src_vote: bool, snapshot, overhead: float = 0.0):
        """Complete a buffered Isend matched by a Recv (MPI_Recv + MPI_Wait
        interception).  ``snapshot`` is (path_copy, freqs_copy_or_None,
        post_clock) captured when the Isend was posted — the internal
        message travels with the SENDER'S PATH AT POST TIME; the sender's
        own state is not rewound (it has moved on), but its statistics ARE
        updated with the completion sample (Figure 2's MPI_Wait update)."""
        states = self.ranks
        s_st, r_st = states[src], states[dst]
        post_path, post_freqs, post_clock = snapshot
        execute = src_vote or self.p2p_vote(dst, sig)

        # receiver adopts the deposited path if it dominates
        if post_path.exec_time > r_st.path.exec_time:
            if self.policy.propagates_counts and post_freqs is not None:
                mine = r_st.ktilde
                for s2, f2 in post_freqs.items():
                    pi = mine.get(s2)
                    if pi is None:
                        pi = r_st.info(s2)
                    pi.freq = f2
            r_st.path.adopt(post_path)

        base = max(post_clock, r_st.clock) + overhead
        if execute:
            t = sampler(sig)
            done = base + t
            for st in (s_st, r_st):
                if self.update_stats:
                    st.stats(sig).update(t)
                st.iter_executed.add(sig)
                st.executed_kernels += 1
            r_st.measured_time += t
            self._charge_comm(r_st, sig, t)
        else:
            done = base
            for st in (s_st, r_st):
                st.skipped_kernels += 1
            self._charge_comm(r_st, sig, self._predicted_mean(r_st, sig))
        r_st.clock = done
        return done

    def _charge_comm(self, st: RankState, sig: Signature, t: float):
        st.path.exec_time += t
        st.path.comm_time += t
        st.path.kernel_count += 1
        st.info(sig).freq += 1

    def isend_snapshot(self, rank: int):
        """Capture the sender-side internal message payload at post time."""
        st = self.ranks[rank]
        freqs = None
        if self.policy.propagates_counts:
            freqs = {s: i.freq for s, i in st.ktilde.items() if i.freq}
        return (st.path.copy(), freqs, st.clock)

    # ----------------------------------------------------------------- report

    def report(self) -> IterationReport:
        pred = max(st.path.exec_time for st in self.ranks)
        wall = max(st.clock for st in self.ranks)
        comp = max(st.path.comp_time for st in self.ranks)
        comm = max(st.path.comm_time for st in self.ranks)
        meas = max(st.measured_time for st in self.ranks)
        mcomp = max(st.measured_comp for st in self.ranks)
        ex = sum(st.executed_kernels for st in self.ranks)
        sk = sum(st.skipped_kernels for st in self.ranks)
        return IterationReport(pred, wall, comp, comm, meas, mcomp, ex, sk,
                               ex + sk)
