"""Critter: the paper's online selective-execution profiler.

This module implements the interception protocol of Figure 2 — the logic the
real tool runs inside PMPI wrappers — as methods invoked by the simmpi
runtime at each kernel event:

- ``on_comp``  — local computation kernel (BLAS/LAPACK interception);
- ``on_coll``  — blocking collective (MPI_Bcast et al. interception):
  internal allreduce of (exec_time, execute-vote, keys, freqs), max-path
  winner adoption, selective execution, ``update_statistics`` and — for
  eager propagation — ``aggregate_statistics`` across the channel;
- ``on_p2p``   — blocking Send/Recv (MPI_Recv interception: internal
  PMPI_Sendrecv, max of the two paths, OR of execute votes);
- ``on_isend_post`` / ``on_isend_match`` — nonblocking p2p (MPI_Isend /
  MPI_Wait interception: decision made from sender-local state, statistics
  updated at completion).

The five selective-execution policies of §IV.B are parameterized by
``core.policies.Policy``; the aggregate-channel closure used by eager
propagation lives in ``core.channels``.

Hot-path layout (this refactor — protocol preserved bit-for-bit, see
``tests/test_golden_reports.py``):

- kernels are addressed by dense interned ids (``core.signatures``), so
  every per-kernel table is an integer-indexed array/dict instead of
  hashing a frozen dataclass per event;
- per-rank scalar state lives in ``core.pathset.EngineState`` NumPy
  struct-of-arrays, so the internal allreduce at collectives (max-path
  winner, clock sync, count adoption, vote) and ``report()`` are
  vectorized reductions over participant index arrays;
- ``predictable()`` verdicts are memoized inside ``KernelStats`` (n-keyed
  caches plus freq-monotonicity thresholds) and extrapolator predictions
  are memoized per sid between refits.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from .channels import ChannelRegistry
from .models import Extrapolator
from .pathset import ColdScalars, EngineState, WarmMirror
from .policies import Policy
from .signatures import Signature
from .stats import KernelStats

# Compiled warm-program opcodes — produced by the runtime's segment
# compiler (simmpi.runtime._build_warm), consumed by ``Critter.run_warm``.
# W_CHEAD / W_BHEAD are comp / comp-block entries that additionally head a
# fused per-rank segment (a maximal run of that rank's computation events
# between two of its skip-decision / communication boundaries); their
# member entries stay plain W_COMP / W_BLOCK and are consumed by a pending
# counter when the head batch-charges the whole segment.
W_COMP, W_BLOCK, W_CHEAD, W_BHEAD, W_COLL, W_P2P, W_IPOST, W_IMATCH = \
    range(8)


class IterationReport:
    """Everything the tuner wants to know about one configuration run."""

    __slots__ = ("predicted_time", "wall_time", "crit_comp", "crit_comm",
                 "measured_time", "max_measured_comp", "executed", "skipped",
                 "events")

    def __init__(self, predicted_time, wall_time, crit_comp, crit_comm,
                 measured_time, max_measured_comp, executed, skipped, events):
        self.predicted_time = predicted_time
        self.wall_time = wall_time
        self.crit_comp = crit_comp
        self.crit_comm = crit_comm
        self.measured_time = measured_time
        self.max_measured_comp = max_measured_comp
        self.executed = executed
        self.skipped = skipped
        self.events = events

    def __repr__(self):
        return (f"IterationReport(pred={self.predicted_time:.4g}s, "
                f"wall={self.wall_time:.4g}s, exec={self.executed}, "
                f"skip={self.skipped})")


class Critter:
    """Shared profiler state across tuning iterations.

    One instance per (policy, study); owns the struct-of-arrays per-rank
    Critter state, the channel registry (via the World), the eager global
    switch-off set, and the a-priori critical-path count snapshots.
    """

    def __init__(self, world, policy: Policy):
        self.world = world
        self.registry: ChannelRegistry = world.registry
        self.policy = policy
        self.state = EngineState(world.size)
        # eager propagation: signature ids switched off machine-wide, and
        # the globally-agreed statistics used to predict them
        self.global_off: set = set()
        self.global_stats: Dict[int, KernelStats] = {}
        # apriori: frozen critical-path execution counts from the offline
        # pass — a (ranks x sids) snapshot of the freq table
        self.apriori_counts: Optional[np.ndarray] = None
        # beyond-paper: per-op-family input-size extrapolation (§VIII);
        # fitted from the pooled kernel statistics at iteration start
        self.extrapolator: Optional[Extrapolator] = \
            Extrapolator(max_rel_err=policy.tolerance) \
            if policy.extrapolate else None
        self._extrap_cache: Dict[int, Optional[Tuple[float, float]]] = {}
        # runtime-facing mode flags (set per run by the tuner/runtime)
        self.force_execute = False
        self.update_stats = True
        # policy traits, resolved once (hot-path)
        self._tol = policy.tolerance
        self._ms = policy.min_samples
        self._vote_frac = policy.comm_vote_fraction
        self._eager = policy.name == "eager"
        self._once = policy.once_per_iteration
        self._propagates = policy.propagates_counts
        self._counts_local = policy.name in ("local", "online")
        self._apriori_mode = policy.name == "apriori"
        # live id -> Signature list (append-only, shared with the world's
        # interner — the runtime interns into the same table)
        self._sigs = world.interner.sigs
        # cross-study transfer: resolver Signature -> KernelStats-or-None
        # (repro.api.transfer); consumed lazily as signatures are interned
        self._prior_lookup = None
        self._prior_upto = 0
        # list-backed per-rank scalars, live only inside one forced run
        # (begin_cold .. finish_cold); see pathset.ColdScalars
        self._cs: Optional[ColdScalars] = None

    # ------------------------------------------------------------------ state

    def set_prior(self, lookup) -> None:
        """Install a transferred-statistics prior.  ``lookup(sig)`` returns
        an installable ``KernelStats`` (already discounted/remapped by the
        bank) or ``None``.  Seeding is lazy: signatures are interned by the
        runtime as programs first execute, so ``begin_iteration`` tops up
        the seed over any ids that appeared since — by the first selective
        trial (which follows a full reference execution) every kernel of
        the configuration carries its prior.  ``reset_models`` re-arms the
        seed, so studies that reset statistics between configurations warm
        every configuration, not just the first."""
        self._prior_lookup = lookup
        self._prior_upto = 0

    def _seed_prior(self) -> None:
        sigs = self._sigs
        lookup = self._prior_lookup
        S = self.state
        n_ranks = S.n_ranks
        eager = self._eager
        while self._prior_upto < len(sigs):
            sid = self._prior_upto
            self._prior_upto += 1
            st = lookup(sigs[sid])
            if st is None or st.n == 0:
                continue
            if sid >= S.cap:
                S.ensure(sid)
            for r in range(n_ranks):
                kb = S.kbar[r]
                if sid in kb:           # posterior beats prior: keep it
                    continue
                inst = kb[sid] = st.copy()
                S.mean_arr[r, sid] = inst.mean
                if eager:
                    self._note_stats(r, sid, inst)
            # an already-confident prior starts the kernel in the skip
            # regime.  For eager the bank stands in for a completed global
            # aggregation (its statistics came from a finished study), so
            # the kernel is switched off machine-wide outright; the
            # once-per-iteration policies keep their mandatory first
            # execution and skip every later occurrence from trial one.
            if eager and sid not in self.global_off \
                    and st.n >= self._ms \
                    and st.is_predictable(self._tol, 1, self._ms):
                self.global_off.add(sid)
                self.global_stats[sid] = st.copy()
                S.goff[sid] = True
                S.gmean[sid] = st.mean
                for r in range(n_ranks):
                    S.pred_live[r].discard(sid)

    def begin_iteration(self, *, force_execute=False, update_stats=True):
        self.state.reset_iteration()
        if self._prior_lookup is not None:
            self._seed_prior()
        self.force_execute = force_execute
        self.update_stats = update_stats
        if self.extrapolator is not None:
            pooled = self.pooled_kbar()
            # family models PERSIST across configurations (unlike the
            # per-signature statistics, which the paper's protocol resets):
            # a model fitted on one configuration's kernel sizes predicts
            # another configuration's different sizes — the cross-config
            # generalization per-signature modeling cannot provide
            if pooled:
                self.extrapolator.refit(pooled)
            self._extrap_cache.clear()

    def pooled_kbar(self) -> Dict[Signature, KernelStats]:
        """First-seen-per-rank pooling of the kernel statistics (used by the
        extrapolator refit and the beyond-paper benchmarks)."""
        sigs = self._sigs
        pooled: Dict[Signature, KernelStats] = {}
        for d in self.state.kbar:
            for sid, stats in d.items():
                sig = sigs[sid]
                if sig not in pooled:
                    pooled[sig] = stats
        return pooled

    def snapshot_apriori_counts(self):
        """Freeze the current per-rank critical-path counts (after a full
        offline pass) for immediate use by the 'apriori' policy."""
        self.apriori_counts = self.state.freq.copy()
        self.state.skip_ok.fill(False)

    def reset_models(self):
        """Paper §VI.A: reset kernel statistics between configurations
        (SLATE/CANDMC studies); eager persists models across configs."""
        self.state.reset_models()
        self.global_off = set()
        self.global_stats = {}
        self.apriori_counts = None
        self._prior_upto = 0       # re-arm transferred priors (set_prior)

    # -------------------------------------------------------------- decisions

    def _freq(self, rank: int, sid: int) -> int:
        """The execution count used to shrink the CI (policy-dependent)."""
        if self._counts_local:
            # local / online: current sub-critical-path running count
            f = int(self.state.freq[rank, sid])
            return f if f > 1 else 1
        if self._apriori_mode and self.apriori_counts is not None:
            ap = self.apriori_counts
            f = int(ap[rank, sid]) if sid < ap.shape[1] else 0
            return f if f > 1 else 1
        # conditional / eager: no execution-count usage
        return 1

    def _extrapolatable(self, sid: int) -> bool:
        """Beyond-paper: a kernel NEVER executed may be skipped when its
        family model's validation error meets the tolerance (§VIII)."""
        if self.extrapolator is None:
            return False
        pred = self._extrap_predict(sid)
        return pred is not None and pred[1] <= self._tol

    def _extrap_predict(self, sid: int):
        """Memoized extrapolator prediction (valid between refits)."""
        cache = self._extrap_cache
        if sid in cache:
            return cache[sid]
        pred = self.extrapolator.predict(self._sigs[sid])
        cache[sid] = pred
        return pred

    def predictable(self, rank: int, sid: int) -> bool:
        if self.state.skip_ok[rank, sid]:
            return True      # memoized skip verdict implies predictability
        if sid in self.global_off:
            return True
        stats = self.state.kbar[rank].get(sid)
        if stats is None or stats.n < self._ms:
            return self._extrapolatable(sid)
        return stats.is_predictable(self._tol, self._freq(rank, sid),
                                    self._ms)

    def _skip_verdict(self, rank: int, sid: int) -> bool:
        """The rank-local execute vote, memoized: True means SKIP.

        A skip verdict is cached in ``skip_ok`` only when it holds at
        critical-path count 1 (``is_predictable(tol, 1, ms)``), which makes
        the cache immune to count adoption — the relative CI only shrinks
        as freq grows — so a cached cell stays valid until the (rank, sid)
        statistics change (cleared at every real execution and at eager
        aggregation installs) or the iteration ends.
        """
        S = self.state
        if S.skip_ok[rank, sid]:
            return True
        if self._once and not S.iter_exec[rank, sid]:
            # beyond-paper: never-executed kernels with a validated family
            # model may be skipped outright (§VIII extrapolation)
            if not (self._never_ran(rank, sid)
                    and self._extrapolatable(sid)):
                return False
        if not self.predictable(rank, sid):
            return False
        stats = S.kbar[rank].get(sid)
        if stats is not None and stats.n > 0 \
                and stats.is_predictable(self._tol, 1, self._ms):
            S.skip_ok[rank, sid] = True
        return True

    def _predicted_mean(self, rank: int, sid: int) -> float:
        g = self.global_stats.get(sid)
        if g is not None:
            return g.mean
        m = self.state.mean_arr[rank, sid]
        if m == m:                       # not NaN: stats present with n > 0
            return float(m)
        if self.extrapolator is not None:
            pred = self._extrap_predict(sid)
            if pred is not None:
                return pred[0]
        return 0.0

    def _never_ran(self, rank: int, sid: int) -> bool:
        stats = self.state.kbar[rank].get(sid)
        return stats is None or stats.n == 0

    def _note_stats(self, rank: int, sid: int, stats: KernelStats) -> None:
        """Eager-only: keep ``pred_live[rank]`` in sync after a statistics
        write.  Membership mirrors the aggregate_statistics candidate
        precondition — predictable at critical-path count 1 — and is NOT
        monotone (new samples can widen the CI), so the verdict is
        recomputed at every write; ``is_predictable`` memoizes on (n, tol)
        so this is one cached check per write."""
        if stats.n >= self._ms and stats.is_predictable(self._tol, 1,
                                                        self._ms):
            if sid not in self.global_off:
                self.state.pred_live[rank].add(sid)
        else:
            self.state.pred_live[rank].discard(sid)

    def _should_execute_local(self, rank: int, sid: int) -> bool:
        if self.force_execute:
            return True
        if sid in self.global_off:
            return False
        if self._eager:
            # eager skips only once the kernel is switched off machine-wide
            # (predictable on some rank AND propagated globally)
            return True
        return not self._skip_verdict(rank, sid)

    # ----------------------------------------------------------- comp kernels

    def on_comp(self, rank: int, sid: int, sampler) -> float:
        """BLAS/LAPACK interception.  Computation kernel execution decisions
        are made independently per processor (default policy, §III.B).
        Returns the wall-clock time the rank spends (0 when skipped)."""
        S = self.state
        if sid >= S.cap:
            S.ensure(sid)
        # fused fast path: memoized skip verdict (or eager global switch-off)
        if not self.force_execute:
            if self._eager:
                skip = S.goff[sid]
                t = S.gmean[sid] if skip else 0.0
            else:
                skip = S.skip_ok[rank, sid]
                t = S.mean_arr[rank, sid] if skip else 0.0
            if skip:
                S.skipped[rank] += 1
                S.path_exec[rank] += t
                S.path_comp[rank] += t
                S.path_kernels[rank] += 1
                S.freq[rank, sid] += 1
                S.seen[rank, sid] = True
                return 0.0
        if self._should_execute_local(rank, sid):
            t = sampler(self._sigs[sid])
            if self.update_stats:
                stats = S.stats(rank, sid)
                stats.update(t)
                S.mean_arr[rank, sid] = stats.mean
                if self._eager:
                    self._note_stats(rank, sid, stats)
            S.iter_exec[rank, sid] = True
            S.clock[rank] += t
            S.measured_time[rank] += t
            S.measured_comp[rank] += t
            S.executed[rank] += 1
            wall = t
        else:
            t = self._predicted_mean(rank, sid)
            S.skipped[rank] += 1
            wall = 0.0
        S.path_exec[rank] += t
        S.path_comp[rank] += t
        S.path_kernels[rank] += 1
        S.freq[rank, sid] += 1
        S.seen[rank, sid] = True
        return wall

    def on_comp_block(self, rank: int, block, sampler) -> float:
        """A run of consecutive computation kernels of one rank (produced by
        the runtime's trace compiler).  When every kernel in the run has a
        memoized skip verdict — the steady state after warmup — the whole
        run is charged in one vectorized step; otherwise it falls back to
        per-kernel ``on_comp`` (identical decisions, identical RNG use).

        The predicted times are accumulated sequentially in the same order
        as individual events, so path metrics stay bit-identical."""
        S = self.state
        if block.max_sid >= S.cap:
            S.ensure(block.max_sid)
        sids_np = block.sids_np
        if not self.force_execute:
            if self._eager:
                ok = S.goff[sids_np]
                means = S.gmean[sids_np] if ok.all() else None
            else:
                ok = S.skip_ok[rank, sids_np]
                means = S.mean_arr[rank, sids_np] if ok.all() else None
            if means is not None:
                pe = float(S.path_exec[rank])
                pc = float(S.path_comp[rank])
                for t in means.tolist():
                    pe += t
                    pc += t
                S.path_exec[rank] = pe
                S.path_comp[rank] = pc
                S.path_kernels[rank] += block.n
                S.skipped[rank] += block.n
                S.freq[rank, block.uniq] += block.counts
                S.seen[rank, block.uniq] = True
                return 0.0
        wall = 0.0
        on_comp = self.on_comp
        for sid in block.sids:
            wall += on_comp(rank, sid, sampler)
        return wall

    # -- batched cold (forced) fast path --------------------------------------
    #
    # The ``*_cold`` interceptions are force-execute specializations used by
    # the runtime's cold interpreter: the sample is drawn up front (the
    # recording/reference run samples every kernel, so draws hoist and
    # vectorize), the execute vote is constant-True, and three per-event
    # writes are elided because nothing can observe them during a forced
    # run:
    #
    # - ``skip_ok`` is all-False after ``reset_iteration`` and nothing sets
    #   it under force (the vote paths that memoize verdicts are skipped),
    #   so writing False is a no-op;
    # - ``iter_exec`` is only read by the selective vote paths (never under
    #   force) and reset at the next ``begin_iteration``; the interpreter
    #   sets the run's statically-known (rank, sid) execution set in one
    #   vectorized pass at the end (``finish_cold``);
    # - ``mean_arr`` is only read by skip-prediction paths (never under
    #   force); ``finish_cold`` mirrors the final K-bar means once per
    #   touched (rank, sid) instead of once per event.  Eager aggregation
    #   at collectives maintains its own mean_arr writes as usual.
    #
    # ``pred_live`` (eager) IS maintained per statistics write — collective
    # aggregation reads it mid-run.
    # Per-rank scalar timers (clock, path profile, measured accumulators,
    # counters) live in list-backed mirrors for the duration of the forced
    # run (``begin_cold`` .. ``finish_cold``; see ``pathset.ColdScalars``):
    # the p2p-heavy interception hot path touches several of them per event
    # for two ranks, and Python-list access is several times cheaper than
    # NumPy scalar indexing while performing the identical IEEE arithmetic.
    # Everything else — Welford statistics, freq (read mid-run by Isend
    # snapshots), seen (read by count adoption) — follows the exact
    # operation order of the scalar methods, so reports, state, and RNG
    # streams stay bit-identical (tests/test_cold_path.py).

    def begin_cold(self) -> ColdScalars:
        """Enter list-backed scalar mode for one forced run (the cold
        interpreter calls this right after growing column capacity)."""
        self._cs = cs = ColdScalars(self.state)
        return cs

    def on_comp_cold(self, rank: int, sid: int, t: float) -> float:
        """Force-execute charging of one computation kernel with a
        precomputed sample (mirrors the execute branch of ``on_comp``; the
        caller has grown column capacity over every sid of the program)."""
        S = self.state
        cs = self._cs
        if self.update_stats:
            stats = S.stats(rank, sid)
            stats.update(t)
            if self._eager:
                self._note_stats(rank, sid, stats)
        cs.clock[rank] += t
        cs.measured_time[rank] += t
        cs.measured_comp[rank] += t
        cs.executed[rank] += 1
        cs.path_exec[rank] += t
        cs.path_comp[rank] += t
        cs.path_kernels[rank] += 1
        S.freq[rank, sid] += 1
        S.seen[rank, sid] = True
        return t

    def on_comp_block_cold(self, rank: int, block, ts) -> float:
        """Force-execute charging of a fused run of computation kernels
        with precomputed samples ``ts`` (Python floats, block order).

        Scalar accumulators (clock, measured, path) are accumulated
        sequentially over Python floats — the same additions in the same
        order as per-event ``on_comp`` — and the Welford statistics of
        each distinct kernel see their samples in block order
        (``KernelStats.update_many``), so every derived quantity is
        bit-identical to the scalar path."""
        S = self.state
        cs = self._cs
        if self.update_stats:
            eager = self._eager
            uniq = block.uniq.tolist()
            groups = block.group_indices()
            for sid, idx in zip(uniq, groups):
                stats = S.stats(rank, sid)
                if len(idx) == block.n:
                    stats.update_many(ts)
                else:
                    stats.update_many([ts[i] for i in idx])
                if eager:
                    self._note_stats(rank, sid, stats)
        c = cs.clock[rank]
        mt = cs.measured_time[rank]
        mc = cs.measured_comp[rank]
        pe = cs.path_exec[rank]
        pc = cs.path_comp[rank]
        total = 0.0
        for t in ts:
            c += t
            mt += t
            mc += t
            pe += t
            pc += t
            total += t
        cs.clock[rank] = c
        cs.measured_time[rank] = mt
        cs.measured_comp[rank] = mc
        cs.path_exec[rank] = pe
        cs.path_comp[rank] = pc
        cs.executed[rank] += block.n
        cs.path_kernels[rank] += block.n
        S.freq[rank, block.uniq] += block.counts
        S.seen[rank, block.uniq] = True
        return total

    def on_coll_cold(self, sid: int, comm, t: float,
                     overhead: float = 0.0) -> float:
        """Force-execute completion of a blocking collective with a
        precomputed sample (mirrors the force branch of ``on_coll``:
        winner adoption, clock sync, per-participant statistics update,
        eager aggregation — with the per-rank scalars on the list mirrors
        and the ``iter_exec``/``mean_arr`` writes deferred to
        ``finish_cold`` like every other cold interception)."""
        S = self.state
        cs = self._cs
        ranks = comm.ranks
        ridx = comm.ranks_np
        pe = cs.path_exec
        clock = cs.clock
        # first-max winner / clock max, matching take().argmax()/max()
        winner = ranks[0]
        best = pe[winner]
        max_clock = clock[winner]
        for r in ranks[1:]:
            v = pe[r]
            if v > best:
                best = v
                winner = r
            c = clock[r]
            if c > max_clock:
                max_clock = c
        if self._propagates:
            wseen = S.seen[winner]
            S.freq[ridx] = np.where(wseen, S.freq[winner], S.freq[ridx])
            S.seen[ridx] |= wseen
        pc = cs.path_comp
        pm = cs.path_comm
        pk = cs.path_kernels
        pew = pe[winner]
        pcw = pc[winner]
        pmw = pm[winner]
        pkw = pk[winner]

        max_clock += overhead  # internal-allreduce profiling cost
        new_clock = max_clock + t
        update = self.update_stats
        eager = self._eager
        mt = cs.measured_time
        ex = cs.executed
        for r in ranks:
            if update:
                stats = S.stats(r, sid)
                stats.update(t)
                if eager:
                    self._note_stats(r, sid, stats)
            clock[r] = new_clock
            mt[r] += t
            ex[r] += 1
            pe[r] = pew + t
            pc[r] = pcw
            pm[r] = pmw + t
            pk[r] = pkw + 1
        S.freq[ridx, sid] += 1
        S.seen[ridx, sid] = True
        if eager and comm.channel is not None:
            self._aggregate_statistics(comm)
        return new_clock

    def on_p2p_cold(self, src: int, dst: int, sid: int, t: float,
                    overhead: float = 0.0) -> float:
        """Force-execute completion of a blocking Send/Recv pair with a
        precomputed sample (mirrors the execute branch of ``on_p2p``)."""
        S = self.state
        cs = self._cs
        pe = cs.path_exec
        winner, loser = (src, dst) if pe[src] > pe[dst] else (dst, src)
        if self._propagates:
            wseen = S.seen[winner]
            np.copyto(S.freq[loser], S.freq[winner], where=wseen)
            S.seen[loser] |= wseen
        pe[loser] = pe[winner]
        pc = cs.path_comp
        pm = cs.path_comm
        pk = cs.path_kernels
        pc[loser] = pc[winner]
        pm[loser] = pm[winner]
        pk[loser] = pk[winner]

        clock = cs.clock
        a = clock[src]
        b = clock[dst]
        done = (a if a > b else b) + overhead + t
        update = self.update_stats
        eager = self._eager
        mt = cs.measured_time
        ex = cs.executed
        for r in (src, dst):
            if update:
                stats = S.stats(r, sid)
                stats.update(t)
                if eager:
                    self._note_stats(r, sid, stats)
            mt[r] += t
            ex[r] += 1
            pe[r] += t
            pm[r] += t
            pk[r] += 1
            S.freq[r, sid] += 1
            S.seen[r, sid] = True
        clock[src] = done
        clock[dst] = done
        return done

    def on_isend_match_cold(self, src: int, dst: int, sid: int, t: float,
                            snapshot, overhead: float = 0.0):
        """Force-execute completion of a buffered Isend matched by a Recv
        with a precomputed sample (mirrors the execute branch of
        ``on_isend_match``; the sender-local vote is constant-True under
        force, so the interpreter's post slots carry only the snapshot)."""
        S = self.state
        cs = self._cs
        (p_exec, p_comp, p_comm, p_kc), post_freqs, post_clock = snapshot

        if p_exec > cs.path_exec[dst]:
            if self._propagates and post_freqs is not None:
                m = post_freqs.shape[0]
                mask = post_freqs > 0
                np.copyto(S.freq[dst, :m], post_freqs, where=mask)
                S.seen[dst, :m] |= mask
            cs.path_exec[dst] = p_exec
            cs.path_comp[dst] = p_comp
            cs.path_comm[dst] = p_comm
            cs.path_kernels[dst] = p_kc

        cd = cs.clock[dst]
        done = (post_clock if post_clock > cd else cd) + overhead + t
        if self.update_stats:
            eager = self._eager
            for r in (src, dst):
                stats = S.stats(r, sid)
                stats.update(t)
                if eager:
                    self._note_stats(r, sid, stats)
        cs.executed[src] += 1
        cs.executed[dst] += 1
        cs.measured_time[dst] += t
        cs.path_exec[dst] += t
        cs.path_comm[dst] += t
        cs.path_kernels[dst] += 1
        S.freq[dst, sid] += 1
        S.seen[dst, sid] = True
        cs.clock[dst] = done
        return done

    def isend_snapshot_cold(self, rank: int):
        """``isend_snapshot`` against the list mirrors (the values are
        already Python scalars)."""
        S = self.state
        cs = self._cs
        freqs = S.freq[rank].copy() if self._propagates else None
        path = (cs.path_exec[rank], cs.path_comp[rank],
                cs.path_comm[rank], cs.path_kernels[rank])
        return (path, freqs, cs.clock[rank])

    def finish_cold(self, rows, cols) -> None:
        """End-of-forced-run bulk pass: write the list-backed per-rank
        scalars back to the arrays, set ``iter_exec`` over the run's
        statically-known (rank, sid) execution pairs and mirror the final
        K-bar means into ``mean_arr`` (both deferred from the per-event
        cold interceptions above)."""
        S = self.state
        cs = self._cs
        if cs is not None:
            cs.writeback(S)
            self._cs = None
        S.iter_exec[rows, cols] = True
        if self.update_stats:
            kbar = S.kbar
            mean_arr = S.mean_arr
            for r, s in zip(rows.tolist(), cols.tolist()):
                mean_arr[r, s] = kbar[r][s].mean

    # -- compiled warm (selective) fast path ----------------------------------
    #
    # ``run_warm`` replays a compiled warm program (simmpi.runtime builds it
    # from the recorded event stream) through list-backed mirrors of the
    # full engine state (pathset.WarmMirror): the selective hot path is
    # dominated by scalar skip-table reads and per-rank accumulator
    # read-modify-writes, which Python lists serve several times cheaper
    # than NumPy scalar indexing at identical IEEE arithmetic.  Fused
    # per-rank comp segments batch-charge their predicted means in event
    # order when every kernel in the segment holds a memoized skip verdict
    # (the steady state); any guard miss falls back to per-event decisions
    # at the original program positions, so decisions, statistics updates
    # and RNG consumption are bit-identical to the scalar interpreter
    # (tests/test_compiled_path.py, tests/test_cold_path.py).

    def warm_eligible(self) -> bool:
        """True when ``run_warm`` reproduces the scalar engine exactly.

        The compiled interpreter specializes away the extrapolation
        branches (every shipped policy has ``extrapolate=False``) and
        assumes ``global_off`` is populated only under eager propagation —
        an invariant of the protocol (only ``_aggregate_statistics`` and
        the eager prior seed add to it) asserted here for safety."""
        return self.extrapolator is None \
            and (self._eager or not self.global_off)

    def run_warm(self, warm, sampler, overhead: float = 0.0) -> None:
        """Replay one compiled warm program (selective, non-forced run)."""
        S = self.state
        nlive = len(self._sigs)
        need = warm.max_sid if warm.max_sid >= nlive else nlive - 1
        if need >= S.cap:
            S.ensure(need)
        wm = WarmMirror(S, nlive)

        # mirror views / resolved traits (locals: closure-cell reads only)
        clock = wm.clock
        pe = wm.path_exec
        pc = wm.path_comp
        pm = wm.path_comm
        pk = wm.path_kernels
        mt = wm.measured_time
        mcmp = wm.measured_comp
        ex = wm.executed
        sk = wm.skipped
        freq_rows = wm.freq
        seen_rows = wm.seen
        iter_rows = wm.iter_exec
        mean_rows = wm.mean
        sko_rows = wm.skip_ok
        goff = wm.goff
        gmean = wm.gmean
        sigs = self._sigs
        kbar = S.kbar
        update = self.update_stats
        eager = self._eager
        once = self._once
        propagates = self._propagates
        counts_local = self._counts_local
        tol = self._tol
        ms = self._ms
        vote_frac = self._vote_frac
        global_off = self.global_off
        global_stats = self.global_stats
        note = self._note_stats
        ap = self.apriori_counts if self._apriori_mode else None
        apw = ap.shape[1] if ap is not None else 0

        slots = [None] * warm.n_slots
        pend = [0] * S.n_ranks      # member entries of a batch-charged run

        # -- decision helpers (exact mirrors of the scalar methods) ----------

        def predictable(r, sid):
            if sko_rows[r][sid]:
                return True
            if sid in global_off:
                return True
            stats = kbar[r].get(sid)
            if stats is None or stats.n < ms:
                return False
            if counts_local:
                f = freq_rows[r][sid]
                if f < 1:
                    f = 1
            elif ap is not None:
                f = int(ap[r, sid]) if sid < apw else 0
                if f < 1:
                    f = 1
            else:
                f = 1
            return stats.is_predictable(tol, f, ms)

        def skip_verdict(r, sid):
            # True means SKIP; memoizes count-1 verdicts into the mirror
            if sko_rows[r][sid]:
                return True
            if once and not iter_rows[r][sid]:
                return False
            if not predictable(r, sid):
                return False
            stats = kbar[r].get(sid)
            if stats is not None and stats.n > 0 \
                    and stats.is_predictable(tol, 1, ms):
                sko_rows[r][sid] = True
            return True

        def p2p_vote(r, sid):
            # callers have already checked sko_rows[r][sid] is False
            if sid in global_off:
                return False
            return not skip_verdict(r, sid)

        if eager:
            def pmean(r, sid):
                g = global_stats.get(sid)
                if g is not None:
                    return g.mean
                m = mean_rows[r][sid]
                return m if m == m else 0.0
        else:
            # non-eager protocols never populate global_stats
            def pmean(r, sid):
                m = mean_rows[r][sid]
                return m if m == m else 0.0

        def comp_slow(r, sid):
            # the memoized fast skip check already failed
            if eager:
                execute = True      # goff is False here, never switched off
            else:
                execute = not skip_verdict(r, sid)
            if execute:
                t = sampler(sigs[sid])
                if update:
                    d = kbar[r]
                    stats = d.get(sid)
                    if stats is None:
                        stats = d[sid] = KernelStats()
                    stats.update(t)
                    mean_rows[r][sid] = stats.mean
                    if eager:
                        note(r, sid, stats)
                iter_rows[r][sid] = True
                clock[r] += t
                mt[r] += t
                mcmp[r] += t
                ex[r] += 1
            else:
                t = pmean(r, sid)
                sk[r] += 1
            pe[r] += t
            pc[r] += t
            pk[r] += 1
            freq_rows[r][sid] += 1
            seen_rows[r][sid] = True

        def comp_one(r, sid):
            if eager:
                if goff[sid]:
                    t = gmean[sid]
                else:
                    comp_slow(r, sid)
                    return
            elif sko_rows[r][sid]:
                t = mean_rows[r][sid]
            else:
                comp_slow(r, sid)
                return
            sk[r] += 1
            pe[r] += t
            pc[r] += t
            pk[r] += 1
            freq_rows[r][sid] += 1
            seen_rows[r][sid] = True

        def block_entry(r, bsids, buniq, bcounts, bn):
            if eager:
                ok = True
                for s in buniq:
                    if not goff[s]:
                        ok = False
                        break
                mr = gmean
            else:
                skr = sko_rows[r]
                ok = True
                for s in buniq:
                    if not skr[s]:
                        ok = False
                        break
                mr = mean_rows[r]
            if ok:
                a = pe[r]
                b = pc[r]
                for s in bsids:
                    t = mr[s]
                    a += t
                    b += t
                pe[r] = a
                pc[r] = b
                pk[r] += bn
                sk[r] += bn
                fr = freq_rows[r]
                sr = seen_rows[r]
                for s, c in zip(buniq, bcounts):
                    fr[s] += c
                    sr[s] = True
                return True
            for s in bsids:
                comp_one(r, s)
            return False

        def coll_vote(ranks, sid):
            all_ok = True
            for r in ranks:
                if not sko_rows[r][sid]:
                    all_ok = False
                    break
            if all_ok:
                return False
            if once:
                for r in ranks:
                    if not iter_rows[r][sid]:
                        return True
            thr = vote_frac * len(ranks)
            n_pred = 0
            left = len(ranks)
            for r in ranks:
                left -= 1
                if predictable(r, sid):
                    n_pred += 1
                    if n_pred >= thr:
                        break
                elif n_pred + left < thr:
                    return True
            if n_pred < thr:
                return True
            if vote_frac >= 1.0:
                for r in ranks:
                    stats = kbar[r].get(sid)
                    if stats is not None and stats.n > 0 \
                            and stats.is_predictable(tol, 1, ms):
                        sko_rows[r][sid] = True
            return False

        # -- interpreter loop -------------------------------------------------

        for e in warm.entries:
            k = e[0]
            if k == W_COMP:
                r = e[1]
                if pend[r]:
                    pend[r] -= 1
                    continue
                sid = e[2]
                if eager:
                    if not goff[sid]:
                        comp_slow(r, sid)
                        continue
                    t = gmean[sid]
                elif sko_rows[r][sid]:
                    t = mean_rows[r][sid]
                else:
                    comp_slow(r, sid)
                    continue
                sk[r] += 1
                pe[r] += t
                pc[r] += t
                pk[r] += 1
                freq_rows[r][sid] += 1
                seen_rows[r][sid] = True
            elif k == W_IMATCH:
                _, src, dst, sid, slot, sig = e
                vote, p_exec, p_comp, p_comm, p_kc, post_freqs, post_clock \
                    = slots[slot]
                if vote:
                    execute = True
                elif sko_rows[dst][sid]:
                    execute = False
                else:
                    execute = p2p_vote(dst, sid)
                if p_exec > pe[dst]:
                    if post_freqs is not None:
                        fd = freq_rows[dst]
                        sd = seen_rows[dst]
                        i = 0
                        for v in post_freqs:
                            if v > 0:
                                fd[i] = v
                                sd[i] = True
                            i += 1
                    pe[dst] = p_exec
                    pc[dst] = p_comp
                    pm[dst] = p_comm
                    pk[dst] = p_kc
                cd = clock[dst]
                base = (post_clock if post_clock > cd else cd) + overhead
                if execute:
                    t = sampler(sig)
                    for r in (src, dst):
                        if update:
                            d = kbar[r]
                            stats = d.get(sid)
                            if stats is None:
                                stats = d[sid] = KernelStats()
                            stats.update(t)
                            mean_rows[r][sid] = stats.mean
                            sko_rows[r][sid] = False
                            if eager:
                                note(r, sid, stats)
                        iter_rows[r][sid] = True
                        ex[r] += 1
                    mt[dst] += t
                    clock[dst] = base + t
                else:
                    sk[src] += 1
                    sk[dst] += 1
                    if eager:
                        t = pmean(dst, sid)
                    else:
                        t = mean_rows[dst][sid]
                        if t != t:               # NaN: no statistics yet
                            t = 0.0
                    clock[dst] = base
                pe[dst] += t
                pm[dst] += t
                pk[dst] += 1
                freq_rows[dst][sid] += 1
                seen_rows[dst][sid] = True
            elif k == W_IPOST:
                _, r, sid, slot = e
                if sko_rows[r][sid]:
                    vote = False
                else:
                    vote = p2p_vote(r, sid)
                slots[slot] = (vote, pe[r], pc[r], pm[r], pk[r],
                               freq_rows[r][:] if propagates else None,
                               clock[r])
            elif k == W_CHEAD:
                r = e[1]
                run = e[3]
                rsids, runiq, rcounts, rn, extra = run
                if eager:
                    ok = True
                    for s in runiq:
                        if not goff[s]:
                            ok = False
                            break
                    mr = gmean
                else:
                    skr = sko_rows[r]
                    ok = True
                    for s in runiq:
                        if not skr[s]:
                            ok = False
                            break
                    mr = mean_rows[r]
                if ok:
                    a = pe[r]
                    b = pc[r]
                    for s in rsids:
                        t = mr[s]
                        a += t
                        b += t
                    pe[r] = a
                    pc[r] = b
                    pk[r] += rn
                    sk[r] += rn
                    fr = freq_rows[r]
                    sr = seen_rows[r]
                    for s, c in zip(runiq, rcounts):
                        fr[s] += c
                        sr[s] = True
                    pend[r] = extra
                else:
                    comp_one(r, e[2])
            elif k == W_BLOCK:
                r = e[1]
                if pend[r]:
                    pend[r] -= 1
                    continue
                block_entry(r, e[2], e[3], e[4], e[5])
            elif k == W_BHEAD:
                r = e[1]
                rsids, runiq, rcounts, rn, extra = e[6]
                if eager:
                    ok = True
                    for s in runiq:
                        if not goff[s]:
                            ok = False
                            break
                    mr = gmean
                else:
                    skr = sko_rows[r]
                    ok = True
                    for s in runiq:
                        if not skr[s]:
                            ok = False
                            break
                    mr = mean_rows[r]
                if ok:
                    a = pe[r]
                    b = pc[r]
                    for s in rsids:
                        t = mr[s]
                        a += t
                        b += t
                    pe[r] = a
                    pc[r] = b
                    pk[r] += rn
                    sk[r] += rn
                    fr = freq_rows[r]
                    sr = seen_rows[r]
                    for s, c in zip(runiq, rcounts):
                        fr[s] += c
                        sr[s] = True
                    pend[r] = extra
                else:
                    block_entry(r, e[2], e[3], e[4], e[5])
            elif k == W_P2P:
                src = e[1]
                dst = e[2]
                sid = e[3]
                if sko_rows[src][sid]:
                    vote = False
                else:
                    vote = p2p_vote(src, sid)
                if vote:
                    execute = True
                elif sko_rows[dst][sid]:
                    execute = False
                else:
                    execute = p2p_vote(dst, sid)
                if pe[src] > pe[dst]:
                    w = src
                    l = dst
                else:
                    w = dst
                    l = src
                if propagates:
                    ws = seen_rows[w]
                    fw = freq_rows[w]
                    fl = freq_rows[l]
                    sl = seen_rows[l]
                    i = 0
                    for flag in ws:
                        if flag:
                            fl[i] = fw[i]
                            sl[i] = True
                        i += 1
                pe[l] = pe[w]
                pc[l] = pc[w]
                pm[l] = pm[w]
                pk[l] = pk[w]
                a = clock[src]
                b = clock[dst]
                base = (a if a > b else b) + overhead
                if execute:
                    t = sampler(e[4])
                    done = base + t
                    for r in (src, dst):
                        if update:
                            d = kbar[r]
                            stats = d.get(sid)
                            if stats is None:
                                stats = d[sid] = KernelStats()
                            stats.update(t)
                            mean_rows[r][sid] = stats.mean
                            sko_rows[r][sid] = False
                            if eager:
                                note(r, sid, stats)
                        iter_rows[r][sid] = True
                        mt[r] += t
                        ex[r] += 1
                        pe[r] += t
                        pm[r] += t
                        pk[r] += 1
                        freq_rows[r][sid] += 1
                        seen_rows[r][sid] = True
                else:
                    done = base
                    for r in (src, dst):
                        sk[r] += 1
                        t = pmean(r, sid)
                        pe[r] += t
                        pm[r] += t
                        pk[r] += 1
                        freq_rows[r][sid] += 1
                        seen_rows[r][sid] = True
                clock[src] = done
                clock[dst] = done
            else:                           # W_COLL
                sid = e[1]
                comm = e[2]
                ranks = e[3]
                # longest path wins (first max, matching argmax)
                w = ranks[0]
                best = pe[w]
                max_clock = clock[w]
                for r in ranks:
                    v = pe[r]
                    if v > best:
                        best = v
                        w = r
                    c = clock[r]
                    if c > max_clock:
                        max_clock = c
                if propagates:
                    ws = seen_rows[w]
                    fw = freq_rows[w]
                    for r in ranks:
                        if r == w:
                            continue
                        fr = freq_rows[r]
                        sr = seen_rows[r]
                        i = 0
                        for flag in ws:
                            if flag:
                                fr[i] = fw[i]
                                sr[i] = True
                            i += 1
                wpe = pe[w]
                wpc = pc[w]
                wpm = pm[w]
                wpk = pk[w]
                for r in ranks:
                    pe[r] = wpe
                    pc[r] = wpc
                    pm[r] = wpm
                    pk[r] = wpk
                if eager:
                    execute = not goff[sid]
                else:
                    execute = coll_vote(ranks, sid)
                max_clock += overhead
                if execute:
                    t = sampler(e[4])
                    new_clock = max_clock + t
                    for r in ranks:
                        if update:
                            d = kbar[r]
                            stats = d.get(sid)
                            if stats is None:
                                stats = d[sid] = KernelStats()
                            stats.update(t)
                            mean_rows[r][sid] = stats.mean
                            if eager:
                                note(r, sid, stats)
                            sko_rows[r][sid] = False
                        iter_rows[r][sid] = True
                        clock[r] = new_clock
                        mt[r] += t
                        ex[r] += 1
                        pe[r] += t
                        pm[r] += t
                        pk[r] += 1
                        freq_rows[r][sid] += 1
                        seen_rows[r][sid] = True
                else:
                    for r in ranks:
                        t = pmean(r, sid)
                        clock[r] = max_clock
                        sk[r] += 1
                        pe[r] += t
                        pm[r] += t
                        pk[r] += 1
                        freq_rows[r][sid] += 1
                        seen_rows[r][sid] = True
                if eager and comm.channel is not None:
                    # aggregation reads K-bar/pred_live (live objects) and
                    # writes the prediction ARRAYS; sync the participants'
                    # mirror rows around it and re-pull the global tables
                    for r in ranks:
                        wm.push_rank(S, r)
                    self._aggregate_statistics(comm)
                    for r in ranks:
                        wm.pull_rank(S, r)
                    wm.pull_global(S)
                    goff = wm.goff
                    gmean = wm.gmean

        wm.writeback(S)

    def on_coll(self, sid: int, comm, sampler, overhead: float = 0.0) -> float:
        """Blocking-collective interception (Figure 2, MPI_Bcast et al.).

        1. internal PMPI_Allreduce over the channel: max path time wins, the
           winner's K-tilde keys/freqs are adopted by dominated ranks
           ('online' policy), execute votes are OR-reduced;
        2. clocks synchronize (the internal allreduce is itself a barrier);
        3. the user collective is selectively executed; every participant
           invokes update_statistics on a real execution;
        4. eager propagation invokes aggregate_statistics across the channel
           and may switch the kernel off globally once the aggregate-channel
           closure covers the world communicator.

        Returns the post-completion clock shared by all participants.
        """
        S = self.state
        if sid >= S.cap:
            S.ensure(sid)
        ranks = comm.ranks
        ridx = comm.ranks_np

        # -- internal allreduce: longest path wins (vectorized) --------------
        winner = ranks[int(S.path_exec.take(ridx).argmax())]
        max_clock = float(S.clock.take(ridx).max())
        if self._propagates:
            # dominated ranks adopt the winner's critical-path counts for
            # every kernel the winner has seen, keeping their own otherwise
            wseen = S.seen[winner]
            S.freq[ridx] = np.where(wseen, S.freq[winner], S.freq[ridx])
            S.seen[ridx] |= wseen
        S.path_exec[ridx] = S.path_exec[winner]
        S.path_comp[ridx] = S.path_comp[winner]
        S.path_comm[ridx] = S.path_comm[winner]
        S.path_kernels[ridx] = S.path_kernels[winner]

        # -- execute vote (OR-reduced across the channel) --------------------
        if self.force_execute:
            execute = True
        elif sid in self.global_off:
            execute = False
        elif self._eager:
            execute = True   # until switched off by global propagation
        else:
            execute = self._coll_vote(ranks, ridx, sid)

        # -- selective execution + statistics update -------------------------
        max_clock += overhead  # internal-allreduce profiling cost
        if execute:
            t = sampler(self._sigs[sid])
            new_clock = max_clock + t
            if self.update_stats:
                mean_col = S.mean_arr
                eager = self._eager
                for r in ranks:
                    stats = S.stats(r, sid)
                    stats.update(t)
                    mean_col[r, sid] = stats.mean
                    if eager:
                        self._note_stats(r, sid, stats)
                S.skip_ok[ridx, sid] = False    # statistics changed
            S.iter_exec[ridx, sid] = True
            S.clock[ridx] = new_clock
            S.measured_time[ridx] += t
            S.executed[ridx] += 1
            S.path_exec[ridx] += t
            S.path_comm[ridx] += t
        else:
            new_clock = max_clock
            tvec = self._predicted_means(ranks, ridx, sid)
            S.clock[ridx] = new_clock
            S.skipped[ridx] += 1
            S.path_exec[ridx] += tvec
            S.path_comm[ridx] += tvec
        S.path_kernels[ridx] += 1
        S.freq[ridx, sid] += 1
        S.seen[ridx, sid] = True

        # -- eager: aggregate_statistics across the channel ------------------
        if self._eager and comm.channel is not None:
            self._aggregate_statistics(comm)
        return new_clock

    def _coll_vote(self, ranks, ridx, sid) -> bool:
        """OR-reduced execute vote: True when some participant must still
        execute (once-per-iteration) or too few deem the kernel
        predictable."""
        S = self.state
        if S.skip_ok[ridx, sid].all():
            return False         # every participant's skip vote is memoized
        itex = S.iter_exec[ridx, sid]
        if self._once and not itex.all():
            if self.extrapolator is None or not self._extrapolatable(sid):
                return True
            # never-executed kernels with a validated family model are
            # exempt from the once-per-iteration re-execution
            for i, r in enumerate(ranks):
                if not itex[i] and not self._never_ran(r, sid):
                    return True
        # count predictable participants; execute unless enough of the
        # channel deems the kernel predictable (early exit both ways)
        thr = self._vote_frac * len(ranks)
        n_pred = 0
        left = len(ranks)
        for r in ranks:
            left -= 1
            if self.predictable(r, sid):
                n_pred += 1
                if n_pred >= thr:
                    break
            elif n_pred + left < thr:
                return True
        if n_pred < thr:
            return True
        # skip: memoize each participant's vote that holds at count 1 so the
        # steady state takes the vectorized all() fast path above
        if self._vote_frac >= 1.0:
            tol, ms = self._tol, self._ms
            for r in ranks:
                stats = S.kbar[r].get(sid)
                if stats is not None and stats.n > 0 \
                        and stats.is_predictable(tol, 1, ms):
                    S.skip_ok[r, sid] = True
        return False

    def _predicted_means(self, ranks, ridx, sid):
        """Per-participant predicted mean, vectorized via the mean mirror
        (scalar when a globally-agreed statistic exists)."""
        g = self.global_stats.get(sid)
        if g is not None:
            return g.mean
        tvec = self.state.mean_arr[ridx, sid]
        nan = np.isnan(tvec)
        if nan.any():
            fill = 0.0
            if self.extrapolator is not None:
                pred = self._extrap_predict(sid)
                if pred is not None:
                    fill = pred[0]
            tvec = np.where(nan, fill, tvec)
        return tvec

    def _aggregate_statistics(self, comm):
        """Figure 2's kernel-aggregation loop at blocking collectives: every
        kernel in the participants' local sets that is deemed predictable and
        has not yet been propagated along this channel has its statistics
        merged and installed on all participants, and the channel is recorded
        in the kernel's propagated set (K[i].agg_channels).  A kernel is
        switched off globally once its propagated channels contain an
        aggregate spanning the world communicator."""
        S = self.state
        ranks = comm.ranks
        chash = comm.channel.hash_id
        global_off = self.global_off
        # candidate kernels: predictable on >= 1 participant, not yet
        # propagated along this channel.  The scan walks each participant's
        # pred_live dirty set (maintained at every statistics write, see
        # _note_stats) instead of its whole K-bar; sids switched off
        # globally since their last write are evicted lazily here.
        candset = set()
        for r in ranks:
            live = S.pred_live[r]
            if not live:
                continue
            agg_r = S.agg_channels[r]
            stale = None
            for sid in live:
                if sid in global_off:
                    if stale is None:
                        stale = []
                    stale.append(sid)
                    continue
                chans = agg_r.get(sid)
                if chans is not None and chash in chans:
                    continue
                candset.add(sid)
            if stale:
                live.difference_update(stale)
        # per-sid merges are independent, so candidate order cannot affect
        # the result; sort anyway for a deterministic event stream
        for sid in sorted(candset):
            merged = KernelStats()
            for r in ranks:
                stats = S.kbar[r].get(sid)
                if stats is not None:
                    merged.merge(stats)
            covered = False
            for r in ranks:
                inst = S.kbar[r][sid] = merged.copy()
                S.mean_arr[r, sid] = merged.mean
                S.skip_ok[r, sid] = False       # statistics changed
                self._note_stats(r, sid, inst)
                agg_r = S.agg_channels[r]
                chans = agg_r.get(sid)
                if chans is None:
                    chans = agg_r[sid] = set()
                chans.add(chash)
                if not covered:
                    covered = self.registry.covers_world(chans)
            if covered or comm.size == self.world.size:
                global_off.add(sid)
                self.global_stats[sid] = merged
                S.goff[sid] = True
                S.gmean[sid] = merged.mean

    # ---------------------------------------------------------- point-to-point

    def p2p_vote(self, rank: int, sid: int) -> bool:
        """The sender-or-receiver-local execute vote (int_msg.execute)."""
        S = self.state
        if sid >= S.cap:
            S.ensure(sid)
        if self.force_execute:
            return True
        if S.skip_ok[rank, sid]:        # memoized skip verdict
            return False
        if sid in self.global_off:
            return False
        return not self._skip_verdict(rank, sid)

    def on_p2p(self, src: int, dst: int, sid: int, sampler,
               src_vote: bool, overhead: float = 0.0) -> float:
        """Complete a matched BLOCKING Send/Recv pair (MPI_Recv interception:
        internal PMPI_Sendrecv of int_msgs, max of the two paths, OR of the
        execute votes).  Both clocks synchronize (rendezvous).

        Returns the shared post-completion clock."""
        S = self.state
        if sid >= S.cap:
            S.ensure(sid)
        execute = src_vote or self.p2p_vote(dst, sid)

        # longest path wins
        pe = S.path_exec
        winner, loser = (src, dst) if pe[src] > pe[dst] else (dst, src)
        if self._propagates:
            wseen = S.seen[winner]
            np.copyto(S.freq[loser], S.freq[winner], where=wseen)
            S.seen[loser] |= wseen
        pe[loser] = pe[winner]
        S.path_comp[loser] = S.path_comp[winner]
        S.path_comm[loser] = S.path_comm[winner]
        S.path_kernels[loser] = S.path_kernels[winner]

        clock = S.clock
        base = max(clock[src], clock[dst]) + overhead
        if execute:
            t = sampler(self._sigs[sid])
            done = base + t
            for r in (src, dst):
                if self.update_stats:
                    stats = S.stats(r, sid)
                    stats.update(t)
                    S.mean_arr[r, sid] = stats.mean
                    S.skip_ok[r, sid] = False   # statistics changed
                    if self._eager:
                        self._note_stats(r, sid, stats)
                S.iter_exec[r, sid] = True
                S.measured_time[r] += t
                S.executed[r] += 1
                self._charge_comm(r, sid, t)
        else:
            done = base
            for r in (src, dst):
                S.skipped[r] += 1
                self._charge_comm(r, sid, self._predicted_mean(r, sid))
        clock[src] = done
        clock[dst] = done
        return done

    def on_isend_match(self, src: int, dst: int, sid: int, sampler,
                       src_vote: bool, snapshot, overhead: float = 0.0):
        """Complete a buffered Isend matched by a Recv (MPI_Recv + MPI_Wait
        interception).  ``snapshot`` is (path_tuple, freqs_or_None,
        post_clock) captured when the Isend was posted — the internal
        message travels with the SENDER'S PATH AT POST TIME; the sender's
        own state is not rewound (it has moved on), but its statistics ARE
        updated with the completion sample (Figure 2's MPI_Wait update)."""
        S = self.state
        if sid >= S.cap:
            S.ensure(sid)
        (p_exec, p_comp, p_comm, p_kc), post_freqs, post_clock = snapshot
        execute = src_vote or self.p2p_vote(dst, sid)

        # receiver adopts the deposited path if it dominates
        if p_exec > S.path_exec[dst]:
            if self._propagates and post_freqs is not None:
                # post_freqs is the sender's freq row at post time; transfer
                # the nonzero counts (the row may be shorter than the
                # current capacity if new signatures appeared since)
                m = post_freqs.shape[0]
                mask = post_freqs > 0
                np.copyto(S.freq[dst, :m], post_freqs, where=mask)
                S.seen[dst, :m] |= mask
            S.path_exec[dst] = p_exec
            S.path_comp[dst] = p_comp
            S.path_comm[dst] = p_comm
            S.path_kernels[dst] = p_kc

        base = max(post_clock, S.clock[dst]) + overhead
        if execute:
            t = sampler(self._sigs[sid])
            done = base + t
            for r in (src, dst):
                if self.update_stats:
                    stats = S.stats(r, sid)
                    stats.update(t)
                    S.mean_arr[r, sid] = stats.mean
                    S.skip_ok[r, sid] = False   # statistics changed
                    if self._eager:
                        self._note_stats(r, sid, stats)
                S.iter_exec[r, sid] = True
                S.executed[r] += 1
            S.measured_time[dst] += t
            self._charge_comm(dst, sid, t)
        else:
            done = base
            S.skipped[src] += 1
            S.skipped[dst] += 1
            self._charge_comm(dst, sid, self._predicted_mean(dst, sid))
        S.clock[dst] = done
        return done

    def _charge_comm(self, rank: int, sid: int, t: float):
        S = self.state
        S.path_exec[rank] += t
        S.path_comm[rank] += t
        S.path_kernels[rank] += 1
        S.freq[rank, sid] += 1
        S.seen[rank, sid] = True

    def isend_snapshot(self, rank: int):
        """Capture the sender-side internal message payload at post time."""
        S = self.state
        # freq-row copy: the seed kept {sig: freq if freq} — transferring
        # only nonzero counts is deferred to the (rarer) adoption at match
        freqs = S.freq[rank].copy() if self._propagates else None
        path = (float(S.path_exec[rank]), float(S.path_comp[rank]),
                float(S.path_comm[rank]), int(S.path_kernels[rank]))
        return (path, freqs, float(S.clock[rank]))

    # ----------------------------------------------------------------- report

    def report(self) -> IterationReport:
        S = self.state
        ex = int(S.executed.sum())
        sk = int(S.skipped.sum())
        return IterationReport(
            float(S.path_exec.max()), float(S.clock.max()),
            float(S.path_comp.max()), float(S.path_comm.max()),
            float(S.measured_time.max()), float(S.measured_comp.max()),
            ex, sk, ex + sk)
