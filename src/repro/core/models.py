"""Beyond-paper: per-op-family input-size extrapolation models.

Paper §VIII names this as future work: "Extrapolation of individual kernel
performance models to characterize kernel performance across varying input
sizes can benefit a wide class of algorithms, including CANDMC's pipelined
QR" (whose gradually shrinking trailing matrix creates many distinct
signatures, each modeled independently — the reason its overall speedup is
limited to 1.2x).

We fit, per op family (gemm, trsm, bcast, ...), a non-negative linear model

    t(sig) ~ a * flops(sig) + b * bytes(sig) + c

over the signatures already observed (weighted by sample count), and allow
the tuner to *skip kernels never executed before* when the family model is
sufficiently consistent.  Consistency is judged by leave-one-out relative
error on the observed signatures — the extrapolated prediction inherits a
confidence interval from that error, so the epsilon-tolerance semantics of
the paper carry over unchanged.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from .signatures import Signature, bytes_of, flops_of
from .stats import KernelStats


class FamilyModel:
    """One fitted linear model for one (kind, name) op family."""

    __slots__ = ("coef", "rel_err", "n_sigs")

    def __init__(self, coef, rel_err, n_sigs):
        self.coef = coef
        self.rel_err = rel_err
        self.n_sigs = n_sigs

    def predict(self, sig: Signature) -> float:
        f, b = flops_of(sig), bytes_of(sig)
        a, bb, c = self.coef
        return a * f + bb * b + c


class Extrapolator:
    """Fits and caches per-family models from a set of kernel statistics."""

    def __init__(self, min_signatures: int = 4, max_rel_err: float = 0.25):
        self.min_signatures = min_signatures
        self.max_rel_err = max_rel_err
        self._models: Dict[Tuple[str, str], FamilyModel] = {}
        self._dirty = True

    def observe_dirty(self):
        self._dirty = True

    def refit(self, kbar: Dict[Signature, KernelStats]):
        """Refit every family from the given kernel statistics."""
        fams: Dict[Tuple[str, str], List[Tuple[Signature, KernelStats]]] = {}
        for sig, st in kbar.items():
            if st.n >= 2 and st.mean > 0:
                fams.setdefault((sig.kind, sig.name), []).append((sig, st))
        self._models = {}
        for fam, entries in fams.items():
            if len(entries) < self.min_signatures:
                continue
            model = self._fit(entries)
            if model is not None and model.rel_err <= self.max_rel_err:
                self._models[fam] = model
        self._dirty = False

    @staticmethod
    def _fit(entries) -> Optional[FamilyModel]:
        X = np.array([[flops_of(s), bytes_of(s), 1.0] for s, _ in entries])
        y = np.array([st.mean for _, st in entries])
        w = np.sqrt(np.array([st.n for _, st in entries], dtype=float))
        Xw = X * w[:, None]
        yw = y * w
        coef, *_ = np.linalg.lstsq(Xw, yw, rcond=None)
        coef = np.maximum(coef, 0.0)   # times are nonnegative in every term
        pred = X @ coef
        # leave-one-out is overkill at this scale; use in-sample relative
        # error inflated by a small-sample factor as the model's uncertainty
        rel = np.abs(pred - y) / np.maximum(y, 1e-30)
        n = len(entries)
        rel_err = float(np.mean(rel) * (1.0 + 2.0 / max(n - 3, 1)))
        return FamilyModel(tuple(float(c) for c in coef), rel_err, n)

    # -- queries ---------------------------------------------------------------

    def predict(self, sig: Signature) -> Optional[Tuple[float, float]]:
        """(predicted mean, relative uncertainty) or None if no usable model."""
        m = self._models.get((sig.kind, sig.name))
        if m is None:
            return None
        t = m.predict(sig)
        if t <= 0:
            return None
        return t, m.rel_err
