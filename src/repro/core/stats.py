"""Single-pass statistical models of kernel execution time.

Implements the paper's statistical characterization (§III.A):

- every kernel signature's measured time is a random variable X with finite
  mean/variance; we keep a Welford single-pass estimator of (mean, M2);
- the confidence interval for the sample mean uses the (scaled) sample
  variance at a 95% confidence level (the paper's default);
- knowledge that a kernel executes ``k`` times along the current sub-critical
  path lets us assign sample variance ``sigma^2 / k`` to its contribution,
  shrinking the confidence interval needed per kernel by ``sqrt(k)``
  (paper: "Knowing that the number of times a kernel is executed along the
  critical path is alpha allows us to assign a sample variance sigma^2/alpha
  ... reduces the confidence interval ... by a factor sqrt(alpha)").
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

# 95% two-sided normal quantile. The paper constructs 95% confidence
# intervals from the scaled sample variance; for very small n we widen via a
# small-sample t-style correction table (indexed by dof) so that 2-3 samples
# are not spuriously declared "predictable".
Z_95 = 1.959963984540054

# student-t 97.5% quantiles for dof 1..30 (then ~z).
_T_975 = [
    12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228,
    2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086,
    2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
]


def t_quantile_975(dof: int) -> float:
    if dof <= 0:
        return math.inf
    if dof <= len(_T_975):
        return _T_975[dof - 1]
    return Z_95


# Acklam's rational approximation of the standard-normal inverse CDF
# (~1.15e-9 absolute error).  The counter-based RNG discipline in
# simmpi.costmodel maps uniform counters to normal deviates through this
# function; it is vectorized so a whole segment's draws evaluate in one
# ufunc pass, and the scalar path evaluates the SAME ufuncs on length-1
# arrays so per-event and per-segment draws are bitwise identical.
_PPF_A = (-3.969683028665376e+01, 2.209460984245205e+02,
          -2.759285104469687e+02, 1.383577518672690e+02,
          -3.066479806614716e+01, 2.506628277459239e+00)
_PPF_B = (-5.447609879822406e+01, 1.615858368580409e+02,
          -1.556989798598866e+02, 6.680131188771972e+01,
          -1.328068155288572e+01)
_PPF_C = (-7.784894002430293e-03, -3.223964580411365e-01,
          -2.400758277161838e+00, -2.549732539343734e+00,
          4.374664141464968e+00, 2.938163982698783e+00)
_PPF_D = (7.784695709041462e-03, 3.224671290700398e-01,
          2.445134137142996e+00, 3.754408661907416e+00)
_PPF_LO = 0.02425


def norm_ppf(q: "np.ndarray") -> "np.ndarray":
    """Vectorized standard-normal quantile function on ``q`` in (0, 1)."""
    q = np.asarray(q, dtype=np.float64)
    out = np.empty_like(q)
    a, b, c, d = _PPF_A, _PPF_B, _PPF_C, _PPF_D
    low = q < _PPF_LO
    high = q > 1.0 - _PPF_LO
    mid = ~(low | high)
    if low.any():
        u = np.sqrt(-2.0 * np.log(q[low]))
        out[low] = ((((((c[0] * u + c[1]) * u + c[2]) * u + c[3]) * u
                      + c[4]) * u + c[5])
                    / ((((d[0] * u + d[1]) * u + d[2]) * u + d[3]) * u
                       + 1.0))
    if high.any():
        u = np.sqrt(-2.0 * np.log(1.0 - q[high]))
        out[high] = -((((((c[0] * u + c[1]) * u + c[2]) * u + c[3]) * u
                        + c[4]) * u + c[5])
                      / ((((d[0] * u + d[1]) * u + d[2]) * u + d[3]) * u
                         + 1.0))
    if mid.any():
        u = q[mid] - 0.5
        r = u * u
        out[mid] = ((((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r
                      + a[4]) * r + a[5]) * u
                    / (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r
                        + b[4]) * r + 1.0))
    return out


@dataclass
class KernelStats:
    """Welford single-pass estimator of a kernel signature's execution time.

    This is the per-signature record the paper stores in the local kernel set
    (K-bar): sample count, mean, M2 (sum of squared deviations), plus
    min/max/total for reporting.
    """

    n: int = 0
    mean: float = 0.0
    m2: float = 0.0
    total: float = 0.0
    min_t: float = math.inf
    max_t: float = 0.0
    #: wall-clock time (time.time()) the evidence was last refreshed, or
    #: None for unstamped records (every pre-daemon bank).  Carried through
    #: copy/merge/discount and the JSON round-trip, but excluded from
    #: equality and OMITTED from JSON when unset so stamped-free banks
    #: serialize (and fingerprint) exactly as before.
    last_updated: "Optional[float]" = field(default=None, compare=False)
    # -- engine-hot-path caches (all keyed on n, which strictly increases on
    # every update/merge, so a stale cache is detected by n alone) ----------
    # t-quantile x std / sqrt(n) factor, valid while _hw_n == n
    _hw_n: int = field(default=-1, init=False, repr=False, compare=False)
    _hw: float = field(default=math.inf, init=False, repr=False, compare=False)
    # memoized predictability verdicts: relative_ci is monotone nonincreasing
    # in freq, so one True verdict at freq f certifies every freq >= f and
    # one False verdict certifies every freq <= f.
    _pred_n: int = field(default=-1, init=False, repr=False, compare=False)
    _pred_tol: float = field(default=math.nan, init=False, repr=False,
                             compare=False)
    _pred_true: float = field(default=math.inf, init=False, repr=False,
                              compare=False)
    _pred_false: int = field(default=0, init=False, repr=False, compare=False)

    def update(self, x: float) -> None:
        self.n += 1
        delta = x - self.mean
        self.mean += delta / self.n
        self.m2 += delta * (x - self.mean)
        self.total += x
        if x < self.min_t:
            self.min_t = x
        if x > self.max_t:
            self.max_t = x

    def update_many(self, xs) -> None:
        """Fold a batch of samples, in order, with the exact arithmetic of
        repeated ``update`` calls (same operations, same order — bitwise-
        identical results; a Chan-style batch merge would NOT be).  The
        engine's batched cold path uses this to amortize attribute access
        over a fused kernel run; the memo caches below stay keyed on ``n``
        and invalidate as usual."""
        n = self.n
        mean = self.mean
        m2 = self.m2
        total = self.total
        min_t = self.min_t
        max_t = self.max_t
        for x in xs:
            n += 1
            delta = x - mean
            mean += delta / n
            m2 += delta * (x - mean)
            total += x
            if x < min_t:
                min_t = x
            if x > max_t:
                max_t = x
        self.n = n
        self.mean = mean
        self.m2 = m2
        self.total = total
        self.min_t = min_t
        self.max_t = max_t

    def merge(self, other: "KernelStats") -> None:
        """Chan et al. parallel merge — used when propagating statistics
        across channels (aggregate_statistics in Figure 2)."""
        if other.n == 0:
            return
        if other.last_updated is not None and (
                self.last_updated is None
                or other.last_updated > self.last_updated):
            self.last_updated = other.last_updated
        if self.n == 0:
            self.n = other.n
            self.mean = other.mean
            self.m2 = other.m2
            self.total = other.total
            self.min_t = other.min_t
            self.max_t = other.max_t
            return
        n = self.n + other.n
        delta = other.mean - self.mean
        self.mean += delta * other.n / n
        self.m2 += other.m2 + delta * delta * self.n * other.n / n
        self.n = n
        self.total += other.total
        self.min_t = min(self.min_t, other.min_t)
        self.max_t = max(self.max_t, other.max_t)

    # -- derived quantities -------------------------------------------------

    @property
    def variance(self) -> float:
        """Unbiased sample variance."""
        if self.n < 2:
            return math.inf
        return self.m2 / (self.n - 1)

    @property
    def std(self) -> float:
        v = self.variance
        return math.sqrt(v) if v != math.inf else math.inf

    def ci_halfwidth(self, freq: int = 1) -> float:
        """95% CI half-width of the sample mean, shrunk by sqrt(freq).

        ``freq`` is the kernel's execution count along the current
        sub-critical path (alpha in the paper); passing freq=1 recovers the
        plain CI (the ``conditional execution`` policy).
        """
        if self.n < 2:
            return math.inf
        if self._hw_n != self.n:
            q = t_quantile_975(self.n - 1)
            self._hw = q * self.std / math.sqrt(self.n)
            self._hw_n = self.n
        hw = self._hw
        if freq > 1:
            hw /= math.sqrt(freq)
        return hw

    def relative_ci(self, freq: int = 1) -> float:
        """epsilon-tilde: CI size divided by sample mean (paper §III.A)."""
        if self.mean <= 0.0:
            return math.inf
        return self.ci_halfwidth(freq) / self.mean

    def is_predictable(self, tolerance: float, freq: int = 1,
                       min_samples: int = 2) -> bool:
        """True once relative CI size falls below the confidence tolerance."""
        if self.n < min_samples:
            return False
        if self._pred_n != self.n or self._pred_tol != tolerance:
            self._pred_n = self.n
            self._pred_tol = tolerance
            self._pred_true = math.inf
            self._pred_false = 0
        if freq >= self._pred_true:
            return True
        if freq <= self._pred_false:
            return False
        ok = self.relative_ci(freq) <= tolerance
        if ok:
            self._pred_true = freq
        else:
            self._pred_false = freq
        return ok

    def copy(self) -> "KernelStats":
        return KernelStats(self.n, self.mean, self.m2, self.total,
                           self.min_t, self.max_t, self.last_updated)

    # -- transfer / serialization -------------------------------------------
    #
    # The sufficient statistics (n, mean, m2) plus the reporting extras
    # (total, min, max) fully determine every derived quantity above, so a
    # bank of exported KernelStats can re-enter a later study as a prior
    # (repro.api.transfer) with nothing lost.  The memo caches are NOT
    # exported: they are keyed on n and rebuild on first use.

    def to_json(self) -> dict:
        d = {"n": int(self.n), "mean": float(self.mean),
             "m2": float(self.m2), "total": float(self.total)}
        if self.n > 0:          # min_t is +inf until the first sample
            d["min"] = float(self.min_t)
            d["max"] = float(self.max_t)
        if self.last_updated is not None:
            d["last_updated"] = float(self.last_updated)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "KernelStats":
        n = int(d["n"])
        lu = d.get("last_updated")
        return cls(n, float(d["mean"]), float(d["m2"]), float(d["total"]),
                   float(d["min"]) if n > 0 else math.inf,
                   float(d["max"]) if n > 0 else 0.0,
                   float(lu) if lu is not None else None)

    @classmethod
    def from_moments(cls, n: int, mean: float, variance: float,
                     min_t: float = None, max_t: float = None
                     ) -> "KernelStats":
        """Build the sufficient statistics of an n-sample stream with the
        given mean and (unbiased) variance — the synthesis direction of the
        copula remap, where a transferred marginal replaces the raw
        samples."""
        m2 = variance * (n - 1) if n >= 2 and math.isfinite(variance) \
            else 0.0
        return cls(n, mean, m2, mean * n,
                   mean if min_t is None else min_t,
                   mean if max_t is None else max_t)

    def discounted(self, factor: float) -> "KernelStats":
        """A weakened copy carrying ``factor`` of the evidence: the mean and
        variance are preserved but the effective sample count shrinks, so a
        transferred prior widens its CI (and re-crosses the predictability
        threshold) unless the source really was confident.  ``factor >= 1``
        returns a plain copy; a prior discounted to n < 1 carries no
        evidence (n = 0)."""
        if factor >= 1.0:
            return self.copy()
        # round, don't truncate: an age discount epsilon under 1.0 must
        # not destroy a whole sample of evidence (n=2 -> 1 would knock a
        # freshly banked kernel back below min_samples)
        n = int(round(self.n * factor))
        if n <= 0:
            return KernelStats()
        out = KernelStats.from_moments(n, self.mean, self.variance,
                                       self.min_t, self.max_t)
        out.last_updated = self.last_updated
        return out

    def discount_by_age(self, now: float, half_life: float
                        ) -> "KernelStats":
        """Age-aware ``discounted``: evidence decays exponentially in wall
        clock, halving every ``half_life`` seconds since ``last_updated``.
        Unstamped records (no ``last_updated``) carry no age and pass
        through as plain copies — a pre-daemon bank is trusted as-is."""
        if self.last_updated is None:
            return self.copy()
        age = now - self.last_updated
        if age <= 0.0:
            return self.copy()
        return self.discounted(0.5 ** (age / half_life))

    def minus(self, prior: "KernelStats") -> "Optional[KernelStats]":
        """Approximate inverse of ``merge``: the sufficient statistics of
        the samples in ``self`` beyond those of ``prior`` (assuming ``self
        == merge(prior, delta)``).  Used by the transfer harvest so a
        seeded prior's evidence is not re-banked on every model reset.
        Returns ``None`` when there is nothing beyond the prior; min/max
        are kept from ``self`` (extremes cannot be un-merged)."""
        nd = self.n - prior.n
        if nd <= 0:
            return None
        total = self.total - prior.total
        mean = (self.n * self.mean - prior.n * prior.mean) / nd
        d = mean - prior.mean
        m2 = self.m2 - prior.m2 - d * d * prior.n * nd / self.n
        if m2 < 0.0:                   # float cancellation guard
            m2 = 0.0
        return KernelStats(nd, mean, m2, total, self.min_t, self.max_t,
                           self.last_updated)

    def scaled(self, a: float) -> "KernelStats":
        """The statistics of ``a * X`` — the affine (through-origin) image
        used when a fitted source->target time map rescales a transferred
        kernel distribution."""
        if self.n == 0:
            return KernelStats()
        return KernelStats(self.n, a * self.mean, a * a * self.m2,
                           a * self.total, a * self.min_t, a * self.max_t,
                           self.last_updated)
