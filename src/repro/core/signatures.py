"""Kernel signatures.

A *kernel* in the paper is "a routine with a particular input size".
Compute kernels are parameterized on the routine name plus matrix dimensions
and BLAS flags (§V.D); communication kernels are parameterized on message
size and the sub-communicator's (size, stride) relative to the world
communicator, with point-to-point treated as a size-2 sub-communicator.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Tuple


@dataclass(frozen=True)
class Signature:
    """Hashable kernel signature.

    kind   -- 'comp' or 'comm'
    name   -- routine name ('gemm', 'potrf', 'bcast', 'send', ...)
    params -- compute: (dims..., flags...); comm: (nbytes, comm_size, comm_stride)
    """

    kind: str
    name: str
    params: Tuple

    def __str__(self) -> str:  # compact, stable, log-friendly
        p = ",".join(str(x) for x in self.params)
        return f"{self.kind}:{self.name}({p})"


class SignatureInterner:
    """Dense-integer interning of Signatures (engine hot path).

    Every Signature observed by the simulator is assigned a small dense id
    at creation; all per-kernel tables in the Critter engine are indexed by
    these ids (list/ndarray columns) instead of hashing the frozen
    dataclass on every event.  Ids are dense per interner and monotonically
    increasing; ``sigs`` is the live id -> Signature list (append-only, so
    holders of a reference always see newly interned signatures).  The
    engine uses one interner per simmpi ``World`` so a study's tables are
    sized by its own kernel count; the module-level ``INTERNER`` serves
    standalone uses.
    """

    __slots__ = ("_ids", "sigs")

    def __init__(self):
        self._ids: Dict[Signature, int] = {}
        self.sigs: List[Signature] = []

    def intern(self, sig: Signature) -> int:
        sid = self._ids.get(sig)
        if sid is None:
            sid = len(self.sigs)
            self._ids[sig] = sid
            self.sigs.append(sig)
        return sid

    def sig_of(self, sid: int) -> Signature:
        return self.sigs[sid]

    def __len__(self) -> int:
        return len(self.sigs)


#: standalone module-level interner for ad-hoc/test use.  NOT the engine's
#: id space: the simulator interns into ``World.interner``, and ids from
#: the two namespaces are not interchangeable — never pass an id from one
#: interner to tables indexed by another.
INTERNER = SignatureInterner()


def intern_sig(sig: Signature) -> int:
    """Intern ``sig`` in the standalone module-level interner (see the
    INTERNER note — engine ids come from ``World.interner``)."""
    return INTERNER.intern(sig)


def sig_of(sid: int) -> Signature:
    """Resolve a standalone-interner id back to its (equal) Signature."""
    return INTERNER.sigs[sid]


def comp_sig(name: str, *params) -> Signature:
    return Signature("comp", name, tuple(params))


def comm_sig(name: str, nbytes: int, comm_size: int, comm_stride: int) -> Signature:
    """Communication-kernel signature.

    Message sizes are bucketed to powers of two so that a gradually shrinking
    message (e.g. CANDMC's trailing-matrix broadcasts) maps onto a bounded
    number of signatures, mirroring the paper's observation that kernels with
    many distinct input sizes limit modeling opportunities but nearby sizes
    behave identically.
    """
    return Signature("comm", name, (_bucket(nbytes), comm_size, comm_stride))


def p2p_sig(name: str, nbytes: int) -> Signature:
    """Point-to-point configurations are treated as size-2 sub-communicators
    (paper §V.D)."""
    return Signature("comm", name, (_bucket(nbytes), 2, 0))


def _bucket(nbytes: int) -> int:
    if nbytes <= 0:
        return 0
    return 1 << (int(nbytes - 1).bit_length())


def structural_key(sig: Signature, world_size: int) -> str:
    """World-independent identity of a kernel signature, for cross-study
    statistics transfer (``repro.api.transfer``).

    Two studies only share a ``Signature`` object space per interner, and a
    comm signature's ``(comm_size, comm_stride)`` is meaningful only
    relative to its own world.  The structural key normalizes that away so
    banks built on one machine geometry can seed another:

    - compute kernels are already world-independent: the key is the
      compact ``str(sig)`` form (routine + dims/flags);
    - communication kernels keep the power-of-two byte bucket and express
      cartesian sub-communicators as *fractions of the world*:
      ``comm_size / world_size`` and (for strided channels) ``comm_stride
      / world_size`` as reduced fractions, with stride 1 (contiguous
      fiber) kept verbatim.  A full-world bcast therefore matches a
      full-world bcast at any processor count, and a strided fiber
      matches the same relative grid shape.  Stride 0 marks p2p and
      non-cartesian rank sets, whose sizes are absolute (a pairwise
      exchange is a pairwise exchange at any world size) and are kept
      verbatim.

    Keys are plain strings (stable, log-friendly, JSON-dict-ready).
    """
    if sig.kind != "comm":
        return str(sig)
    nbytes, size, stride = sig.params
    w = max(int(world_size), 1)

    def frac(x: int) -> str:
        g = math.gcd(int(x), w) or 1
        num, den = int(x) // g, w // g
        return str(num) if den == 1 else f"{num}/{den}"

    if stride == 0:        # p2p / non-cartesian: absolute size
        return f"comm:{sig.name}(b{nbytes},s{size},t0)"
    s = "1" if stride == 1 else frac(stride)
    return f"comm:{sig.name}(b{nbytes},s{frac(size)},t{s})"


def flops_of(sig: Signature) -> float:
    """Analytic flop count for the BLAS/LAPACK compute signatures used by the
    linalg case studies — consumed by the cost model and by the beyond-paper
    extrapolation features.  Dims convention documented per-routine."""
    if sig.kind != "comp":
        return 0.0
    n = sig.name
    p = sig.params
    if n == "gemm":      # (m, n, k)
        m, nn, k = p[0], p[1], p[2]
        return 2.0 * m * nn * k
    if n == "syrk":      # (n, k): C (n x n) += A (n x k) A^T
        return float(p[0]) * p[0] * p[1]
    if n == "trsm":      # (m, n): triangular solve with m x m tri, n rhs
        return float(p[0]) * p[0] * p[1]
    if n == "trmm":      # (m, n)
        return float(p[0]) * p[0] * p[1]
    if n == "potrf":     # (n,)
        return p[0] ** 3 / 3.0
    if n == "trtri":     # (n,)
        return p[0] ** 3 / 3.0
    if n == "geqrf":     # (m, n) tall-skinny QR panel
        m, nn = p[0], p[1]
        return 2.0 * m * nn * nn
    if n == "ormqr":     # (m, n, k) apply Q
        return 4.0 * p[0] * p[1] * p[2]
    if n == "tpqrt":     # (m, n) triangular-pentagonal QR
        return 2.0 * p[0] * p[1] * p[1]
    if n == "tpmqrt":    # (m, n, k)
        return 4.0 * p[0] * p[1] * p[2]
    if n == "blk2cyc":   # (nbytes,) data redistribution — bandwidth bound
        return 0.0
    # LM-framework kernels carry explicit flops in params[-1] by convention
    if p and isinstance(p[-1], float):
        return p[-1]
    return 0.0


def bytes_of(sig: Signature) -> float:
    """Approximate bytes moved (8-byte words for linalg)."""
    if sig.kind == "comm":
        return float(sig.params[0])
    n, p = sig.name, sig.params
    w = 8.0
    if n == "gemm":
        m, nn, k = p[0], p[1], p[2]
        return w * (m * k + k * nn + 2 * m * nn)
    if n in ("syrk",):
        return w * (p[0] * p[1] + p[0] * p[0])
    if n in ("trsm", "trmm"):
        return w * (p[0] * p[0] / 2 + 2 * p[0] * p[1])
    if n in ("potrf", "trtri"):
        return w * p[0] * p[0]
    if n in ("geqrf", "tpqrt"):
        return w * 2 * p[0] * p[1]
    if n in ("ormqr", "tpmqrt"):
        return w * (p[0] * p[1] * 2 + p[0] * p[2])
    if n == "blk2cyc":
        return float(p[0])
    return 0.0
