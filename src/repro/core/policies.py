"""Selective kernel-execution policies (paper §IV.B).

Five policies, ordered from most conservative to most aggressive:

- ``conditional``  — no execution-count usage: a kernel is skipped only when
  its plain CI satisfies the tolerance. Executes every kernel at least once
  per tuning iteration.
- ``local``        — like conditional, but the CI is shrunk by sqrt(freq)
  using only *locally observed* execution counts.
- ``online``       — critical-path execution counts are propagated online
  between processors (longest-path algorithm) and used to shrink the CI.
- ``apriori``      — one initial full iteration records exact critical-path
  counts, which subsequent iterations apply immediately (the extra full
  execution is charged to the autotuning time, as in the paper).
- ``eager``        — a kernel is switched off globally as soon as a single
  processor deems it predictable *and* its statistics have been propagated
  across aggregate channels spanning the whole machine; kernels are NOT
  re-executed once per iteration, and models persist across configurations
  that share kernel signatures.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

POLICIES = ("conditional", "local", "online", "apriori", "eager")


@dataclass(frozen=True)
class Policy:
    name: str
    # confidence tolerance epsilon: relative CI size below which a kernel's
    # time is considered sufficiently predictable.
    tolerance: float = 0.25
    # minimum samples before a kernel may be considered predictable
    min_samples: int = 3
    # fraction of a communication kernel's sub-communicator that must deem it
    # predictable for the execution to be skipped (default: all).
    comm_vote_fraction: float = 1.0
    # beyond-paper: allow the tuner to predict kernels never executed, via
    # per-op-family input-size extrapolation models (paper §VIII future work)
    extrapolate: bool = False

    def __post_init__(self):
        if self.name not in POLICIES:
            raise ValueError(f"unknown policy {self.name!r}; want one of {POLICIES}")

    @property
    def uses_counts(self) -> bool:
        return self.name in ("local", "online", "apriori")

    @property
    def propagates_counts(self) -> bool:
        return self.name == "online"

    @property
    def needs_offline_pass(self) -> bool:
        return self.name == "apriori"

    @property
    def once_per_iteration(self) -> bool:
        """All methods except eager execute each kernel at least once per
        tuning iteration (paper §VI.A)."""
        return self.name != "eager"

    @property
    def persistent_models(self) -> bool:
        """Eager propagation reuses kernel performance models across
        configurations (paper §VI.B)."""
        return self.name == "eager"


def policy(name: str, tolerance: float = 0.25, **kw) -> Policy:
    return Policy(name=name, tolerance=tolerance, **kw)
