"""Per-rank pathsets and kernel sets, stored struct-of-arrays.

Each processor owns (paper §III.B):

- ``K-bar``   — performance statistics for each locally-executed kernel;
- ``K-tilde`` — per-kernel info along its *current sub-critical path*
                (execution counts/frequencies, propagation bookkeeping);
- pathset ``P`` — the accumulated cost metrics of the rank's current
                sub-critical path (exec time, and the breakdown into
                computation / communication time used by the paper's
                critical-path metrics).

The seed implementation kept one object per rank holding dict-of-Signature
tables; this rewrite stores everything as NumPy struct-of-arrays indexed by
``(rank, signature id)`` (see ``core.signatures.SignatureInterner``), so

- the internal allreduce at collectives (max-path winner, clock sync,
  critical-path count adoption) is a vectorized reduction over participant
  index arrays instead of a Python loop over ranks x kernels, and
- ``report()`` is a handful of array reductions.

Path-profile quantities (``path_*``: exec/comp/comm time estimates) travel
with the longest-path adoption protocol; *physical* quantities — the
wall-clock the rank actually spends under selective execution (``clock``)
and the time it spends really executing kernels (``measured_*``) — are
per-rank and are never adopted.  K-bar keeps one ``KernelStats`` object per
(rank, sid) — Welford merge/copy semantics live there — with the sample
mean mirrored into ``mean_arr`` so skip-path predictions vectorize.
"""

from __future__ import annotations

import math
from typing import Dict, List, Set

import numpy as np

from .stats import KernelStats


class EngineState:
    """All Critter state for all ranks, struct-of-arrays.

    Column capacity grows on demand as new signature ids are interned; rows
    are fixed at the world size.  ``seen[r, s]`` marks membership of sid
    ``s`` in rank ``r``'s K-tilde (the seed kept dict keys for this), which
    the count-adoption protocol needs: a dominated rank adopts the winner's
    counts only for kernels *the winner has seen*, keeping its own counts
    for the rest.

    Forced-run liveness contract (the batched cold path relies on this —
    see ``Critter.on_comp_cold``/``finish_cold``): during a forced run,
    ``freq`` and ``seen`` are read mid-run (Isend snapshots, count
    adoption) and must be written per event, while ``iter_exec``,
    ``mean_arr`` and ``skip_ok`` are only consumed by the selective vote
    and skip-prediction paths — never under force — so cold interceptions
    may defer them to one bulk pass at the end of the run (``iter_exec``,
    ``mean_arr``) or elide no-op writes entirely (``skip_ok``, all-False
    after ``reset_iteration`` and never set under force).
    """

    __slots__ = ("n_ranks", "cap", "clock", "path_exec", "path_comp",
                 "path_comm", "path_kernels", "measured_time",
                 "measured_comp", "executed", "skipped", "freq", "seen",
                 "iter_exec", "mean_arr", "skip_ok", "goff", "gmean",
                 "kbar", "agg_channels", "pred_live")

    def __init__(self, n_ranks: int, cap: int = 256):
        self.n_ranks = n_ranks
        self.cap = cap
        # per-rank scalars ---------------------------------------------------
        self.clock = np.zeros(n_ranks)
        self.path_exec = np.zeros(n_ranks)
        self.path_comp = np.zeros(n_ranks)
        self.path_comm = np.zeros(n_ranks)
        self.path_kernels = np.zeros(n_ranks, dtype=np.int64)
        self.measured_time = np.zeros(n_ranks)
        self.measured_comp = np.zeros(n_ranks)
        self.executed = np.zeros(n_ranks, dtype=np.int64)
        self.skipped = np.zeros(n_ranks, dtype=np.int64)
        # per (rank, sid) ----------------------------------------------------
        self.freq = np.zeros((n_ranks, cap), dtype=np.int64)
        self.seen = np.zeros((n_ranks, cap), dtype=bool)
        self.iter_exec = np.zeros((n_ranks, cap), dtype=bool)
        # mirror of kbar[r][sid].mean (NaN when absent or n == 0)
        self.mean_arr = np.full((n_ranks, cap), math.nan)
        # memoized skip verdicts: True means "this rank's local execute vote
        # for sid is SKIP, proven at critical-path count 1" — such verdicts
        # are immune to count adoption (relative CI only shrinks with freq)
        # and stay valid until the (rank, sid) statistics change or the
        # iteration ends (see Critter._skip_verdict)
        self.skip_ok = np.zeros((n_ranks, cap), dtype=bool)
        # eager global switch-off, array form (mirrors Critter.global_off):
        # goff[sid] + the globally-agreed mean for switched-off kernels
        self.goff = np.zeros(cap, dtype=bool)
        self.gmean = np.full(cap, math.nan)
        # K-bar: Welford statistics objects, dict-of-int per rank
        self.kbar: List[Dict[int, KernelStats]] = \
            [dict() for _ in range(n_ranks)]
        # K[i].agg_channels: channel hashes a kernel's statistics have been
        # propagated along (eager), per rank {sid: set-of-hash}
        self.agg_channels: List[Dict[int, Set[int]]] = \
            [dict() for _ in range(n_ranks)]
        # eager-only dirty set: sids whose CURRENT statistics on this rank
        # are predictable at critical-path count 1 — exactly the candidate
        # precondition of aggregate_statistics, maintained incrementally at
        # every statistics write so the per-collective scan touches only
        # these instead of walking the whole K-bar (sids already switched
        # off globally are filtered lazily during the scan)
        self.pred_live: List[Set[int]] = [set() for _ in range(n_ranks)]

    # -- capacity ------------------------------------------------------------

    def ensure(self, sid: int) -> None:
        """Grow column capacity to cover ``sid``."""
        if sid < self.cap:
            return
        new_cap = max(self.cap * 2, sid + 1)
        pad = new_cap - self.cap
        self.freq = np.pad(self.freq, ((0, 0), (0, pad)))
        self.seen = np.pad(self.seen, ((0, 0), (0, pad)))
        self.iter_exec = np.pad(self.iter_exec, ((0, 0), (0, pad)))
        self.mean_arr = np.pad(self.mean_arr, ((0, 0), (0, pad)),
                               constant_values=math.nan)
        self.skip_ok = np.pad(self.skip_ok, ((0, 0), (0, pad)))
        self.goff = np.pad(self.goff, (0, pad))
        self.gmean = np.pad(self.gmean, (0, pad), constant_values=math.nan)
        self.cap = new_cap

    # -- resets --------------------------------------------------------------

    def reset_iteration(self) -> None:
        """Reset per-iteration path state (start of a configuration run);
        K-tilde membership, statistics and propagation sets persist."""
        self.clock.fill(0.0)
        self.path_exec.fill(0.0)
        self.path_comp.fill(0.0)
        self.path_comm.fill(0.0)
        self.path_kernels.fill(0)
        self.measured_time.fill(0.0)
        self.measured_comp.fill(0.0)
        self.executed.fill(0)
        self.skipped.fill(0)
        self.freq.fill(0)
        self.iter_exec.fill(False)
        self.skip_ok.fill(False)

    def reset_models(self) -> None:
        """Forget all kernel statistics (paper: 'we reset the performance
        statistics of all kernels before tuning a new configuration')."""
        for d in self.kbar:
            d.clear()
        for d in self.agg_channels:
            d.clear()
        for s in self.pred_live:
            s.clear()
        self.seen.fill(False)
        self.freq.fill(0)
        self.mean_arr.fill(math.nan)
        self.skip_ok.fill(False)
        self.goff.fill(False)
        self.gmean.fill(math.nan)

    # -- K-bar helpers -------------------------------------------------------

    def stats(self, rank: int, sid: int) -> KernelStats:
        d = self.kbar[rank]
        st = d.get(sid)
        if st is None:
            st = d[sid] = KernelStats()
        return st


class ColdScalars:
    """List-backed mirrors of the per-rank scalar timers for the duration
    of one forced (cold) run.

    The cold interpreter's interceptions are dominated by scalar reads and
    read-modify-writes of the per-rank accumulators (clock, path profile,
    measured time, counters) — on the p2p-heavy programs two ranks per
    event, several fields each.  NumPy scalar indexing pays boxing/unboxing
    per access; plain Python lists of floats/ints are several times
    cheaper, and the arithmetic (IEEE double adds, int increments, max of
    two floats) is value-identical.  ``Critter.begin_cold`` snapshots the
    arrays into lists, the ``*_cold`` interceptions operate on them, and
    ``finish_cold`` writes them back — nothing else reads the per-rank
    scalars mid-forced-run (the selective vote and skip-prediction paths
    never run under force).  ``skipped`` is untouched by forced runs and
    stays on the array.
    """

    __slots__ = ("clock", "path_exec", "path_comp", "path_comm",
                 "path_kernels", "measured_time", "measured_comp",
                 "executed")

    def __init__(self, S: EngineState):
        self.clock = S.clock.tolist()
        self.path_exec = S.path_exec.tolist()
        self.path_comp = S.path_comp.tolist()
        self.path_comm = S.path_comm.tolist()
        self.path_kernels = S.path_kernels.tolist()
        self.measured_time = S.measured_time.tolist()
        self.measured_comp = S.measured_comp.tolist()
        self.executed = S.executed.tolist()

    def writeback(self, S: EngineState) -> None:
        S.clock[:] = self.clock
        S.path_exec[:] = self.path_exec
        S.path_comp[:] = self.path_comp
        S.path_comm[:] = self.path_comm
        S.path_kernels[:] = self.path_kernels
        S.measured_time[:] = self.measured_time
        S.measured_comp[:] = self.measured_comp
        S.executed[:] = self.executed


class WarmMirror:
    """List-backed mirrors of the full engine state for one compiled
    (warm, selective) replay — ``ColdScalars`` extended to the per-
    (rank, sid) tables.

    The compiled warm interpreter (``Critter.run_warm``) is dominated by
    scalar reads of ``skip_ok``/``mean_arr``/``goff``/``gmean`` and scalar
    read-modify-writes of ``freq``/``seen`` and the per-rank accumulators;
    Python lists make each of those several times cheaper than NumPy
    scalar indexing while keeping the arithmetic value-identical (IEEE
    double adds, int increments, bool stores).  Rows are truncated to
    ``nlive`` — the number of interned signatures when the replay starts —
    which covers every sid the recorded program can touch; columns at or
    beyond ``nlive`` are provably untouched and keep their array values.

    ``goff``/``gmean`` are read-only snapshots refreshed by the caller
    after eager aggregation (which writes the arrays directly); they are
    not written back.
    """

    __slots__ = ("nlive", "clock", "path_exec", "path_comp", "path_comm",
                 "path_kernels", "measured_time", "measured_comp",
                 "executed", "skipped", "freq", "seen", "iter_exec",
                 "mean", "skip_ok", "goff", "gmean")

    def __init__(self, S: EngineState, nlive: int):
        self.nlive = nlive
        self.clock = S.clock.tolist()
        self.path_exec = S.path_exec.tolist()
        self.path_comp = S.path_comp.tolist()
        self.path_comm = S.path_comm.tolist()
        self.path_kernels = S.path_kernels.tolist()
        self.measured_time = S.measured_time.tolist()
        self.measured_comp = S.measured_comp.tolist()
        self.executed = S.executed.tolist()
        self.skipped = S.skipped.tolist()
        self.freq = S.freq[:, :nlive].tolist()
        self.seen = S.seen[:, :nlive].tolist()
        self.iter_exec = S.iter_exec[:, :nlive].tolist()
        self.mean = S.mean_arr[:, :nlive].tolist()
        self.skip_ok = S.skip_ok[:, :nlive].tolist()
        self.goff = S.goff[:nlive].tolist()
        self.gmean = S.gmean[:nlive].tolist()

    def pull_rank(self, S: EngineState, r: int) -> None:
        """Re-snapshot one rank's prediction rows after an external write
        (eager aggregation updates ``mean_arr``/``skip_ok`` in place)."""
        n = self.nlive
        self.mean[r] = S.mean_arr[r, :n].tolist()
        self.skip_ok[r] = S.skip_ok[r, :n].tolist()

    def pull_global(self, S: EngineState) -> None:
        n = self.nlive
        self.goff = S.goff[:n].tolist()
        self.gmean = S.gmean[:n].tolist()

    def push_rank(self, S: EngineState, r: int) -> None:
        """Write one rank's rows back before an external reader (eager
        aggregation reads ``mean_arr`` via KernelStats, and writes must
        land on current values)."""
        n = self.nlive
        if n:
            S.mean_arr[r, :n] = self.mean[r]
            S.skip_ok[r, :n] = self.skip_ok[r]

    def writeback(self, S: EngineState) -> None:
        S.clock[:] = self.clock
        S.path_exec[:] = self.path_exec
        S.path_comp[:] = self.path_comp
        S.path_comm[:] = self.path_comm
        S.path_kernels[:] = self.path_kernels
        S.measured_time[:] = self.measured_time
        S.measured_comp[:] = self.measured_comp
        S.executed[:] = self.executed
        S.skipped[:] = self.skipped
        n = self.nlive
        if n:
            S.freq[:, :n] = self.freq
            S.seen[:, :n] = self.seen
            S.iter_exec[:, :n] = self.iter_exec
            S.mean_arr[:, :n] = self.mean
            S.skip_ok[:, :n] = self.skip_ok
