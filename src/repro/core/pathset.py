"""Per-rank pathsets and kernel sets.

Each processor owns (paper §III.B):

- ``K-bar``   — performance statistics for each locally-executed kernel;
- ``K-tilde`` — per-kernel info along its *current sub-critical path*
                (execution counts/frequencies, predictability flags);
- pathset ``P`` — the accumulated cost metrics of the rank's current
                sub-critical path (exec time, and the breakdown into
                computation / communication time used by the paper's
                critical-path metrics).

Path-profile quantities (exec/comp/comm time estimates) travel with the
longest-path adoption protocol; *physical* quantities — the wall-clock the
rank actually spends under selective execution (``clock``) and the time it
spends really executing kernels (``measured_*``) — are per-rank and are
never adopted.
"""

from __future__ import annotations

from typing import Dict

from .signatures import Signature
from .stats import KernelStats, PathKernelInfo


class PathProfile:
    """The pathset P: cost metrics accumulated along the current
    sub-critical path of one rank.  Adopted wholesale when a communication
    partner's path dominates (longest-path algorithm)."""

    __slots__ = ("exec_time", "comp_time", "comm_time", "kernel_count")

    def __init__(self, exec_time=0.0, comp_time=0.0, comm_time=0.0,
                 kernel_count=0):
        self.exec_time = exec_time
        self.comp_time = comp_time
        self.comm_time = comm_time
        self.kernel_count = kernel_count

    def copy(self) -> "PathProfile":
        return PathProfile(self.exec_time, self.comp_time, self.comm_time,
                           self.kernel_count)

    def adopt(self, other: "PathProfile") -> None:
        self.exec_time = other.exec_time
        self.comp_time = other.comp_time
        self.comm_time = other.comm_time
        self.kernel_count = other.kernel_count


class RankState:
    """All Critter state owned by one virtual rank."""

    __slots__ = ("rank", "kbar", "ktilde", "path", "clock",
                 "measured_time", "measured_comp", "iter_executed",
                 "executed_kernels", "skipped_kernels")

    def __init__(self, rank: int):
        self.rank = rank
        self.kbar: Dict[Signature, KernelStats] = {}
        self.ktilde: Dict[Signature, PathKernelInfo] = {}
        self.path = PathProfile()
        # wall-clock the rank actually spends under selective execution: the
        # discrete-event clock.  path.exec_time is the *estimated*
        # full-execution time along the rank's current sub-critical path.
        self.clock = 0.0
        self.measured_time = 0.0    # time spent really executing kernels
        self.measured_comp = 0.0    # ... computation portion (Fig 4c/5c)
        self.iter_executed = set()  # signatures executed this tuning iteration
        self.executed_kernels = 0
        self.skipped_kernels = 0

    def stats(self, sig: Signature) -> KernelStats:
        st = self.kbar.get(sig)
        if st is None:
            st = KernelStats()
            self.kbar[sig] = st
        return st

    def info(self, sig: Signature) -> PathKernelInfo:
        pi = self.ktilde.get(sig)
        if pi is None:
            pi = PathKernelInfo()
            self.ktilde[sig] = pi
        return pi

    def adopt_freqs(self, winner: "RankState") -> None:
        """Adopt the dominating rank's critical-path kernel frequencies
        (Figure 2: K[:].freq = int_gmsg.freqs) — 'online' policy only."""
        mine = self.ktilde
        for sig, info in winner.ktilde.items():
            pi = mine.get(sig)
            if pi is None:
                pi = PathKernelInfo()
                mine[sig] = pi
            pi.freq = info.freq

    def reset_iteration(self) -> None:
        """Reset per-iteration path state (start of a configuration run)."""
        self.path = PathProfile()
        self.clock = 0.0
        self.measured_time = 0.0
        self.measured_comp = 0.0
        self.iter_executed = set()
        self.executed_kernels = 0
        self.skipped_kernels = 0
        for info in self.ktilde.values():
            info.freq = 0

    def reset_models(self) -> None:
        """Forget all kernel statistics (paper: 'we reset the performance
        statistics of all kernels before tuning a new configuration')."""
        self.kbar = {}
        self.ktilde = {}
