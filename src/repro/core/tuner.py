"""The approximate autotuner.

Drives a configuration-space search over a study (a set of schedule
configurations sharing a virtual machine), measuring what the paper
measures (§VI.A):

- per-configuration *relative prediction error*: selective-execution
  estimate vs a full execution performed directly prior;
- *autotuning speedup*: total benchmark time under full kernel execution vs
  under selective execution (including policy extras such as the a-priori
  offline pass);
- *optimum selection quality*: the configuration the tuner would pick vs
  the configuration a full-execution exhaustive search picks.

Exhaustive search mirrors the paper's evaluation; ``tune_racing`` is the
beyond-paper integration of the paper's own confidence intervals with a
racing/successive-halving search that prunes configurations whose CI lower
bound exceeds the incumbent's upper bound.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.simmpi.comm import World
from repro.simmpi.costmodel import CostModel, MachineSpec, KNL_STAMPEDE2
from repro.simmpi.runtime import Runtime
from .critter import Critter
from .policies import Policy
from .stats import KernelStats, t_quantile_975


@dataclass
class Configuration:
    """One point of the tuning space: a named schedule generator."""

    name: str
    params: dict
    # make_program(world) -> program_factory(rank, world) -> generator
    make_program: Callable[[World], Callable]


@dataclass
class Study:
    """A tuning study: configurations sharing one virtual machine."""

    name: str
    world_size: int
    configs: List[Configuration]
    # paper §VI.A: SLATE/CANDMC reset kernel statistics between
    # configurations; Capital does not (eager reuses models across configs)
    reset_between_configs: bool = True
    machine: MachineSpec = KNL_STAMPEDE2


@dataclass
class ConfigRecord:
    name: str
    params: dict
    full_time: float
    predicted: float
    rel_error: float
    comp_error: float
    selective_cost: float     # wall time paid for this config's trials
    full_cost: float          # what full execution would have paid
    executed: int
    skipped: int
    predictions: List[float] = field(default_factory=list)


@dataclass
class StudyReport:
    study: str
    policy: str
    tolerance: float
    records: List[ConfigRecord]
    full_tuning_time: float
    selective_tuning_time: float

    @property
    def speedup(self) -> float:
        if self.selective_tuning_time <= 0:
            return math.inf
        return self.full_tuning_time / self.selective_tuning_time

    @property
    def mean_error(self) -> float:
        return float(np.mean([r.rel_error for r in self.records]))

    @property
    def mean_comp_error(self) -> float:
        return float(np.mean([r.comp_error for r in self.records]))

    @property
    def chosen(self) -> ConfigRecord:
        return min(self.records, key=lambda r: r.predicted)

    @property
    def true_best(self) -> ConfigRecord:
        return min(self.records, key=lambda r: r.full_time)

    @property
    def optimum_quality(self) -> float:
        """full-execution time of the truly-best config divided by that of
        the chosen config (1.0 = optimal choice; paper reports >= 0.99)."""
        return self.true_best.full_time / self.chosen.full_time

    def row(self) -> dict:
        return {
            "study": self.study, "policy": self.policy,
            "tolerance": self.tolerance, "speedup": self.speedup,
            "mean_error": self.mean_error,
            "mean_comp_error": self.mean_comp_error,
            "optimum_quality": self.optimum_quality,
            "full_time": self.full_tuning_time,
            "selective_time": self.selective_tuning_time,
        }


class Autotuner:
    """Exhaustive (paper) and racing (beyond-paper) searches."""

    def __init__(self, study: Study, policy: Policy, *,
                 trials: int = 3, seed: int = 0, allocation: int = 0,
                 timer: Optional[Callable] = None,
                 cost_model: Optional[CostModel] = None,
                 overhead: float = 1e-6):
        self.study = study
        self.policy = policy
        self.trials = trials
        self.world = World(study.world_size)
        self.critter = Critter(self.world, policy)
        if timer is None:
            cm = cost_model or CostModel(study.machine, allocation=allocation,
                                         seed=seed)
            timer = cm.sample
        self.runtime = Runtime(self.world, self.critter, timer,
                               seed=seed + 17 * allocation, overhead=overhead)

    # -- exhaustive (the paper's evaluation protocol) -------------------------

    def run_config(self, cfg: Configuration) -> ConfigRecord:
        rt, critter = self.runtime, self.critter
        prog = cfg.make_program(self.world)

        # full execution performed directly prior to the approximated one
        # (measures prediction error; does not feed the models)
        ref = rt.run(prog, force_execute=True, update_stats=False)
        full_time = ref.wall_time
        full_comp = ref.crit_comp

        selective_cost = 0.0
        if self.policy.needs_offline_pass:
            off = rt.run(prog, force_execute=True, update_stats=True)
            critter.snapshot_apriori_counts()
            selective_cost += off.wall_time

        predictions: List[float] = []
        last = None
        for _ in range(self.trials):
            last = rt.run(prog)
            selective_cost += last.wall_time
            predictions.append(last.predicted_time)

        predicted = predictions[-1]
        rel_error = abs(predicted - full_time) / full_time
        comp_error = (abs(last.crit_comp - full_comp) / full_comp
                      if full_comp > 0 else 0.0)
        return ConfigRecord(
            name=cfg.name, params=cfg.params, full_time=full_time,
            predicted=predicted, rel_error=rel_error, comp_error=comp_error,
            selective_cost=selective_cost,
            full_cost=full_time * self.trials,
            executed=last.executed, skipped=last.skipped,
            predictions=predictions)

    def tune(self) -> StudyReport:
        records = []
        for i, cfg in enumerate(self.study.configs):
            if i > 0 and self.study.reset_between_configs:
                self.critter.reset_models()
            records.append(self.run_config(cfg))
        return StudyReport(
            study=self.study.name, policy=self.policy.name,
            tolerance=self.policy.tolerance, records=records,
            full_tuning_time=sum(r.full_cost for r in records),
            selective_tuning_time=sum(r.selective_cost for r in records))

    # -- racing search (beyond-paper) ------------------------------------------

    def tune_racing(self, *, max_rounds: int = 6,
                    min_survivor_trials: int = 2) -> "RacingReport":
        """Successive elimination driven by the paper's own CIs.

        Each round gives every surviving configuration one selective
        benchmark; a configuration is pruned once the lower CI bound of its
        predicted time exceeds the upper CI bound of the incumbent.  The
        per-kernel statistical machinery is reused verbatim — racing only
        changes *which* configurations keep getting iterations, exactly the
        composition the paper suggests with search-space pruning studies.
        """
        rt, critter = self.runtime, self.critter
        cfgs = list(self.study.configs)
        progs = {c.name: c.make_program(self.world) for c in cfgs}
        samples: Dict[str, List[float]] = {c.name: [] for c in cfgs}
        active = {c.name for c in cfgs}
        cost = 0.0
        pruned_at: Dict[str, int] = {}

        def ci(name):
            xs = samples[name]
            n = len(xs)
            m = float(np.mean(xs))
            if n < 2:
                return m, math.inf
            hw = t_quantile_975(n - 1) * float(np.std(xs, ddof=1)) / math.sqrt(n)
            return m, hw

        for rnd in range(max_rounds):
            for c in cfgs:
                if c.name not in active:
                    continue
                if self.study.reset_between_configs and len(cfgs) > 1:
                    # racing interleaves configs; resetting would discard
                    # everything each step — keep models per config name
                    pass
                res = rt.run(progs[c.name])
                cost += res.wall_time
                samples[c.name].append(res.predicted_time)
            # prune
            stats = {nm: ci(nm) for nm in active}
            inc = min(stats, key=lambda nm: stats[nm][0])
            inc_hi = stats[inc][0] + stats[inc][1]
            for nm in list(active):
                if nm == inc:
                    continue
                m, hw = stats[nm]
                if len(samples[nm]) >= min_survivor_trials and m - hw > inc_hi:
                    active.remove(nm)
                    pruned_at[nm] = rnd
            if len(active) == 1:
                break
        best = min(active, key=lambda nm: float(np.mean(samples[nm])))
        return RacingReport(study=self.study.name, policy=self.policy.name,
                            tolerance=self.policy.tolerance,
                            best=best, cost=cost, samples=samples,
                            pruned_at=pruned_at,
                            survivors=sorted(active))


@dataclass
class RacingReport:
    study: str
    policy: str
    tolerance: float
    best: str
    cost: float
    samples: Dict[str, List[float]]
    pruned_at: Dict[str, int]
    survivors: List[str]

    @property
    def total_iterations(self) -> int:
        return sum(len(v) for v in self.samples.values())
