"""The approximate autotuner — legacy entry point.

.. deprecated::
    ``repro.api`` is the supported front-end: ``AutotuneSession`` over a
    ``SimBackend`` subsumes everything here (plus wall-clock and dry-run
    backends, process-parallel sweeps, and checkpoint/resume).  This
    module remains as a thin shim because the golden-report regression
    and the published benchmarks pin its exact protocol; the measurement
    logic itself lives in ``repro.api.search`` (drivers) and
    ``repro.api.backends.SimBackend`` (virtual-machine execution).

Drives a configuration-space search over a study (a set of schedule
configurations sharing a virtual machine), measuring what the paper
measures (§VI.A):

- per-configuration *relative prediction error*: selective-execution
  estimate vs a full execution performed directly prior;
- *autotuning speedup*: total benchmark time under full kernel execution vs
  under selective execution (including policy extras such as the a-priori
  offline pass);
- *optimum selection quality*: the configuration the tuner would pick vs
  the configuration a full-execution exhaustive search picks.

Exhaustive search mirrors the paper's evaluation; ``tune_racing`` is the
beyond-paper integration of the paper's own confidence intervals with a
racing/successive-halving search that prunes configurations whose CI lower
bound exceeds the incumbent's upper bound.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from repro.api.result import ConfigRecord, StudyResult
from repro.api.search import exhaustive, measure_config, racing
from repro.api.space import ConfigPoint, SearchSpace
from repro.simmpi.costmodel import CostModel, MachineSpec, KNL_STAMPEDE2

from .policies import Policy

#: historical name for the uniform study report (same class; the api name
#: is ``StudyResult``)
StudyReport = StudyResult


@dataclass
class Configuration:
    """One point of the tuning space: a named schedule generator."""

    name: str
    params: dict
    # make_program(world) -> program_factory(rank, world) -> generator
    make_program: Callable[["World"], Callable]


@dataclass
class Study:
    """A tuning study: configurations sharing one virtual machine."""

    name: str
    world_size: int
    configs: List[Configuration]
    # paper §VI.A: SLATE/CANDMC reset kernel statistics between
    # configurations; Capital does not (eager reuses models across configs)
    reset_between_configs: bool = True
    machine: MachineSpec = KNL_STAMPEDE2


def space_of_study(study: Study) -> SearchSpace:
    """Adapt a legacy ``Study`` to the session API's ``SearchSpace``."""
    return SearchSpace(
        name=study.name,
        points=[ConfigPoint(name=c.name, params=c.params,
                            payload=c.make_program)
                for c in study.configs],
        reset_between_configs=study.reset_between_configs,
        world_size=study.world_size, machine=study.machine)


class Autotuner:
    """Exhaustive (paper) and racing (beyond-paper) searches.

    Thin shim over ``repro.api``: builds a ``SimBackend`` run and
    delegates to the lifted search drivers.  ``world``/``critter``/
    ``runtime`` stay exposed — benchmarks introspect them.
    """

    def __init__(self, study: Study, policy: Policy, *,
                 trials: int = 3, seed: int = 0, allocation: int = 0,
                 timer: Optional[Callable] = None,
                 cost_model: Optional[CostModel] = None,
                 overhead: float = 1e-6):
        from repro.api.backends import SimBackend   # avoid import cycle
        self.study = study
        self.policy = policy
        self.trials = trials
        self.space = space_of_study(study)
        self._run = SimBackend(
            machine=study.machine, timer=timer, cost_model=cost_model,
            overhead=overhead).open(self.space, policy, seed=seed,
                                    allocation=allocation)
        self.world = self._run.world
        self.critter = self._run.critter
        self.runtime = self._run.runtime

    # -- exhaustive (the paper's evaluation protocol) -------------------------

    def run_config(self, cfg: Configuration) -> ConfigRecord:
        # measure the configuration as passed (it need not belong to the
        # study — legacy callers probe ad-hoc configs)
        point = ConfigPoint(name=cfg.name, params=cfg.params,
                            payload=cfg.make_program)
        return measure_config(self._run, point, self.policy,
                              trials=self.trials)

    def tune(self) -> StudyReport:
        records, _ = exhaustive(self._run, self.space, self.policy,
                                trials=self.trials)
        return StudyReport(
            study=self.study.name, policy=self.policy.name,
            tolerance=self.policy.tolerance, records=records,
            full_tuning_time=sum(r.full_cost for r in records),
            selective_tuning_time=sum(r.selective_cost for r in records))

    # -- racing search (beyond-paper) ------------------------------------------

    def tune_racing(self, *, max_rounds: int = 6,
                    min_survivor_trials: int = 2) -> "RacingReport":
        records, extra = racing(self._run, self.space, self.policy,
                                max_rounds=max_rounds,
                                min_survivor_trials=min_survivor_trials)
        return RacingReport(
            study=self.study.name, policy=self.policy.name,
            tolerance=self.policy.tolerance, best=extra["best"],
            cost=extra["cost"],
            samples={r.name: r.predictions for r in records},
            pruned_at=extra["pruned_at"], survivors=extra["survivors"])


@dataclass
class RacingReport:
    study: str
    policy: str
    tolerance: float
    best: str
    cost: float
    samples: Dict[str, List[float]]
    pruned_at: Dict[str, int]
    survivors: List[str]

    @property
    def total_iterations(self) -> int:
        return sum(len(v) for v in self.samples.values())
