"""Cartesian communication channels and aggregate channels (paper §III.B).

A *channel* describes a sub-communicator as a strided subgrid of the world
communicator: an offset plus per-dimension (stride, size) pairs.  Channel
hash ids are generated purely from (stride, size) — offset-independent — so
that congruent sub-communicators (e.g. every row of a processor grid) share
one identity, which is what lets kernel statistics be aggregated across
symmetric grid slices.

*Aggregate channels* are recursively built unions of channels that span a
cartesian subgrid of the machine.  Once a kernel's statistics have been
propagated along a set of channels whose aggregate ``is_maximal`` (covers the
world communicator), every processor is known to hold the same statistics
and the kernel's execution can be switched off globally (eager propagation).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


def ranks_to_channel(ranks: Sequence[int]) -> Optional["Channel"]:
    """Recover a strided-cartesian description from a sorted rank list.

    Mirrors Critter's MPI_Comm_split interception: allgather world ranks,
    sort, then factor the rank set into (stride, size) dimensions.  Returns
    None if the rank set is not a cartesian (possibly multi-dimensional)
    strided grid — such communicators fall back to non-aggregating channels.
    """
    ranks = sorted(set(int(r) for r in ranks))
    if not ranks:
        return None
    offset = ranks[0]
    rel = [r - offset for r in ranks]
    dims: List[Tuple[int, int]] = []
    remaining = rel
    # Greedily peel the smallest stride: the gap between the first two ranks.
    while len(remaining) > 1:
        stride = remaining[1] - remaining[0]
        if stride <= 0:
            return None
        # size = how many consecutive multiples of stride are present
        size = 1
        while size < len(remaining) and remaining[size] == size * stride:
            size += 1
        if len(remaining) % size != 0:
            return None
        # verify remaining factors as blocks of this dimension
        nblocks = len(remaining) // size
        base: List[int] = []
        for b in range(nblocks):
            block = remaining[b * size:(b + 1) * size]
            start = block[0]
            for j, r in enumerate(block):
                if r != start + j * stride:
                    return None
            base.append(start)
        dims.append((stride, size))
        remaining = base
    return Channel(offset=offset, dims=tuple(dims) if dims else ((1, 1),))


@dataclass(frozen=True)
class Channel:
    """A strided cartesian subgrid of world ranks.

    dims is a tuple of (stride, size) pairs, innermost first.
    """

    offset: int
    dims: Tuple[Tuple[int, int], ...]

    @property
    def size(self) -> int:
        s = 1
        for _, sz in self.dims:
            s *= sz
        return s

    @property
    def hash_id(self) -> int:
        """Hash generated purely from (stride, size) pairs (Figure 2)."""
        h = 0x9E3779B97F4A7C15
        for stride, size in sorted(self.dims):
            h ^= (stride * 0x100000001B3 + size * 0x1B873593) & (2**64 - 1)
            h = (h * 0xC2B2AE3D27D4EB4F) & (2**64 - 1)
        return h

    def ranks(self) -> List[int]:
        out = [0]
        for stride, size in self.dims:
            out = [r + i * stride for i in range(size) for r in out]
        return sorted(self.offset + r for r in out)

    def key(self) -> Tuple[Tuple[int, int], ...]:
        """Offset-independent identity used for statistics aggregation."""
        return tuple(sorted(self.dims))


@dataclass
class Aggregate:
    """A recursively built union of channels spanning a cartesian subgrid."""

    dims: Tuple[Tuple[int, int], ...]       # combined (stride, size) pairs
    hash_id: int
    members: Tuple[int, ...]                # member channel hash ids
    is_maximal: bool = False

    @property
    def size(self) -> int:
        s = 1
        for _, sz in self.dims:
            s *= sz
        return s


class ChannelRegistry:
    """World-wide registry of channels and aggregate channels.

    The real Critter builds this identically on every rank from intercepted
    MPI_Comm_split calls; our simulator keeps one authoritative copy (the
    per-rank copies would be identical by construction).
    """

    def __init__(self, world_size: int):
        self.world_size = world_size
        self.channels: Dict[int, Channel] = {}
        self.aggregates: Dict[int, Aggregate] = {}
        # member-sets of world-spanning aggregates, precomputed so the
        # covers_world query on the eager hot path is a subset test per
        # covering set instead of a scan over every aggregate
        self._world_covers: List[frozenset] = []
        world = Channel(offset=0, dims=((1, world_size),))
        self.world_channel = world
        self.register(world)

    # -- registration (MPI_Init / MPI_Comm_split interception) -------------

    def register(self, channel: Channel) -> Channel:
        h = channel.hash_id
        if h not in self.channels:
            self.channels[h] = channel
            self._build_aggregates(channel)
        return channel

    def register_ranks(self, ranks: Sequence[int]) -> Optional[Channel]:
        ch = ranks_to_channel(ranks)
        if ch is not None:
            self.register(ch)
        return ch

    def _disjoint(self, a: Tuple[Tuple[int, int], ...],
                  b: Tuple[Tuple[int, int], ...]) -> bool:
        """Two dim-sets combine into a cartesian grid iff, sorted by stride,
        each dimension's stride is a multiple of (and at least) the previous
        dimension's span — every rank combination is then distinct and the
        union is a strided cartesian subgrid."""
        merged = sorted(a + b)
        span = 1
        for stride, size in merged:
            if stride < span or stride % span != 0:
                return False
            span = stride * size
        return span <= self.world_size

    def _build_aggregates(self, channel: Channel) -> None:
        """Recursively combine the new channel with existing aggregates
        (Figure 2, MPI_Comm_split interception)."""
        base = Aggregate(dims=tuple(sorted(channel.dims)),
                         hash_id=channel.hash_id,
                         members=(channel.hash_id,),
                         is_maximal=(channel.size == self.world_size))
        if base.hash_id not in self.aggregates:
            self.aggregates[base.hash_id] = base
            if base.size == self.world_size:
                self._world_covers.append(frozenset(base.members))
        frontier = [base]
        while frontier:
            nxt: List[Aggregate] = []
            for agg in frontier:
                for other in list(self.aggregates.values()):
                    if agg.hash_id == other.hash_id:
                        continue
                    if set(agg.members) & set(other.members):
                        continue
                    dims = tuple(sorted(agg.dims + other.dims))
                    if not self._disjoint(agg.dims, other.dims):
                        continue
                    new_hash = agg.hash_id ^ other.hash_id
                    if new_hash in self.aggregates:
                        continue
                    size = 1
                    for _, sz in dims:
                        size *= sz
                    if size > self.world_size:
                        continue
                    new = Aggregate(
                        dims=dims, hash_id=new_hash,
                        members=tuple(sorted(agg.members + other.members)),
                        is_maximal=(size == self.world_size))
                    if new.is_maximal:
                        # combining into the full machine demotes maximality
                        # of strict sub-aggregates (Figure 2: is_maximal=false)
                        for m in (agg, other):
                            if m.size < self.world_size:
                                m.is_maximal = False
                    self.aggregates[new_hash] = new
                    if new.size == self.world_size:
                        self._world_covers.append(frozenset(new.members))
                    nxt.append(new)
            frontier = nxt

    # -- queries ------------------------------------------------------------

    def covers_world(self, channel_hashes: set) -> bool:
        """True if some registered aggregate built solely from the given
        channel hashes spans the world communicator — i.e. a kernel whose
        statistics were propagated along these channels is globally agreed."""
        for members in self._world_covers:
            if members <= channel_hashes:
                return True
        return False
