"""core — the paper's contribution: statistical selective-execution
autotuning with online critical-path analysis (Critter)."""

from .signatures import (Signature, SignatureInterner, comp_sig, comm_sig,
                         p2p_sig, flops_of, bytes_of)
from .stats import KernelStats, t_quantile_975
from .pathset import EngineState
from .channels import Channel, ChannelRegistry, ranks_to_channel
from .policies import POLICIES, Policy, policy
from .critter import Critter, IterationReport
from .models import Extrapolator, FamilyModel
from .tuner import (Autotuner, Configuration, ConfigRecord, RacingReport,
                    Study, StudyReport)

__all__ = [
    "Signature", "SignatureInterner", "comp_sig", "comm_sig", "p2p_sig",
    "flops_of", "bytes_of",
    "KernelStats", "t_quantile_975",
    "EngineState",
    "Channel", "ChannelRegistry", "ranks_to_channel",
    "POLICIES", "Policy", "policy",
    "Critter", "IterationReport",
    "Extrapolator", "FamilyModel",
    "Autotuner", "Configuration", "ConfigRecord", "RacingReport",
    "Study", "StudyReport",
]
