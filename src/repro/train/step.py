"""The jitted training step: microbatched grad accumulation + AdamW.

``make_train_step`` builds a function

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)

closed over the model, the sharding rules and the step knobs.  Microbatching
splits the global batch into ``grad_accum`` slices scanned sequentially —
each slice's backward exposes its own reduce-scatter, which XLA's
latency-hiding scheduler overlaps with the next slice's compute (the
structural overlap is what the dry-run HLO exhibits; DESIGN.md §6).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.model import Model
from repro.parallel.sharding import ShardingRules, axis_rules, map_axes
from .optim import AdamWConfig, adamw_init, adamw_update


@dataclass(frozen=True)
class TrainConfig:
    grad_accum: int = 1
    optimizer: AdamWConfig = field(default_factory=AdamWConfig)
    # 'none' | 'int8' — cross-shard gradient all-reduce compression
    grad_compression: str = "none"
    # grad accumulation dtype (f32 default; bf16 halves the carry)
    accum_dtype: Any = jnp.float32


def param_shardings(model: Model, rules: ShardingRules):
    """NamedSharding tree for params (and f32 moments) under the rules."""
    axes = model.param_axes()
    shapes = model.param_shapes()

    def one(ax, shp):
        return NamedSharding(rules.mesh,
                             rules.spec(*ax, dims=shp.shape))
    return map_axes(one, axes, shapes)


def opt_shardings(model: Model, rules: ShardingRules):
    ps = param_shardings(model, rules)
    return {"m": ps, "v": ps,
            "step": NamedSharding(rules.mesh, P())}


def batch_shardings(rules: ShardingRules, batch_specs):
    """batch_specs: dict name -> (shape, logical axes)."""
    return {k: NamedSharding(rules.mesh, rules.spec(*ax, dims=shape))
            for k, (shape, ax) in batch_specs.items()}


def shard_params(model: Model, params, rules: ShardingRules):
    """Place an (unsharded host) param tree onto the mesh."""
    return jax.device_put(params, param_shardings(model, rules))


def make_train_step(model: Model, rules: ShardingRules,
                    tc: TrainConfig = TrainConfig()):
    """Build the (un-jitted) step; caller wraps in jax.jit with shardings."""

    def loss_fn(params, mb):
        with axis_rules(rules):
            return model.loss(params, mb)

    grad_fn = jax.value_and_grad(loss_fn)

    def constrain_grads(g):
        """Pin gradient leaves to the parameter sharding — without this the
        grad-accumulation carry is left to SPMD propagation, which keeps
        large (e.g. expert) gradient leaves replicated."""
        if rules is None or rules.mesh is None:
            return g
        axes = model.param_axes()

        def one(ax, leaf):
            return jax.lax.with_sharding_constraint(
                leaf, NamedSharding(rules.mesh,
                                    rules.spec(*ax, dims=leaf.shape)))
        return map_axes(one, axes, g)

    def train_step(params, opt_state, batch):
        if tc.grad_accum == 1:
            loss, grads = grad_fn(params, batch)
            grads = constrain_grads(grads)
        else:
            n = tc.grad_accum

            def split(x):
                b = x.shape[0]
                return x.reshape((n, b // n) + x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                loss_acc, g_acc = carry
                loss, g = grad_fn(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc,
                                     constrain_grads(g))
                return (loss_acc + loss, constrain_grads(g_acc)), None

            g0 = constrain_grads(jax.tree.map(
                lambda p: jnp.zeros(p.shape, tc.accum_dtype), params))
            (loss, grads), _ = lax.scan(acc_body, (jnp.zeros(()), g0), mbs)
            loss = loss / n
            grads = jax.tree.map(lambda g: g / n, grads)

        if tc.grad_compression == "int8":
            from repro.parallel.compression import simulate_int8_roundtrip
            grads = jax.tree.map(simulate_int8_roundtrip, grads)

        params2, opt2, metrics = adamw_update(
            tc.optimizer, params, grads, opt_state)
        metrics["loss"] = loss
        return params2, opt2, metrics

    return train_step


def jit_train_step(model: Model, rules: ShardingRules, tc: TrainConfig,
                   batch_specs):
    """jit with explicit in/out shardings — what the dry-run lowers."""
    step = make_train_step(model, rules, tc)
    ps = param_shardings(model, rules)
    os = opt_shardings(model, rules)
    bs = batch_shardings(rules, batch_specs)
    metr = NamedSharding(rules.mesh, P())
    return jax.jit(
        step,
        in_shardings=(ps, os, bs),
        out_shardings=(ps, os, {"loss": metr, "grad_norm": metr, "lr": metr}),
        donate_argnums=(0, 1),
    )
