"""Data pipeline: deterministic synthetic corpus + memmap-backed corpus.

Both sources yield host numpy batches; ``make_global_batch`` places them on
the mesh with the batch sharding (multi-host ready: each process would feed
its addressable shard — on this single-process container that degenerates to
one device_put).

The synthetic stream is Zipf-distributed tokens with a per-step PRNG keyed
on (seed, step) so restarts resume bit-identically (checkpoint/restart test
relies on this).
"""

from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ArchConfig, Shape


@dataclass
class DataConfig:
    seed: int = 0
    zipf_a: float = 1.2
    corpus_path: Optional[str] = None   # memmap .bin of uint16/uint32 tokens


def _tokens(rng: np.random.Generator, shape, vocab: int, a: float):
    z = rng.zipf(a, size=shape).astype(np.int64)
    return ((z - 1) % vocab).astype(np.int32)


def synthetic_batch(cfg: ArchConfig, shape: Shape, step: int,
                    dc: DataConfig = DataConfig()) -> Dict[str, np.ndarray]:
    """One deterministic host batch for (arch, shape, step)."""
    rng = np.random.default_rng((dc.seed, step))
    B, S = shape.global_batch, shape.seq_len
    if cfg.n_patches:
        S_text = S - cfg.n_patches
        toks = _tokens(rng, (B, S_text + 1), cfg.vocab, dc.zipf_a)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "patches": rng.standard_normal(
                (B, cfg.n_patches, cfg.d_model)).astype(np.float32),
        }
    if cfg.n_codebooks:
        toks = _tokens(rng, (B, S + 1, cfg.n_codebooks), cfg.vocab, dc.zipf_a)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    toks = _tokens(rng, (B, S + 1), cfg.vocab, dc.zipf_a)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class MemmapCorpus:
    """Flat token file (np.uint16/uint32) sampled in fixed windows."""

    def __init__(self, path: str, vocab: int, dtype=np.uint16):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.vocab = vocab

    def batch(self, B: int, S: int, step: int, seed: int = 0):
        rng = np.random.default_rng((seed, step))
        starts = rng.integers(0, len(self.data) - S - 1, size=B)
        toks = np.stack([self.data[s:s + S + 1] for s in starts]) \
            .astype(np.int32) % self.vocab
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def batch_iterator(cfg: ArchConfig, shape: Shape, dc: DataConfig,
                   start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    corpus = None
    if dc.corpus_path and os.path.exists(dc.corpus_path):
        corpus = MemmapCorpus(dc.corpus_path, cfg.vocab)
    step = start_step
    while True:
        if corpus is not None:
            yield corpus.batch(shape.global_batch, shape.seq_len, step,
                               dc.seed)
        else:
            yield synthetic_batch(cfg, shape, step, dc)
        step += 1


def make_global_batch(host_batch, shardings):
    """Place host arrays on the mesh (name -> NamedSharding)."""
    return {k: jax.device_put(v, shardings[k]) for k, v in host_batch.items()}
