"""AdamW with ZeRO-style sharded state.

States inherit the parameter's sharding (m/v are f32 regardless of the
parameter dtype — bf16 params train against f32 moments).  Global-norm
clipping is computed in f32.  Implemented directly (no optax dependency in
the container) as pure pytree transforms.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    t = jnp.clip((step - cfg.warmup) /
                 jnp.maximum(cfg.decay_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state
                 ) -> Tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9)) \
        if cfg.clip_norm else 1.0
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
