"""train — optimizer, step function, data pipeline, checkpointing."""

from .optim import AdamWConfig, adamw_init, adamw_update
from .step import TrainConfig, make_train_step, shard_params

__all__ = ["AdamWConfig", "adamw_init", "adamw_update",
           "TrainConfig", "make_train_step", "shard_params"]
