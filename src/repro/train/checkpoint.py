"""Checkpoint/restart with elastic resharding.

Checkpoints store *logical* (unsharded) arrays plus a JSON manifest; restore
takes a target sharding tree, so a run saved on one mesh restores onto any
other device count (elastic scaling).  Writes are atomic (tmp + rename) and
a retention policy prunes old steps.  ``latest_step`` enables auto-resume.

At real scale the npz container would be replaced by a per-shard
OCDBT/tensorstore layout — the save/restore *protocol* (manifest, logical
shapes, atomic publish, reshard-on-restore) is what this module pins down
and what the restart tests exercise.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3,
         extra: Optional[Dict] = None) -> str:
    """Atomically write checkpoint ``step``; prune to ``keep`` newest."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat}
    manifest = {
        "step": int(step),
        "keys": [k for k, _ in flat],
        "shapes": {k: list(a.shape) for k, a in arrays.items()},
        "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
        "extra": extra or {},
    }
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k.replace("/", "__"): a for k, a in arrays.items()})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.startswith(".tmp"):
            try:
                out.append(int(d[len("step_"):]))
            except ValueError:
                pass
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like, *, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: same-structure tree of NamedSharding
    for elastic placement onto the current mesh; None = host arrays."""
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    npz = np.load(os.path.join(path, "arrays.npz"))
    flat_like = _flatten(like)
    treedef = jax.tree_util.tree_structure(like)
    leaves = []
    for key, leaf in flat_like:
        a = npz[key.replace("/", "__")]
        want = tuple(leaf.shape)
        if tuple(a.shape) != want:
            raise ValueError(f"checkpoint leaf {key}: {a.shape} != {want}")
        leaves.append(a)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, manifest
