"""Always-on tuning for the LM serving stack: the daemon's LM binding.

``repro.api.daemon`` supplies the generic service (shape router, fleet
profile store, drift detector, background re-tunes); this module binds it
to the LM step-knob studies:

- request shapes are ``(arch, batch, bucketed seqlen)`` — the sequence
  bucket comes from ``repro.serve.engine.bucket_length``, the SAME
  function the engine pads prompts with, so the daemon tunes exactly the
  shapes the engine runs;
- shape keys live in the world-independent structural-key namespace
  (``shape_key``), the identity space the statistics bank already uses;
- a shape's study is ``LMStudy.session`` over ``StepKnobs`` (grad-accum x
  remat x chunking x MoE dispatch), warm-started from the fleet store —
  LM kernel signatures are position-independent and keyed by the knob
  subset that affects them, so shapes sharing a sequence bucket (or just
  an optimizer size) overlap and the second shape's study skips what the
  fleet already knows.

Lifecycle (see README "Serving with always-on tuning")::

    route -> warm-start -> serve -> drift -> re-tune

``ServingTuner`` is the engine-side facade (``serve_step`` /
``knobs_for``); ``run_daemon_demo`` drives simulated traffic through a
daemon against a reduced config — the runnable end-to-end path used by
``examples/serve_lm.py --daemon`` and ``scripts/check.sh --stage daemon``.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.signatures import comp_sig, structural_key
from repro.api.daemon import (DaemonConfig, FleetStore, TuningDaemon,
                              TUNED, TUNING, RETUNING)
from .engine import bucket_length


def shape_key(arch: str, batch: int, seq: int) -> str:
    """Study key of one (arch, batch, bucketed-seqlen) request shape, in
    the same world-independent structural-key namespace the statistics
    bank uses."""
    return structural_key(comp_sig("lm_shape", arch, int(batch), int(seq)),
                          1)


class VirtualClock:
    """Deterministic tick clock: every reading advances time by ``dt``,
    so a timed region spanning one thunk always measures exactly ``dt``.
    Simulated-traffic runs give each thread (serve loop, background
    tuner) its own instance; scaling ``dt`` mid-run injects a kernel-cost
    shift for drift-detection drills."""

    def __init__(self, dt: float = 1e-3):
        self.dt = dt
        self.now = 0.0

    def __call__(self) -> float:
        self.now += self.dt
        return self.now


class LMShapeProvider:
    """``TuningDaemon`` provider over per-shape ``LMStudy`` instances.

    Studies are cached per (arch, batch, seq) so serving reuses the
    study's compiled kernel closures; ``clock`` (optional) pins study
    timing to a deterministic source.  The fleet prior is handed to
    ``LMStudy.session`` undiscounted (``prior_discount=1.0``) — the fleet
    store's age decay is the trust mechanism."""

    def __init__(self, *, policy: str = "eager", tolerance: float = 0.25,
                 trials: int = 2, max_configs: Optional[int] = None,
                 seed: int = 0, clock=None, prior_discount: float = 1.0,
                 prior_max_cv: Optional[float] = None):
        self.policy = policy
        self.tolerance = tolerance
        self.trials = trials
        self.max_configs = max_configs
        self.seed = seed
        self.clock = clock
        self.prior_discount = prior_discount
        self.prior_max_cv = prior_max_cv
        self._studies: Dict[Tuple[str, int, int], object] = {}

    def study(self, meta: dict):
        skey = (meta["arch"], int(meta["batch"]), int(meta["seq"]))
        st = self._studies.get(skey)
        if st is None:
            from repro.tune.lm_study import LMStudy
            st = self._studies[skey] = LMStudy(
                skey[0], batch=skey[1], seq=skey[2], seed=self.seed)
        return st

    def point_for(self, meta: dict, name: str):
        for pt in self.study(meta).search_space(self.max_configs).points:
            if pt.name == name:
                return pt
        raise KeyError(f"no StepKnobs configuration named {name!r}")

    # -- TuningDaemon provider protocol --------------------------------------

    def session_for(self, key: str, meta: dict, prior):
        return self.study(meta).session(
            policy=self.policy, tolerance=self.tolerance,
            trials=self.trials, max_configs=self.max_configs,
            prior=prior, prior_discount=self.prior_discount,
            prior_max_cv=self.prior_max_cv, collect_stats=True,
            clock=self.clock, seed=self.seed)

    def kernels_for(self, key: str, meta: dict, winner_name: str):
        return self.study(meta).kernels_of(self.point_for(meta,
                                                          winner_name))

    def kernel_keys(self, key: str, meta: dict,
                    winner_name: str) -> List[str]:
        knobs = self.point_for(meta, winner_name).payload
        return sorted({structural_key(sig, 1) for sig, _, _
                       in self.study(meta).kernel_sequence(knobs)})


class ServingTuner:
    """The engine-side facade: route live (batch, seqlen) traffic into
    the always-on tuning daemon.

    ``serve_step`` runs one serving step for a request shape (pumping
    completed background studies first, so freshly landed winners swap in
    before routing); ``knobs_for`` resolves the shape's tuned
    ``StepKnobs`` (or None while the first study is still in flight) for
    the engine to apply."""

    def __init__(self, arch: str, *,
                 seq_buckets: Sequence[int] = (16, 32, 64, 128),
                 provider: Optional[LMShapeProvider] = None,
                 clock=time.time, config: Optional[DaemonConfig] = None,
                 fleet: Optional[FleetStore] = None,
                 checkpoint: Optional[str] = None,
                 executor_factory=None, **provider_kw):
        self.arch = arch
        self.seq_buckets = tuple(seq_buckets)
        self.provider = provider if provider is not None \
            else LMShapeProvider(**provider_kw)
        self.daemon = TuningDaemon(
            self.provider, clock=clock, config=config, fleet=fleet,
            checkpoint=checkpoint, executor_factory=executor_factory)

    def shape_of(self, batch: int, seqlen: int) -> Tuple[str, dict]:
        seq = bucket_length(int(seqlen), self.seq_buckets)
        meta = {"arch": self.arch, "batch": int(batch), "seq": seq}
        return shape_key(self.arch, batch, seq), meta

    def serve_step(self, batch: int, seqlen: int) -> dict:
        self.daemon.pump()
        key, meta = self.shape_of(batch, seqlen)
        return self.daemon.serve(key, meta)

    def knobs_for(self, batch: int, seqlen: int):
        key, meta = self.shape_of(batch, seqlen)
        winner = self.daemon.winners.get(key)
        if winner is None:
            return None
        return self.provider.point_for(meta, winner["name"]).payload

    def close(self, *, checkpoint: bool = True) -> None:
        self.daemon.close(checkpoint=checkpoint)


# ------------------------------------------------------- simulated traffic

def _pump_until(daemon: TuningDaemon, keys, *, timeout: float = 300.0,
                poll: float = 0.02) -> bool:
    """Pump until every key reaches TUNED (or the wait times out)."""
    deadline = time.monotonic() + timeout
    while True:
        daemon.pump()
        if all(daemon.state.get(k) == TUNED for k in keys):
            return True
        if time.monotonic() > deadline:
            return False
        time.sleep(poll)


def run_daemon_demo(arch: str = "smollm-135m", *,
                    shapes: Sequence[Tuple[int, int]] = ((2, 16), (2, 24),
                                                         (4, 16)),
                    seq_buckets: Sequence[int] = (16, 32),
                    rounds: int = 4, max_configs: int = 3, trials: int = 2,
                    shadow_every: int = 3, drift_scale: float = 5.0,
                    drift_rounds: int = 10, checkpoint: Optional[str] = None,
                    bank_path: Optional[str] = None,
                    synchronous: bool = False, dt: float = 1e-3,
                    log=None) -> dict:
    """Simulated live traffic through an always-on tuning daemon.

    Three phases over ``shapes`` (each a (batch, seqlen) pair) against
    the reduced ``arch`` config, on deterministic virtual clocks (one per
    thread, so background studies and the serve loop never perturb each
    other's timings):

    1. every shape's first occurrence opens a (fleet-warm-started) study
       in the background; the loop keeps serving until winners land;
    2. steady-state serving: tuned shapes run the winner's kernels
       through the shadow-mode selective timer — banked signatures
       execute zero times outside forced shadow samples;
    3. a kernel-cost shift (both clocks' ``dt`` scaled by
       ``drift_scale``) trips the drift detector; affected shapes
       re-tune in the background while the loop keeps serving, and the
       recovery lands in the daemon's event journal.

    Returns a JSON-able summary (counters, hit/miss ratios, per-phase
    serve infos, the journal).
    """
    say = log or (lambda *a: None)
    serve_clock = VirtualClock(dt)
    study_clock = VirtualClock(dt)
    provider = LMShapeProvider(trials=trials, max_configs=max_configs,
                               clock=study_clock)
    cfg = DaemonConfig(shadow_every=shadow_every, drift_z=3.0,
                       drift_min_samples=2, serve_min_samples=2,
                       synchronous=synchronous)
    tuner = ServingTuner(arch, seq_buckets=seq_buckets, provider=provider,
                         clock=serve_clock, config=cfg,
                         checkpoint=checkpoint)
    daemon = tuner.daemon
    keys = [tuner.shape_of(b, s)[0] for b, s in shapes]

    say(f"phase 1: routing {len(shapes)} shapes (studies open in "
        f"background)")
    for b, s in shapes:
        info = tuner.serve_step(b, s)
        say(f"  shape batch={b} seq={s}: {info['state']}")
        # let each study land before the next shape arrives, so later
        # shapes warm-start from the fleet knowledge earlier ones banked
        if not _pump_until(daemon, [tuner.shape_of(b, s)[0]]):
            raise RuntimeError(f"study for shape {(b, s)} did not land")

    say("phase 2: steady-state serving")
    tuned_serves: Dict[str, List[dict]] = {k: [] for k in keys}
    for _ in range(max(rounds, 2)):
        for b, s in shapes:
            info = tuner.serve_step(b, s)
            if info["winner"] is not None:
                tuned_serves[info["shape"]].append(
                    {k: info[k] for k in ("state", "winner", "executed",
                                          "forced", "cold_banked")})

    # snapshot before the drift drill: steady-state serving must re-run
    # zero banked kernels cold (drift *recovery* legitimately re-executes
    # banked kernels whose evidence went stale)
    steady = dict(daemon.counters)

    drifted = False
    served_while_retuning = 0
    if drift_scale and drift_scale != 1.0:
        say(f"phase 3: injecting {drift_scale}x kernel-cost shift")
        serve_clock.dt *= drift_scale
        study_clock.dt *= drift_scale
        for _ in range(drift_rounds):
            for b, s in shapes:
                info = tuner.serve_step(b, s)
                if info["state"] == RETUNING:
                    served_while_retuning += 1
            daemon.pump()
        drifted = daemon.counters["drifts"] > 0
        if not _pump_until(daemon, keys):
            raise RuntimeError("re-tunes did not settle")

    daemon.pump()
    second = {k: (v[1] if len(v) > 1 else None)
              for k, v in tuned_serves.items()}
    summary = {
        "arch": arch, "shapes": len(shapes),
        "counters": dict(daemon.counters),
        "steady_state_counters": steady,
        "ratios": daemon.ratios(),
        "second_tuned_serves": second,
        "served_while_retuning": served_while_retuning,
        "drift_detected": drifted,
        "retunes": daemon.counters["retunes"],
        "events": list(daemon.events),
    }
    if bank_path:
        daemon.fleet.save(bank_path)
        summary["bank_path"] = bank_path
        summary["bank_entries"] = len(daemon.fleet)
    tuner.close(checkpoint=checkpoint is not None)
    say(f"done: {summary['counters']}")
    return summary
