"""serve — KV-cache serving engine (prefill + decode, batched) and the
always-on tuning daemon binding (``repro.serve.tuner``)."""

from .engine import Engine, ServeConfig, bucket_length
from .tuner import (LMShapeProvider, ServingTuner, VirtualClock,
                    run_daemon_demo, shape_key)

__all__ = ["Engine", "LMShapeProvider", "ServeConfig", "ServingTuner",
           "VirtualClock", "bucket_length", "run_daemon_demo", "shape_key"]
