"""serve — KV-cache serving engine (prefill + decode, batched)."""

from .engine import ServeConfig, Engine

__all__ = ["ServeConfig", "Engine"]
