"""Batched serving engine.

Slot-based continuous batching over a fixed-capacity decode batch:

- requests enter a queue; free slots are filled by running ``prefill`` for
  the incoming prompt (right-padded to the slot's capacity) and splicing its
  cache into the batch cache at the slot index;
- one ``decode_step`` advances every active slot by a token;
- finished slots (eos or max tokens) are retired and refilled.

The decode step is jitted once per (batch capacity, s_max); prefill is
jitted per prompt-length bucket.  Sampling: greedy or temperature.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.model import Model, ModelKnobs
from repro.parallel.sharding import ShardingRules, axis_rules


def bucket_length(n: int, buckets: Sequence[int]) -> int:
    """Pad length ``n`` up to the smallest bucket that holds it (the last
    bucket when none does; ``n`` itself with no buckets).  THE bucketing
    function: the engine's prompt padding and the tuning daemon's shape
    keys both go through here, so a request can never be padded to one
    sequence length and tuned at another."""
    if not buckets:
        return n
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


@dataclass
class ServeConfig:
    batch_size: int = 8
    s_max: int = 512
    max_new_tokens: int = 64
    temperature: float = 0.0        # 0 = greedy
    eos_id: Optional[int] = None
    # () = jit per exact prompt length (keeps SSM states pad-free);
    # nonempty = pad prompts up to bucket sizes (attention-only archs)
    prompt_buckets: Sequence[int] = ()
    seed: int = 0


@dataclass
class Request:
    uid: int
    tokens: np.ndarray              # (S_prompt,) prompt token ids
    max_new_tokens: Optional[int] = None


@dataclass
class Result:
    uid: int
    tokens: List[int] = field(default_factory=list)


class Engine:
    """Single-host engine; rules=None runs unsharded (CPU smoke scale)."""

    def __init__(self, model: Model, params, sc: ServeConfig,
                 rules: Optional[ShardingRules] = None):
        self.model = model
        self.params = params
        self.sc = sc
        self.rules = rules
        self.cfg = model.cfg
        B, S = sc.batch_size, sc.s_max
        with axis_rules(rules):
            self.cache = model.init_cache(B, S)
        self.lengths = np.zeros(B, np.int64)         # per-slot position
        self.budget = np.zeros(B, np.int64)
        self.active = np.zeros(B, bool)
        self.slot_uid = np.full(B, -1, np.int64)
        self.results: Dict[int, Result] = {}
        self.queue: List[Request] = []
        self.last_token = np.zeros((B,) + self._tok_trailing(), np.int32)
        self._rng = np.random.default_rng(sc.seed)
        self._decode = jax.jit(self._decode_fn)
        self._prefill_cache: Dict[int, Any] = {}

    def _tok_trailing(self):
        return (self.cfg.n_codebooks,) if self.cfg.n_codebooks else ()

    # -- jitted closures -------------------------------------------------------

    def _decode_fn(self, params, cache, t_per_slot, tokens):
        """t_per_slot: (B,) int32 current positions (ragged batch)."""
        with axis_rules(self.rules):
            # ragged positions: mask via per-slot t in attention
            # (decode_step takes scalar t; we pass max and mask by position)
            logits, cache = self.model.decode_step(
                params, cache, t_per_slot, {"tokens": tokens[:, None]})
        return logits, cache

    def _prefill_fn(self, params, batch, s_max, logits_at):
        with axis_rules(self.rules):
            return self.model.prefill(params, batch, s_max,
                                      logits_at=logits_at)

    # -- public API -------------------------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)
        self.results[req.uid] = Result(req.uid)

    def _bucket(self, n):
        return bucket_length(n, self.sc.prompt_buckets)

    def _admit(self):
        """Fill free slots from the queue (prefill + cache splice)."""
        for slot in np.nonzero(~self.active)[0]:
            if not self.queue:
                break
            req = self.queue.pop(0)
            S_p = self._bucket(len(req.tokens))
            toks = np.zeros((1, S_p) + self._tok_trailing(), np.int32)
            toks[0, :len(req.tokens)] = req.tokens
            fn = self._prefill_cache.get(S_p)
            if fn is None:
                fn = jax.jit(lambda p, b, at: self._prefill_fn(
                    p, b, self.sc.s_max, at))
                self._prefill_cache[S_p] = fn
            at = jnp.asarray([len(req.tokens) - 1], jnp.int32)
            logits, cache1, _ = fn(self.params,
                                   {"tokens": jnp.asarray(toks)}, at)
            # splice the single-request cache into slot `slot`
            self.cache = jax.tree.map(
                lambda big, one: jax.lax.dynamic_update_slice_in_dim(
                    big, one.astype(big.dtype), int(slot), axis=1),
                self.cache, cache1)
            tok0 = self._sample(np.asarray(logits)[0])
            self.last_token[slot] = tok0
            self.lengths[slot] = len(req.tokens)
            # the prefill-sampled token is the first generated token
            self.budget[slot] = (req.max_new_tokens
                                 or self.sc.max_new_tokens) - 1
            self.active[slot] = True
            self.slot_uid[slot] = req.uid
            self.results[req.uid].tokens.append(int(np.ravel(tok0)[0])
                                                if not self.cfg.n_codebooks
                                                else list(map(int, tok0)))

    def _sample(self, logits):
        if self.sc.temperature <= 0:
            return np.argmax(logits, axis=-1).astype(np.int32)
        z = logits / self.sc.temperature
        z = z - z.max(-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(-1, keepdims=True)
        flat = p.reshape(-1, p.shape[-1])
        out = np.array([self._rng.choice(len(q), p=q) for q in flat],
                       np.int32)
        return out.reshape(p.shape[:-1])

    def step(self) -> int:
        """Admit + one decode step for all active slots; returns #active."""
        self._admit()
        if not self.active.any():
            return 0
        t = jnp.asarray(self.lengths.astype(np.int32))
        logits, self.cache = self._decode(
            self.params, self.cache, t, jnp.asarray(self.last_token))
        logits = np.asarray(logits)
        for slot in np.nonzero(self.active)[0]:
            nxt = self._sample(logits[slot])
            self.last_token[slot] = nxt
            self.lengths[slot] += 1
            self.budget[slot] -= 1
            uid = int(self.slot_uid[slot])
            val = (int(np.ravel(nxt)[0]) if not self.cfg.n_codebooks
                   else list(map(int, nxt)))
            self.results[uid].tokens.append(val)
            eos = (self.sc.eos_id is not None
                   and not self.cfg.n_codebooks and val == self.sc.eos_id)
            if eos or self.budget[slot] <= 0 \
                    or self.lengths[slot] >= self.sc.s_max - 1:
                self.active[slot] = False
                self.slot_uid[slot] = -1
        return int(self.active.sum())

    def run(self) -> Dict[int, Result]:
        while self.queue or self.active.any():
            self.step()
        return self.results
