"""tune — the paper's approximate-autotuning technique applied to the JAX
LM framework itself.

Two scales:
- ``lm_study`` (laptop, measured): step functions of reduced architectures
  are decomposed into recurring *kernels* (block forward/backward closures
  with concrete input shapes); ``selective.SelectiveTimer`` applies the
  paper's confidence-interval machinery to real wall-clock samples, skipping
  kernels once predictable.  Configurations share kernel signatures, so
  eager-style model reuse across configurations transfers exactly as in the
  paper's Capital study.
- ``dryrun_search`` (production mesh, modeled): configurations are ranked
  by the three-term roofline of their compiled dry-run — the search loop
  used for the §Perf hillclimb.

Both are front-ended by ``repro.api.AutotuneSession`` (``WallClockBackend``
wraps ``SelectiveTimer`` over ``LMStudy.kernels_of``; ``DryRunBackend``
wraps ``dryrun_search.evaluate_point``) — prefer the session API.
"""

from .selective import SelectiveTimer, TimerReport
from .lm_study import LMStudy, lm_config_space
from .dryrun_search import dryrun_search

__all__ = ["SelectiveTimer", "TimerReport", "LMStudy", "lm_config_space",
           "dryrun_search"]
