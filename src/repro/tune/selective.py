"""Selective wall-clock kernel timing — the paper's §III.A machinery over
real jitted-closure executions (no virtual machine).

This is the measurement substrate of ``repro.api.WallClockBackend``; the
supported way to drive it is ``repro.api.AutotuneSession`` (see the
top-level README), which owns the per-configuration protocol, sweeps and
checkpointing.  Direct ``SelectiveTimer`` use remains for single-kernel
call sites (e.g. the serving engine's step timer).

All kernels here are computation kernels (one process, XLA dispatch), so
the propagation policies collapse to how execution *counts* are used:

- ``conditional``: plain CI, one execution per kernel per iteration;
- ``local``/``online``: CI shrunk by sqrt(freq) of the kernel's per-step
  count (identical single-process; kept as separate names for reporting
  parity with the paper);
- ``eager``: a kernel switches off permanently (across configurations)
  the first time its CI meets the tolerance — the cross-configuration
  model reuse of the paper's Capital study.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.policies import Policy
from repro.core.signatures import Signature
from repro.core.stats import KernelStats


@dataclass
class TimerReport:
    predicted_time: float
    measured_time: float
    executed: int
    skipped: int


class SelectiveTimer:
    """Owns kernel statistics across tuning iterations (one per policy).

    ``prior_lookup`` (cross-study transfer, ``repro.api.transfer``) maps a
    ``Signature`` to a transferred ``KernelStats`` or ``None``; it is
    consulted lazily the first time each kernel appears — and again after
    every ``reset_models`` — so warm-started kernels carry a tight CI
    before their first timed execution, and an eager session switches an
    already-confident kernel off outright.
    """

    def __init__(self, policy: Policy, clock: Callable[[], float] = None,
                 prior_lookup: Optional[Callable[[Signature],
                                                 Optional[KernelStats]]]
                 = None):
        self.policy = policy
        self.kbar: Dict[Signature, KernelStats] = {}
        self.global_off: set = set()
        self.clock = clock or time.perf_counter
        self.prior_lookup = prior_lookup
        self._iter_executed: set = set()
        self._pred = 0.0
        self._meas = 0.0
        self._nexec = 0
        self._nskip = 0

    def reset_models(self):
        self.kbar.clear()
        self.global_off.clear()

    def _stats(self, sig: Signature) -> KernelStats:
        st = self.kbar.get(sig)
        if st is None:
            st = self.prior_lookup(sig) if self.prior_lookup else None
            if st is None:
                st = KernelStats()
            elif self.policy.persistent_models and st.n > 0 \
                    and st.is_predictable(self.policy.tolerance, 1,
                                          self.policy.min_samples):
                self.global_off.add(sig)
            self.kbar[sig] = st
        return st

    def begin_iteration(self):
        self._iter_executed = set()
        self._pred = self._meas = 0.0
        self._nexec = self._nskip = 0

    def _should_execute(self, sig: Signature, freq: int) -> bool:
        if sig in self.global_off:
            return False
        if self.policy.once_per_iteration and sig not in self._iter_executed:
            return True
        st = self.kbar.get(sig)
        if st is None:
            return True
        f = freq if self.policy.uses_counts else 1
        return not st.is_predictable(self.policy.tolerance, f,
                                     self.policy.min_samples)

    def time_kernel(self, sig: Signature, thunk: Callable[[], None],
                    freq: int = 1, *, force: bool = False) -> float:
        """Run (or skip) one kernel occurrence; returns the time charged to
        the configuration's predicted cost.  ``freq`` is the kernel's
        occurrence count along the step (the paper's alpha).

        ``force=True`` executes and measures even a confident (or globally
        switched-off) kernel — shadow mode: the serving daemon's drift
        detector periodically forces a real sample so live evidence keeps
        flowing after the skip regime is reached."""
        st = self._stats(sig)
        if force or self._should_execute(sig, freq):
            t0 = self.clock()
            thunk()
            t = self.clock() - t0
            st.update(t)
            self._iter_executed.add(sig)
            self._nexec += 1
            self._meas += t
            charged = t
            if self.policy.persistent_models and st.is_predictable(
                    self.policy.tolerance, 1, self.policy.min_samples):
                self.global_off.add(sig)
        else:
            charged = st.mean
            self._nskip += 1
        self._pred += charged
        return charged

    def report(self) -> TimerReport:
        return TimerReport(self._pred, self._meas, self._nexec, self._nskip)
