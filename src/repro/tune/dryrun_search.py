"""Production-scale configuration search over dry-run rooflines.

Enumerates (sharding variant x grad_accum x remat x chunk) points for one
(arch x shape) cell, lowers each on the production mesh, scores by the
dominant roofline term, and returns the ranked table.  This is the §Perf
hillclimb's inner loop — each evaluation is a compile, so the search space
is kept small and every result is cached to disk.
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp

from repro.launch.cells import DRYRUN_KNOBS, build_cell, model_flops
from repro.launch.hlo_analysis import (collective_stats, cpu_upcast_bytes,
                                       roofline_terms)
from repro.launch.hlo_graph import collective_stats_trip_aware
from repro.launch.jaxpr_cost import cost_of
from repro.launch.mesh import make_production_mesh
from repro.models.model import ModelKnobs
from repro.train.step import TrainConfig


@dataclass
class SearchPoint:
    name: str
    variant: str = "cp"
    grad_accum: int = 4
    remat: str = "full"
    kv_chunk: int = 512
    ssm_chunk: int = 256
    moe_dispatch: str = "a2a"
    scan_unroll: int = 1
    accum_dtype: str = "float32"

    def knobs(self) -> ModelKnobs:
        return replace(DRYRUN_KNOBS, kv_chunk=self.kv_chunk,
                       ssm_chunk=self.ssm_chunk, remat=self.remat,
                       moe_dispatch=self.moe_dispatch,
                       scan_unroll=self.scan_unroll)

    def tc(self) -> TrainConfig:
        return TrainConfig(grad_accum=self.grad_accum,
                           accum_dtype=getattr(jnp, self.accum_dtype))


def evaluate_point(arch: str, shape: str, pt: SearchPoint, *,
                   multi_pod: bool = False,
                   cache_dir: Optional[str] = None) -> Dict:
    tag = f"{arch}_{shape}_{pt.name}_{'multi' if multi_pod else 'single'}"
    if cache_dir:
        path = os.path.join(cache_dir, tag + ".json")
        if os.path.exists(path):
            with open(path) as f:
                return json.load(f)
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = build_cell(arch, shape, mesh, variant=pt.variant,
                      knobs=pt.knobs(), tc=pt.tc())
    t0 = time.time()
    compiled = cell.lower().compile()
    compile_s = time.time() - t0
    hlo = compiled.as_text()
    mem = compiled.memory_analysis()
    jc = cost_of(cell.fn, *cell.args)
    coll = collective_stats_trip_aware(hlo)
    n = mesh.devices.size
    terms = roofline_terms(jc.flops / n, jc.bytes / n, coll.total_bytes)
    live = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes - cpu_upcast_bytes(hlo))
    rec = {
        "tag": tag, "arch": arch, "shape": shape,
        "point": pt.__dict__, "compile_s": round(compile_s, 1),
        "roofline": terms,
        "live_bytes": int(live), "fits": bool(live <= 16 * (1 << 30)),
        "collective_by_kind": coll.bytes_by_kind,
    }
    if cache_dir:
        os.makedirs(cache_dir, exist_ok=True)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def dryrun_search(arch: str, shape: str, points: Sequence[SearchPoint], *,
                  multi_pod: bool = False, cache_dir: Optional[str] = None,
                  require_fit: bool = True) -> List[Dict]:
    """Evaluate all points, return records sorted by roofline step time
    (unfitting points sorted last)."""
    recs = []
    for pt in points:
        try:
            recs.append(evaluate_point(arch, shape, pt,
                                       multi_pod=multi_pod,
                                       cache_dir=cache_dir))
        except Exception as e:  # lowering failures are real search results
            recs.append({"tag": f"{arch}_{shape}_{pt.name}",
                         "point": pt.__dict__, "error": repr(e)})
    def key(r):
        if "error" in r:
            return (2, float("inf"))
        bad = require_fit and not r["fits"]
        return (1 if bad else 0, r["roofline"]["step_s"])
    return sorted(recs, key=key)
