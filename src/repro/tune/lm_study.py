"""Measured-mode LM autotuning study (the paper's technique on our own
framework, real wall-clock, reduced architectures on CPU).

A *configuration* is a ``StepKnobs`` point (grad accumulation x remat x
attention/ssm chunking x MoE dispatch).  A configuration's step is
decomposed into recurring kernels:

    embed+loss closure        once per microbatch
    <mixer kind> fwd+bwd      n_periods x period-positions x microbatches
    <ffn kind>  fwd+bwd       likewise
    optimizer update          once per step

Each kernel is a jitted closure keyed by a ``Signature`` carrying the knob
subset that affects it — so configurations SHARE kernels exactly when the
paper's theory says they should (e.g. changing MoE dispatch leaves every
attention kernel's signature intact).  ``SelectiveTimer`` then applies the
confidence-interval skipping; per-step occurrence counts feed the sqrt(k)
CI shrink.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ArchConfig
from repro.core.signatures import Signature, comp_sig
from repro.models import layers as ML
from repro.models import moe as MM
from repro.models import ssm as MS
from repro.models.model import Model, ModelKnobs, init_params
from .selective import SelectiveTimer


@dataclass(frozen=True)
class StepKnobs:
    name: str
    grad_accum: int = 1
    remat: str = "none"          # 'none' | 'full'
    kv_chunk: int = 32
    ssm_chunk: int = 16
    moe_dispatch: str = "sort"   # 'sort' | 'dense'


def lm_config_space(cfg: ArchConfig) -> List[StepKnobs]:
    accums = (1, 2, 4)
    remats = ("none", "full")
    kvs = (16, 64)
    moes = ("sort", "dense") if cfg.moe else ("sort",)
    ssms = (8, 32) if any(k in ("mamba", "mlstm", "slstm")
                          for k in cfg.pattern) else (16,)
    out = []
    for ga, rm, kv, md, sc in itertools.product(accums, remats, kvs, moes,
                                                ssms):
        out.append(StepKnobs(
            name=f"ga{ga}-{rm}-kv{kv}-{md}-ssm{sc}",
            grad_accum=ga, remat=rm, kv_chunk=kv, ssm_chunk=sc,
            moe_dispatch=md))
    return out


def _block_params(model: Model, params, pos: int, period: int):
    """Slice one period's params for one position (concrete arrays)."""
    per = params[f"pos{pos}"]
    return jax.tree.map(lambda a: a[period], per)


class LMStudy:
    """Benchmarks StepKnobs configurations for one reduced arch."""

    def __init__(self, arch: str, *, batch: int = 2, seq: int = 32,
                 seed: int = 0):
        self.cfg = get_config(arch, reduced=True)
        self.batch, self.seq = batch, seq
        key = jax.random.PRNGKey(seed)
        self.params = init_params(self.cfg, key)
        tshape = ((batch, seq, self.cfg.n_codebooks) if self.cfg.n_codebooks
                  else (batch, seq))
        k1, k2, k3 = jax.random.split(key, 3)
        self.batch_data = {
            "tokens": jax.random.randint(k1, tshape, 0, self.cfg.vocab),
            "labels": jax.random.randint(k2, tshape, 0, self.cfg.vocab),
        }
        if self.cfg.n_patches:
            self.batch_data["patches"] = jax.random.normal(
                k3, (batch, self.cfg.n_patches, self.cfg.d_model))
        self._fns: Dict[Signature, callable] = {}
        self._args: Dict[Signature, tuple] = {}

    # -- kernel construction ---------------------------------------------------

    def _kernel(self, sig: Signature, build):
        """Get-or-build the jitted closure + concrete args for a signature;
        compile (first call) happens outside the timed region."""
        if sig not in self._fns:
            fn, args = build()
            jax.block_until_ready(fn(*args))   # compile outside timed region
            self._fns[sig] = fn
            self._args[sig] = args
        return self._fns[sig], self._args[sig]

    def _mixer_kernel(self, kind: str, pos: int, knobs: StepKnobs, mb: int):
        cfg = self.cfg
        S = self.seq
        sig = comp_sig(f"{kind}_fb", mb, S, cfg.d_model, knobs.kv_chunk
                       if kind in ("attn", "mla") else knobs.ssm_chunk,
                       knobs.remat)

        def build():
            p = _block_params(Model(cfg), self.params, pos, 0)
            mix = {k[len("mix_"):]: v for k, v in p.items()
                   if k.startswith("mix_")}
            x = jax.random.normal(jax.random.PRNGKey(pos),
                                  (mb, S, cfg.d_model))
            positions = jnp.arange(S)

            def fwd(mix, x):
                if kind == "attn":
                    h, _ = ML.attn_block(mix, x, cfg, positions=positions,
                                         kv_chunk=knobs.kv_chunk)
                elif kind == "mla":
                    h, _ = ML.mla_block(mix, x, cfg, positions=positions,
                                        kv_chunk=knobs.kv_chunk)
                elif kind == "mamba":
                    h, _ = MS.mamba_block(mix, x, cfg, chunk=knobs.ssm_chunk)
                elif kind == "mlstm":
                    h, _ = MS.mlstm_block(mix, x, cfg, chunk=knobs.ssm_chunk)
                else:
                    h, _ = MS.slstm_block(mix, x, cfg, chunk=knobs.ssm_chunk)
                return jnp.sum(h * h)
            if knobs.remat == "full":
                fwd = jax.checkpoint(fwd)
            fn = jax.jit(jax.grad(fwd))
            return (lambda m, xx: jax.block_until_ready(fn(m, xx))), (mix, x)
        return sig, build

    def _ffn_kernel(self, fk: str, pos: int, knobs: StepKnobs, mb: int):
        cfg = self.cfg
        S = self.seq
        extra = knobs.moe_dispatch if fk == "moe" else "-"
        sig = comp_sig(f"{fk}_fb", mb, S, cfg.d_model, extra, knobs.remat)

        def build():
            p = _block_params(Model(cfg), self.params, pos, 0)
            ffn = {k[len("ffn_"):]: v for k, v in p.items()
                   if k.startswith("ffn_")}
            x = jax.random.normal(jax.random.PRNGKey(100 + pos),
                                  (mb, S, cfg.d_model))

            def fwd(ffn, x):
                if fk == "dense":
                    h = ML.ffn_block(ffn, x, cfg)
                else:
                    h = MM.moe_ffn(ffn, x, cfg,
                                   dispatch=knobs.moe_dispatch)
                return jnp.sum(h * h)
            if knobs.remat == "full":
                fwd = jax.checkpoint(fwd)
            fn = jax.jit(jax.grad(fwd))
            return (lambda m, xx: jax.block_until_ready(fn(m, xx))), (ffn, x)
        return sig, build

    def _embed_loss_kernel(self, knobs: StepKnobs, mb: int):
        cfg = self.cfg
        sig = comp_sig("embed_loss_fb", mb, self.seq, cfg.vocab)

        def build():
            model = Model(cfg, ModelKnobs(kv_chunk=knobs.kv_chunk,
                                          ssm_chunk=knobs.ssm_chunk))
            data = jax.tree.map(lambda a: a[:mb], self.batch_data)

            def fwd(params):
                x = model._embed(params, data)
                x = ML.rms_norm(x, params["final"]["ln"], cfg.norm_eps)
                logits = model._head(params, x)
                return jnp.mean(logits.astype(jnp.float32) ** 2)
            fn = jax.jit(jax.grad(fwd))
            sub = {"embed": self.params["embed"],
                   "final": self.params["final"]}
            if "head" in self.params:
                sub["head"] = self.params["head"]
            return (lambda p: jax.block_until_ready(fn(p))), (sub,)
        return sig, build

    def _opt_kernel(self):
        sig = comp_sig("adamw", sum(
            int(np.prod(l.shape)) for l in jax.tree.leaves(self.params)))

        def build():
            from repro.train.optim import AdamWConfig, adamw_init, \
                adamw_update
            oc = AdamWConfig()
            st = adamw_init(self.params)
            g = jax.tree.map(jnp.ones_like, self.params)
            fn = jax.jit(lambda p, gg, s: adamw_update(oc, p, gg, s))
            return (lambda p, gg, s: jax.block_until_ready(fn(p, gg, s))), \
                (self.params, g, st)
        return sig, build

    # -- one configuration benchmark --------------------------------------------

    def kernel_sequence(self, knobs: StepKnobs):
        """The step's kernel occurrence list: (sig, build, freq)."""
        cfg = self.cfg
        mb = max(self.batch // knobs.grad_accum, 1)
        seq = []
        counts: Dict[Signature, int] = {}
        per_step = []
        for pos, (kind, fk) in enumerate(zip(cfg.pattern, cfg.ffn_pattern)):
            per_step.append(self._mixer_kernel(kind, pos, knobs, mb))
            if fk != "none":
                per_step.append(self._ffn_kernel(fk, pos, knobs, mb))
        items = []
        for _ in range(knobs.grad_accum):
            for _ in range(cfg.n_periods):
                items.extend(per_step)
            items.append(self._embed_loss_kernel(knobs, mb))
        items.append(self._opt_kernel())
        for sig, _ in items:
            counts[sig] = counts.get(sig, 0) + 1
        return [(sig, build, counts[sig]) for sig, build in items]

    # -- session-API adapters ----------------------------------------------------

    def kernels_of(self, point):
        """``WallClockBackend`` provider: resolve a ``ConfigPoint`` (or a
        bare ``StepKnobs``) to the step's bound kernel occurrence list
        ``[(Signature, thunk, freq)]``; compilation happens here, outside
        any timed region."""
        knobs = getattr(point, "payload", point) or point
        out = []
        for sig, build, freq in self.kernel_sequence(knobs):
            fn, args = self._kernel(sig, build)
            out.append((sig,
                        (lambda fn=fn, args=args: fn(*args)), freq))
        return out

    @staticmethod
    def stats_bank(*results):
        """Merge the kernel-statistics banks of completed LM study results
        (``AutotuneSession(..., collect_stats=True)``) into one transfer
        prior.  LM kernels are keyed by the knob subset that affects them,
        so a bank recorded on one StepKnobs subspace (or another arch
        sharing block shapes) warm-starts exactly the kernels the paper's
        theory says it should: pass the merged bank back as
        ``AutotuneSession(..., prior=bank)``."""
        from repro.api.transfer import StatisticsBank
        bank = StatisticsBank()
        for r in results:
            b = r.stats_bank() if hasattr(r, "stats_bank") else r
            if b:
                bank = bank.merge(b)
        return bank

    def session(self, *, policy: str = "conditional",
                tolerance: float = 0.25, search: str = "exhaustive",
                max_configs: Optional[int] = None, trials: int = 3,
                prior=None, clock=None, **kw):
        """The supported front-end over this study: an ``AutotuneSession``
        measuring StepKnobs points with ``WallClockBackend`` bound to
        ``kernels_of``.  Sweeps run through ``repro.api.scheduler`` like
        every other study (serially — wall-clock backends are not
        ``parallel_safe``); ``search="racing"`` races configurations by
        real wall clock (see ``race``).  ``clock`` overrides the backend's
        time source (deterministic tests, daemon parity checks)."""
        from repro.api import AutotuneSession, WallClockBackend
        return AutotuneSession(self.search_space(max_configs),
                               backend=WallClockBackend(self.kernels_of,
                                                        clock=clock),
                               policy=policy, tolerance=tolerance,
                               search=search, trials=trials, prior=prior,
                               **kw)

    def race(self, *, policy: str = "conditional", tolerance: float = 0.25,
             max_configs: Optional[int] = None, max_rounds: int = 6,
             prior=None, **kw):
        """Wall-clock racing study: successive elimination over the
        StepKnobs space driven by the paper's per-kernel CIs on real
        measured step times — each round gives every surviving
        configuration one selective trial and prunes configurations whose
        CI lower bound exceeds the incumbent's upper bound.  Returns the
        ``StudyResult`` (winner in ``extra["best"]``); far cheaper than
        the exhaustive protocol when only the optimum is wanted, because
        losing configurations stop being timed at all."""
        return self.session(policy=policy, tolerance=tolerance,
                            search="racing", max_configs=max_configs,
                            search_options={"max_rounds": max_rounds},
                            prior=prior, **kw).run()

    def search_space(self, max_configs: Optional[int] = None):
        """The session-API view of this study's StepKnobs space.  Resets
        follow the policy (eager's persistent models skip the reset), the
        convention of the measured LM benchmarks."""
        from repro.api.space import RESET_POLICY, ConfigPoint, SearchSpace
        pts = [ConfigPoint(name=kn.name, params={
                   "grad_accum": kn.grad_accum, "remat": kn.remat,
                   "kv_chunk": kn.kv_chunk, "ssm_chunk": kn.ssm_chunk,
                   "moe_dispatch": kn.moe_dispatch}, payload=kn)
               for kn in lm_config_space(self.cfg)]
        if max_configs is not None:
            pts = pts[:max_configs]
        return SearchSpace(name=f"lm-{self.cfg.name}", points=pts,
                           reset_between_configs=RESET_POLICY)

    def run_config(self, knobs: StepKnobs, timer: SelectiveTimer,
                   *, iters: int = 3):
        """Selective benchmark of one configuration; returns
        (predicted step time, full-execution reference time, cost)."""
        seqn = self.kernel_sequence(knobs)
        # full execution directly prior (reference; not fed to models)
        full = 0.0
        for sig, build, freq in seqn:
            fn, args = self._kernel(sig, build)
            t0 = timer.clock()
            fn(*args)
            full += timer.clock() - t0
        cost = 0.0
        pred = None
        for _ in range(iters):
            timer.begin_iteration()
            for sig, build, freq in seqn:
                fn, args = self._kernel(sig, build)
                timer.time_kernel(sig, lambda: fn(*args), freq)
            rep = timer.report()
            cost += rep.measured_time
            pred = rep.predicted_time
        return pred, full, cost
