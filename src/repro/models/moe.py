"""Mixture-of-experts FFN: expert-parallel all-to-all dispatch (default),
sort-based local dispatch, and a dense decoy.

The dispatch implementation is a *tuning parameter* of the step function
(DESIGN.md §7: the dispatch alternative is the configuration knob most
representative of the paper's competing-analytic-costs setting):

- ``a2a``   (default under a mesh): shard_map expert parallelism.  Three
  regimes picked from the active sharding rules:
    * tokens sharded over the expert axis  -> ring all_to_all dispatch
      (tokens travel to their experts' shard, GShard/Switch EP);
    * tokens replicated over the expert axis -> masked local experts +
      psum combine (decode-friendly EP);
    * experts unsharded -> purely local sort dispatch per token shard.
  Expert weights FSDP-sharded over token axes are all-gathered per layer
  inside the body (ZeRO-3 semantics) and re-gathered in backward.
  Falls back to ``sort`` when no mesh/rules are active (CPU smoke tests).
- ``sort``: global-program argsort/capacity dispatch.  Correct everywhere,
  but under SPMD its data-dependent gather/scatter replicates — kept as the
  naive baseline arm the autotuner must learn to reject.
- ``dense``: every expert on every token (tiny configs only).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.parallel.sharding import annotate, current_rules


def router_topk(logits, k: int, *, renormalize: bool = True):
    """logits (T, E) f32 -> (gates (T,k), idx (T,k))."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = lax.top_k(probs, k)
    if renormalize:
        gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    return gates, idx


def _expert_ffn_local(p, x):
    """x: (E, C, D) -> (E, C, D) per-expert gated MLP; no constraints
    (usable inside shard_map manual regions)."""
    g = jnp.einsum("ecd,edf->ecf", x, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", x, p["w_up"])
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w_down"])


def _expert_ffn(p, x):
    """Global-program variant with logical-axis constraints."""
    g = jnp.einsum("ecd,edf->ecf", x, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", x, p["w_up"])
    g = annotate(g, "expert", "exp_cap", "ffn")
    u = annotate(u, "expert", "exp_cap", "ffn")
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w_down"])


def moe_ffn(p, x, cfg, *, dispatch: str = "a2a"):
    """x: (B, S, D) -> (B, S, D).  p holds router (D,E), expert stacks
    (E,D,F)/(E,F,D), and optionally shared-expert dense weights."""
    moe = cfg.moe
    B, S, D = x.shape
    T = B * S
    xf = x.reshape(T, D)
    xf = annotate(xf, "tokens", "embed")

    if dispatch == "a2a":
        rules = current_rules()
        if rules is None or rules.mesh is None:
            dispatch = "sort"
        else:
            y = _ep_dispatch(p, xf, moe, rules)
            dispatch = None

    if dispatch is not None:
        logits = jnp.einsum("td,de->te", xf, p["router"]) \
            .astype(jnp.float32)
        gates, idx = router_topk(logits, moe.top_k)
        gates = gates.astype(x.dtype)
        if dispatch == "dense":
            h = _expert_ffn({k: p[k] for k in ("w_gate", "w_up", "w_down")},
                            jnp.broadcast_to(xf[None],
                                             (moe.n_experts, T, D)))
            gate_mat = jnp.zeros((T, moe.n_experts), x.dtype)
            gate_mat = gate_mat.at[jnp.arange(T)[:, None], idx].add(gates)
            y = jnp.einsum("etd,te->td", h, gate_mat)
        elif dispatch == "sort":
            y = _sort_dispatch(p, xf, gates, idx, moe)
        else:
            raise ValueError(f"unknown moe dispatch {dispatch!r}")

    if moe.n_shared:
        sh = {"ln": None, "w_gate": p["sh_gate"], "w_up": p["sh_up"],
              "w_down": p["sh_down"]}
        g = jnp.einsum("td,df->tf", xf, sh["w_gate"])
        u = jnp.einsum("td,df->tf", xf, sh["w_up"])
        y = y + jnp.einsum("tf,fd->td", jax.nn.silu(g) * u, sh["w_down"])
    y = annotate(y, "tokens", "embed")
    return y.reshape(B, S, D)


# ---------------------------------------------------------------------------
# expert-parallel shard_map dispatch
# ---------------------------------------------------------------------------

def _axes_tuple(v):
    if v is None:
        return ()
    return (v,) if isinstance(v, str) else tuple(v)


def _gather_weight(w, spec_axes, skip_axis):
    """all-gather weight dims FSDP-sharded over mapped axes (ZeRO-3).
    Minor axis first: a dim sharded (major, minor) reconstructs contiguously
    only when gathered minor-to-major."""
    for dim, axs in enumerate(spec_axes):
        for ax in reversed(_axes_tuple(axs)):
            if ax and ax != skip_axis:
                w = lax.all_gather(w, ax, axis=dim, tiled=True)
    return w


def _capacity(t_loc: int, k: int, n_exp: int, cf: float) -> int:
    c = int(math.ceil(t_loc * k / n_exp * cf))
    return max(8 * ((c + 7) // 8), 8)


def _local_pack(xl, gates, idx, n_exp, cap):
    """Sort local tokens into an (n_exp, cap, D) buffer.

    Returns (buffer, slot (T_loc*k,), src_token (T_loc*k,), gate, keep)."""
    t_loc, d = xl.shape
    k = idx.shape[-1]
    tk = t_loc * k
    fidx = idx.reshape(tk)
    fgate = gates.reshape(tk)
    ftok = jnp.arange(tk, dtype=jnp.int32) // k
    order = jnp.argsort(fidx)
    se, st, sg = fidx[order], ftok[order], fgate[order]
    counts = jnp.bincount(fidx, length=n_exp)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(tk, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep = pos < cap
    slot = jnp.where(keep, se.astype(jnp.int32) * cap + pos, tk + n_exp * cap)
    buf = jnp.zeros((n_exp * cap, d), xl.dtype)
    buf = buf.at[slot].set(xl[st] * keep[:, None].astype(xl.dtype),
                           mode="drop")
    return buf.reshape(n_exp, cap, d), slot, st, sg, keep


def _local_combine(y_slots, slot, st, sg, keep, t_loc):
    """Inverse of _local_pack: gather expert outputs back, gate-combine."""
    d = y_slots.shape[-1]
    flat = y_slots.reshape(-1, d)
    y_tok = jnp.take(flat, jnp.where(keep, slot, 0), axis=0)
    y_tok = y_tok * (keep * sg).astype(y_tok.dtype)[:, None]
    return jnp.zeros((t_loc, d), y_slots.dtype).at[st].add(y_tok)


def _ep_dispatch(p, xf, moe, rules):
    """shard_map expert parallelism (see module docstring for regimes)."""
    mesh = rules.mesh
    T, D = xf.shape
    E, k, cf = moe.n_experts, moe.top_k, moe.capacity_factor

    tok_spec = rules.spec("tokens", None, dims=(T, D))
    tok_axes = _axes_tuple(tok_spec[0] if len(tok_spec) else None)
    w_shape = p["w_gate"].shape                      # (E, D, F)
    w_spec = rules.spec("expert", "fsdp_embed", "ffn", dims=w_shape)
    exp_axes = _axes_tuple(w_spec[0] if len(w_spec) else None)
    assert len(exp_axes) <= 1, exp_axes
    exp_ax = exp_axes[0] if exp_axes else None
    n_ep = mesh.shape[exp_ax] if exp_ax else 1
    e_loc = E // n_ep
    t_loc = T
    for ax in tok_axes:
        t_loc //= mesh.shape[ax]
    cap = _capacity(t_loc, k, E, cf)

    w_specs = {nm: rules.spec("expert", "fsdp_embed", "ffn",
                              dims=p[nm].shape)
               for nm in ("w_gate", "w_up", "w_down")}
    # w_down is (E, F, D): recompute with the right logical order
    w_specs["w_down"] = rules.spec("expert", "ffn", "fsdp_embed",
                                   dims=p["w_down"].shape)

    def body(xl, router, wg, wu, wd):
        wg = _gather_weight(wg, w_specs["w_gate"], exp_ax)
        wu = _gather_weight(wu, w_specs["w_up"], exp_ax)
        wd = _gather_weight(wd, w_specs["w_down"], exp_ax)
        logits = (xl @ router).astype(jnp.float32)
        gates, idx = router_topk(logits, k)
        gates = gates.astype(xl.dtype)

        if exp_ax is None:
            # experts fully local
            buf, slot, st, sg, keep = _local_pack(xl, gates, idx, E, cap)
            ye = _expert_ffn_local(
                {"w_gate": wg, "w_up": wu, "w_down": wd}, buf)
            return _local_combine(ye, slot, st, sg, keep, xl.shape[0])

        if exp_ax in tok_axes:
            # ring all_to_all: tokens travel to their experts' shard
            buf, slot, st, sg, keep = _local_pack(xl, gates, idx, E, cap)
            send = buf.reshape(n_ep, e_loc * cap, D)
            recv = lax.all_to_all(send, exp_ax, split_axis=0,
                                  concat_axis=0, tiled=False)
            he = recv.reshape(n_ep, e_loc, cap, D) \
                .transpose(1, 0, 2, 3).reshape(e_loc, n_ep * cap, D)
            ye = _expert_ffn_local(
                {"w_gate": wg, "w_up": wu, "w_down": wd}, he)
            back = ye.reshape(e_loc, n_ep, cap, D) \
                .transpose(1, 0, 2, 3).reshape(n_ep, e_loc * cap, D)
            ret = lax.all_to_all(back, exp_ax, split_axis=0,
                                 concat_axis=0, tiled=False)
            return _local_combine(ret.reshape(E * cap, D), slot, st, sg,
                                  keep, xl.shape[0])

        # tokens replicated over the expert axis: mask to local experts,
        # compute partial outputs, psum-combine
        m_idx = lax.axis_index(exp_ax)
        lo = m_idx * e_loc
        local = (idx >= lo) & (idx < lo + e_loc)
        idx_l = jnp.where(local, idx - lo, e_loc)       # e_loc = overflow
        gates_l = jnp.where(local, gates, 0.0).astype(xl.dtype)
        cap_l = _capacity(xl.shape[0], k, e_loc, cf)
        buf, slot, st, sg, keep = _local_pack(
            xl, gates_l, idx_l, e_loc + 1, cap_l)
        ye = _expert_ffn_local(
            {"w_gate": jnp.concatenate(
                [wg, jnp.zeros((1,) + wg.shape[1:], wg.dtype)]),
             "w_up": jnp.concatenate(
                 [wu, jnp.zeros((1,) + wu.shape[1:], wu.dtype)]),
             "w_down": jnp.concatenate(
                 [wd, jnp.zeros((1,) + wd.shape[1:], wd.dtype)])}, buf)
        y = _local_combine(ye, slot, st, sg, keep, xl.shape[0])
        return lax.psum(y, exp_ax)

    in_specs = (tok_spec, P(None, None),
                w_specs["w_gate"], w_specs["w_up"], w_specs["w_down"])
    fn = compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=tok_spec, check_vma=False)
    return fn(xf, p["router"].astype(xf.dtype), p["w_gate"], p["w_up"],
              p["w_down"])


def _sort_dispatch(p, xf, gates, idx, moe):
    T, D = xf.shape
    E, k = moe.n_experts, moe.top_k
    Tk = T * k
    cap = int(max(1, round(Tk / E * moe.capacity_factor)))
    # pad capacity to a multiple of 256 for layout friendliness
    cap = -(-cap // 256) * 256 if Tk >= 256 else cap

    fidx = idx.reshape(Tk)
    fgate = gates.reshape(Tk)
    ftok = jnp.arange(Tk, dtype=jnp.int32) // k
    order = jnp.argsort(fidx)
    se, st, sg = fidx[order], ftok[order], fgate[order]
    # position within expert: running index minus expert segment start
    counts = jnp.bincount(fidx, length=E)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(Tk, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep = pos_in_e < cap
    slot = jnp.where(keep, se.astype(jnp.int32) * cap + pos_in_e, Tk + E * cap)

    gathered = jnp.zeros((E * cap, D), xf.dtype)
    gathered = gathered.at[slot].set(
        xf[st] * keep[:, None].astype(xf.dtype), mode="drop")
    he = gathered.reshape(E, cap, D)
    he = annotate(he, "expert", "exp_cap", "embed")
    ye = _expert_ffn(p, he)
    ye = annotate(ye, "expert", "exp_cap", "embed")
    y_slots = ye.reshape(E * cap, D)
    y_tok = jnp.take(y_slots, jnp.where(keep, slot, 0), axis=0)
    y_tok = y_tok * (keep[:, None] * sg[:, None]).astype(y_tok.dtype)
    y = jnp.zeros((T, D), xf.dtype).at[st].add(y_tok)
    return y
