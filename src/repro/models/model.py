"""Model assembly: parameters, forward/loss, prefill, decode — all archs.

One ``Model`` class consumes an ``ArchConfig`` and exposes:

  init(key)                          -> params pytree
  param_axes()                       -> same-structure tree of logical axes
  loss(params, batch)                -> scalar CE (+ MoE aux)
  forward(params, batch)             -> logits
  prefill(params, batch, s_max)      -> (last-step logits, cache, t)
  decode_step(params, cache, t, tok) -> (logits, cache)
  init_cache(batch, s_max)           -> cache pytree (+ cache_axes())

Layers are stacked over scan periods (leading ``n_periods`` dim) so the HLO
is depth-independent; within a period the (pattern, ffn_pattern) positions
are unrolled.  Sharding is injected only via logical-axis annotations.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ArchConfig
from repro.parallel.sharding import annotate
from . import layers as L
from . import moe as M
from . import ssm as S

PyTree = Any


@dataclass(frozen=True)
class ModelKnobs:
    """Step-function tuning parameters — the configuration space the
    paper's technique searches over for the LM framework (tune/)."""

    kv_chunk: int = 1024          # flash-attention KV chunk
    moe_dispatch: str = "a2a"     # 'a2a' | 'sort' | 'dense'
    ssm_chunk: int = 256          # mamba/xlstm chunk length
    remat: str = "full"           # 'none' | 'full' | 'dots'
    scan_unroll: int = 1          # lax.scan unroll over periods
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    logits_f32: bool = True


def _kind_params(cfg: ArchConfig, kind: str) -> Dict[str, tuple]:
    """(shape, logical_axes, init_scale) per weight of one mixer kind."""
    D, dh = cfg.d_model, cfg.head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    di = cfg.d_inner
    out: Dict[str, tuple] = {"ln": ((D,), ("embed",), 0.0)}
    if kind == "attn":
        out.update({
            "wq": ((D, H, dh), ("fsdp_embed", "heads_w", None), D),
            "wk": ((D, KV, dh), ("fsdp_embed", "heads_w", None), D),
            "wv": ((D, KV, dh), ("fsdp_embed", "heads_w", None), D),
            "wo": ((H, dh, D), ("heads_w", None, "fsdp_embed"), H * dh),
        })
    elif kind == "mla":
        m = cfg.mla
        out.update({
            "wq_a": ((D, m.q_lora), ("fsdp_embed", "lora"), D),
            "q_ln": ((m.q_lora,), ("lora",), 0.0),
            "wq_b": ((m.q_lora, H, m.d_nope + m.d_rope),
                     ("lora", "heads_w", None), m.q_lora),
            "wkv_a": ((D, m.kv_lora + m.d_rope), ("fsdp_embed", "lora"), D),
            "kv_ln": ((m.kv_lora,), ("lora",), 0.0),
            "wk_b": ((m.kv_lora, H, m.d_nope), ("lora", "heads_w", None),
                     m.kv_lora),
            "wv_b": ((m.kv_lora, H, m.d_v), ("lora", "heads_w", None),
                     m.kv_lora),
            "wo": ((H, m.d_v, D), ("heads_w", None, "fsdp_embed"),
                   H * m.d_v),
        })
    elif kind == "mamba":
        N, dtr = cfg.d_state, di // 16
        out.update({
            "in_proj": ((D, 2 * di), ("fsdp_embed", "inner"), D),
            "conv_w": ((cfg.d_conv, di), (None, "inner"), cfg.d_conv),
            "x_proj": ((di, dtr + 2 * N), ("inner", None), di),
            "dt_w": ((dtr, di), (None, "inner"), dtr),
            "dt_b": ((di,), ("inner",), 0.0),
            "a_log": ((di, N), ("inner", "state"), 0.0),
            "d": ((di,), ("inner",), 0.0),
            "out_proj": ((di, D), ("inner", "fsdp_embed"), di),
        })
    elif kind == "mlstm":
        nh = cfg.n_heads
        out.update({
            "up": ((D, 2 * di), ("fsdp_embed", "inner"), D),
            "conv_w": ((cfg.d_conv, di), (None, "inner"), cfg.d_conv),
            "wq": ((di, di), ("inner", None), di),
            "wk": ((di, di), ("inner", None), di),
            "wv": ((di, di), ("inner", None), di),
            "wif": ((di, 2 * nh), ("inner", None), di),
            "b_if": ((2 * nh,), (None,), 0.0),
            "down": ((di, D), ("inner", "fsdp_embed"), di),
        })
    elif kind == "slstm":
        nh = cfg.n_heads
        dh_s = D // nh
        out.update({
            "w": ((D, 4 * D), ("fsdp_embed", None), D),
            "r": ((nh, dh_s, 4 * dh_s), (None, None, None), dh_s),
            "b": ((4 * D,), (None,), 0.0),
            "up": ((D, 2 * di), ("fsdp_embed", "inner"), D),
            "down": ((di, D), ("inner", "fsdp_embed"), di),
        })
    else:
        raise ValueError(kind)
    return out


def _ffn_params(cfg: ArchConfig, fk: str) -> Dict[str, tuple]:
    D, F = cfg.d_model, cfg.d_ff
    out: Dict[str, tuple] = {}
    if fk == "dense":
        out.update({
            "ln": ((D,), ("embed",), 0.0),
            "w_gate": ((D, F), ("fsdp_embed", "ffn"), D),
            "w_up": ((D, F), ("fsdp_embed", "ffn"), D),
            "w_down": ((F, D), ("ffn", "fsdp_embed"), F),
        })
    elif fk == "moe":
        e = cfg.moe
        E, Fe = e.n_experts, e.d_ff_expert
        out.update({
            "ln": ((D,), ("embed",), 0.0),
            "router": ((D, E), ("fsdp_embed", None), D),
            "w_gate": ((E, D, Fe), ("expert", "fsdp_embed", "ffn"), D),
            "w_up": ((E, D, Fe), ("expert", "fsdp_embed", "ffn"), D),
            "w_down": ((E, Fe, D), ("expert", "ffn", "fsdp_embed"), Fe),
        })
        if e.n_shared:
            Fs = e.n_shared * Fe
            out.update({
                "sh_gate": ((D, Fs), ("fsdp_embed", "ffn"), D),
                "sh_up": ((D, Fs), ("fsdp_embed", "ffn"), D),
                "sh_down": ((Fs, D), ("ffn", "fsdp_embed"), Fs),
            })
    elif fk != "none":
        raise ValueError(fk)
    return out


def _spec_tree(cfg: ArchConfig) -> Dict[str, Dict[str, tuple]]:
    """Full (shape, axes, fan_in) spec tree.  Block weights get a leading
    n_periods stack dim with logical axis 'layers' (always replicated)."""
    D, V = cfg.d_model, cfg.vocab
    ncb = max(cfg.n_codebooks, 1)
    tree: Dict[str, Dict[str, tuple]] = {}
    emb_shape = (V, D) if ncb == 1 else (ncb, V, D)
    emb_axes = ("vocab", "fsdp_embed") if ncb == 1 else \
        (None, "vocab", "fsdp_embed")
    tree["embed"] = {"tok": (emb_shape, emb_axes, -1)}   # -1: embed init
    P_ = cfg.n_periods
    for i, (kind, fk) in enumerate(zip(cfg.pattern, cfg.ffn_pattern)):
        pos: Dict[str, tuple] = {}
        for nm, (shape, axes, fan) in _kind_params(cfg, kind).items():
            pos["mix_" + nm] = ((P_,) + shape, ("layers",) + axes, fan)
        for nm, (shape, axes, fan) in _ffn_params(cfg, fk).items():
            pos["ffn_" + nm] = ((P_,) + shape, ("layers",) + axes, fan)
        tree[f"pos{i}"] = pos
    tree["final"] = {"ln": ((D,), ("embed",), 0.0)}
    head_shape = (D, V) if ncb == 1 else (ncb, D, V)
    head_axes = ("fsdp_embed", "vocab") if ncb == 1 else \
        (None, "fsdp_embed", "vocab")
    if not cfg.tie_embeddings:
        tree["head"] = {"w": (head_shape, head_axes, D)}
    return tree


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> PyTree:
    spec = _spec_tree(cfg)
    flat = []
    for g, sub in sorted(spec.items()):
        for nm in sorted(sub):
            flat.append((g, nm))
    keys = jax.random.split(key, len(flat))
    params: Dict[str, Dict[str, jnp.ndarray]] = {}
    for (g, nm), k in zip(flat, keys):
        shape, axes, fan = spec[g][nm]
        if nm.endswith("mix_d") or nm == "mix_d":
            w = jnp.ones(shape, dtype)           # mamba skip weight
        elif nm.endswith(("ln", "dt_b", "b_if", "_b")) or fan == 0.0:
            w = jnp.zeros(shape, dtype)
        elif fan == -1:
            w = (jax.random.normal(k, shape) * 0.02).astype(dtype)
        else:
            w = (jax.random.normal(k, shape) / math.sqrt(max(fan, 1))
                 ).astype(dtype)
        if nm.endswith("a_log"):
            # mamba: A init to -[1..N] per channel (S4D-real)
            N = shape[-1]
            w = jnp.broadcast_to(
                jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32)),
                shape).astype(dtype)
        if nm.endswith("dt_b"):
            w = jnp.full(shape, math.log(math.expm1(0.01)), dtype)
        params.setdefault(g, {})[nm] = w
    return params


def param_axes(cfg: ArchConfig) -> PyTree:
    spec = _spec_tree(cfg)
    return {g: {nm: axes for nm, (shape, axes, fan) in sub.items()}
            for g, sub in spec.items()}


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

class Model:
    def __init__(self, cfg: ArchConfig, knobs: ModelKnobs = ModelKnobs()):
        self.cfg = cfg
        self.knobs = knobs

    # -- params ---------------------------------------------------------------

    def init(self, key) -> PyTree:
        return init_params(self.cfg, key, self.knobs.param_dtype)

    def param_axes(self) -> PyTree:
        return param_axes(self.cfg)

    def param_shapes(self) -> PyTree:
        spec = _spec_tree(self.cfg)
        return {g: {nm: jax.ShapeDtypeStruct(shape, self.knobs.param_dtype)
                    for nm, (shape, axes, fan) in sub.items()}
                for g, sub in spec.items()}

    # -- embedding / head -------------------------------------------------------

    def _embed(self, params, batch):
        cfg = self.cfg
        cd = self.knobs.compute_dtype
        tok = batch["tokens"]
        table = params["embed"]["tok"].astype(cd)
        if cfg.n_codebooks:
            # (B,S,ncb) tokens; sum of per-codebook embeddings
            parts = [jnp.take(table[c], tok[..., c], axis=0)
                     for c in range(cfg.n_codebooks)]
            x = sum(parts)
        else:
            x = jnp.take(table, tok, axis=0)
        if cfg.n_patches and "patches" in batch:
            patches = batch["patches"].astype(cd)    # (B,P,D) stub frontend
            x = jnp.concatenate([patches, x], axis=1)
        return annotate(x, "batch", "seq", "embed")

    def _head(self, params, x):
        cfg = self.cfg
        table = params["head"]["w"] if "head" in params else None
        if self.knobs.logits_f32:
            x = x.astype(jnp.float32)
        if cfg.n_codebooks:
            w = table.astype(x.dtype)
            logits = jnp.einsum("bsd,cdv->bscv", x, w)
            return annotate(logits, "batch", "seq", None, "vocab")
        if table is None:   # tied
            w = params["embed"]["tok"].astype(x.dtype).T
        else:
            w = table.astype(x.dtype)
        logits = jnp.einsum("bsd,dv->bsv", x, w)
        return annotate(logits, "batch", "seq", "vocab")

    # -- full-sequence forward (train / prefill) --------------------------------

    def _stacked(self, params):
        return [params[f"pos{i}"] for i in range(self.cfg.period)]

    def _period_body_fwd(self, positions, with_cache):
        cfg, kn = self.cfg, self.knobs

        def body(x, per_period):
            caches = []
            for i, (kind, fk) in enumerate(zip(cfg.pattern, cfg.ffn_pattern)):
                p = {k[len("mix_"):]: v for k, v in per_period[i].items()
                     if k.startswith("mix_")}
                pf = {k[len("ffn_"):]: v for k, v in per_period[i].items()
                      if k.startswith("ffn_")}
                if kind == "attn":
                    h, c = L.attn_block(p, x, cfg, positions=positions,
                                        kv_chunk=kn.kv_chunk)
                elif kind == "mla":
                    h, c = L.mla_block(p, x, cfg, positions=positions,
                                       kv_chunk=kn.kv_chunk)
                elif kind == "mamba":
                    h, c = S.mamba_block(p, x, cfg, chunk=kn.ssm_chunk)
                elif kind == "mlstm":
                    h, c = S.mlstm_block(p, x, cfg, chunk=kn.ssm_chunk)
                else:
                    h, c = S.slstm_block(p, x, cfg, chunk=kn.ssm_chunk)
                x = x + h
                if fk == "dense":
                    x = x + L.ffn_block(pf, x, cfg)
                elif fk == "moe":
                    x = x + M.moe_ffn(pf, x, cfg, dispatch=kn.moe_dispatch)
                x = annotate(x, "batch", "seq", "embed")
                caches.append(c)
            return x, (tuple(caches) if with_cache else None)

        if kn.remat == "full":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        elif kn.remat == "dots":
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
        return body

    def _backbone(self, params, batch, *, with_cache=False):
        cfg, kn = self.cfg, self.knobs
        x = self._embed(params, batch)
        S_total = x.shape[1]
        positions = jnp.arange(S_total)
        body = self._period_body_fwd(positions, with_cache)
        stacked = self._stacked(params)
        x, caches = lax.scan(body, x, stacked, unroll=kn.scan_unroll)
        x = L.rms_norm(x, params["final"]["ln"], cfg.norm_eps)
        return x, caches

    def forward(self, params, batch, *, with_cache=False):
        x, caches = self._backbone(params, batch, with_cache=with_cache)
        logits = self._head(params, x)
        return (logits, caches) if with_cache else logits

    # -- loss -------------------------------------------------------------------

    def loss(self, params, batch):
        cfg = self.cfg
        logits = self.forward(params, batch)
        labels = batch["labels"]
        if cfg.n_patches:
            # labels align with the text tail of the concatenated sequence
            logits = logits[:, -labels.shape[1]:]
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        oh = jax.nn.one_hot(labels, cfg.vocab, dtype=logits.dtype)
        tgt = jnp.sum(logits * oh, axis=-1)
        ce = jnp.mean(lse - tgt)
        return ce

    # -- prefill / decode ---------------------------------------------------------

    def cache_axes(self) -> PyTree:
        """Logical axes for every cache leaf (matches init_cache structure)."""
        cfg = self.cfg
        axes = []
        for kind in cfg.pattern:
            if kind == "attn":
                a = ("layers", "batch", "kv_seq", "kv_heads", None)
                axes.append((a, a))
            elif kind == "mla":
                axes.append((("layers", "batch", "kv_seq", "lora"),
                             ("layers", "batch", "kv_seq", None)))
            elif kind == "mamba":
                axes.append((("layers", "batch", None, "inner"),
                             ("layers", "batch", "inner", "state")))
            elif kind == "mlstm":
                # C: (v-dim sharded, k-dim replicated); n tracks the k-dim
                # and stays replicated (see ssm.mlstm_block H1 note)
                axes.append((("layers", "batch", None, "inner"),
                             (("layers", "batch", None, "head_ff", None),
                              ("layers", "batch", None, None),
                              ("layers", "batch", None))))
            else:  # slstm
                axes.append((("layers", "batch", None),) * 3 +
                            (("layers", "batch", None),))
        return tuple(axes)

    def init_cache(self, batch_size: int, s_max: int) -> PyTree:
        cfg = self.cfg
        P_ = cfg.n_periods
        B = batch_size
        cd = self.knobs.compute_dtype
        di, N = cfg.d_inner, cfg.d_state
        out = []
        for kind in cfg.pattern:
            if kind == "attn":
                kv = (P_, B, s_max, cfg.n_kv_heads, cfg.head_dim)
                out.append((jnp.zeros(kv, cd), jnp.zeros(kv, cd)))
            elif kind == "mla":
                m = cfg.mla
                out.append((jnp.zeros((P_, B, s_max, m.kv_lora), cd),
                            jnp.zeros((P_, B, s_max, m.d_rope), cd)))
            elif kind == "mamba":
                out.append((jnp.zeros((P_, B, cfg.d_conv - 1, di), cd),
                            jnp.zeros((P_, B, di, N), jnp.float32)))
            elif kind == "mlstm":
                nh = cfg.n_heads
                dh = di // nh
                out.append((
                    jnp.zeros((P_, B, cfg.d_conv - 1, di), cd),
                    (jnp.zeros((P_, B, nh, dh, dh), jnp.float32),
                     jnp.zeros((P_, B, nh, dh), jnp.float32),
                     jnp.full((P_, B, nh), -1e30, jnp.float32))))
            else:  # slstm
                D = cfg.d_model
                nh = cfg.n_heads
                out.append((jnp.zeros((P_, B, D), jnp.float32),
                            jnp.zeros((P_, B, D), jnp.float32),
                            jnp.zeros((P_, B, D), jnp.float32),
                            jnp.full((P_, B, nh), -1e30, jnp.float32)))
        return tuple(out)

    def decode_step(self, params, cache, t, batch):
        """One new token.  batch['tokens']: (B,1) [or (B,1,ncb)].
        Returns (logits (B, V[, ncb->(B,ncb,V)]), new cache)."""
        cfg, kn = self.cfg, self.knobs
        x = self._embed(params, batch)           # (B,1,D)
        x = annotate(x, "batch", None, "embed")
        s_max = self._cache_smax(cache)
        kv_positions = jnp.arange(s_max)

        def body(x, per):
            per_period, cache_in = per
            new_caches = []
            for i, (kind, fk) in enumerate(zip(cfg.pattern, cfg.ffn_pattern)):
                p = {k[len("mix_"):]: v for k, v in per_period[i].items()
                     if k.startswith("mix_")}
                pf = {k[len("ffn_"):]: v for k, v in per_period[i].items()
                      if k.startswith("ffn_")}
                c = cache_in[i]
                if kind == "attn":
                    h, c = L.attn_decode(p, x, c, cfg, t=t,
                                         kv_positions=kv_positions)
                elif kind == "mla":
                    h, c = L.mla_decode(p, x, c, cfg, t=t,
                                        kv_positions=kv_positions)
                elif kind == "mamba":
                    h, (cs, ss) = S.mamba_block(
                        p, x, cfg, chunk=1, conv_state=c[0], ssm_state=c[1])
                    c = (cs, ss)
                elif kind == "mlstm":
                    h, (cs, st) = S.mlstm_block(
                        p, x, cfg, chunk=1, conv_state=c[0], state=c[1])
                    c = (cs, st)
                else:
                    h, st = S.slstm_block(p, x, cfg, chunk=1, state=c)
                    c = st
                x = x + h
                if fk == "dense":
                    x = x + L.ffn_block(pf, x, cfg)
                elif fk == "moe":
                    x = x + M.moe_ffn(pf, x, cfg, dispatch=kn.moe_dispatch)
                new_caches.append(c)
            return x, tuple(new_caches)

        stacked = self._stacked(params)
        x, new_cache = lax.scan(body, x, (stacked, cache),
                                unroll=kn.scan_unroll)
        x = L.rms_norm(x, params["final"]["ln"], cfg.norm_eps)
        logits = self._head(params, x)
        return logits[:, 0], new_cache

    def _cache_smax(self, cache):
        for kind, c in zip(self.cfg.pattern, cache):
            if kind in ("attn", "mla"):
                return c[0].shape[2]
        return 0

    def prefill(self, params, batch, s_max: int, logits_at=None):
        """Run the full prompt, build an s_max-capacity cache.

        ``logits_at``: optional (B,) positions of each row's true prompt end
        (right-padded batches); default = last position.  Returns
        (logits (B, V[...]) at those positions, cache, t=prompt_len)."""
        cfg = self.cfg
        x, caches = self._backbone(params, batch, with_cache=True)
        B, S_prompt = x.shape[0], x.shape[1]
        if logits_at is None:
            x_last = x[:, -1:]
        else:
            x_last = jnp.take_along_axis(
                x, logits_at.astype(jnp.int32)[:, None, None], axis=1)
        logits = self._head(params, x_last)[:, 0]
        out = []
        for i, kind in enumerate(cfg.pattern):
            c = caches[i]
            if kind in ("attn", "mla"):
                k, v = c
                out.append((self._pad_cache(k, s_max),
                            self._pad_cache(v, s_max)))
            else:
                out.append(c)
        return logits, tuple(out), S_prompt

    @staticmethod
    def _pad_cache(x, s_max):
        # x: (P_, B, S, ...) -> (P_, B, s_max, ...)
        pad = s_max - x.shape[2]
        if pad <= 0:
            return x
        cfgpad = [(0, 0)] * x.ndim
        cfgpad[2] = (0, pad)
        return jnp.pad(x, cfgpad)
