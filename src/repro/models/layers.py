"""Shared layer primitives: norms, rope, GQA/MLA attention, dense FFN.

All functions are *global-program* JAX: they never mention mesh axes.
Sharding is injected via ``annotate(x, 'batch', 'seq', ...)`` logical
constraints; on a bare CPU (no active rules) those are no-ops.

Attention uses an online-softmax formulation chunked over the KV length
(``lax.scan``) so the score matrix never materializes at (Sq x Skv) — the
pure-jnp oracle for the Pallas flash kernel, and the memory-feasible path
for the 32k prefill cells.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.sharding import annotate

_NEG_INF = -1e30


def rms_norm(x, w, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    y = x * lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(dt)


def rope_tables(positions, dim: int, theta: float):
    """cos/sin tables for the given absolute positions; positions may be any
    shape, tables get a trailing (dim/2) axis."""
    half = dim // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., n_heads, dim); cos/sin: broadcastable (..., dim/2).

    Rotates pairs split at half (llama convention)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked (flash-style) attention — the jnp reference path
# ---------------------------------------------------------------------------

def chunked_attention(q, k, v, *, q_positions, kv_positions, causal=True,
                      kv_chunk: int = 1024, softmax_scale=None,
                      kv_expand=None):
    """Online-softmax attention with GQA.

    q:  (B, Sq, H, dk)         k: (B, Skv, KVH, dk)   v: (B, Skv, KVH, dv)
    q_positions: (Sq,) absolute positions (global — causal masking works
    unchanged when Sq is sequence-sharded); kv_positions: (Skv,).

    ``kv_expand``: optional fn(chunk_slice) -> (k_chunk, v_chunk) producing
    the chunk's keys/values lazily (MLA expands per-chunk from the latent so
    the full per-head K/V never materialize).
    Returns (B, Sq, H, dv).
    """
    B, Sq, H, dk = q.shape
    if kv_expand is None:
        Skv, KVH = k.shape[1], k.shape[2]
        dv = v.shape[-1]
    else:
        Skv, KVH, dk_, dv = kv_expand.shape_info  # type: ignore[attr-defined]
    G = H // KVH
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(dk)
    n_chunks = max(Skv // kv_chunk, 1)
    chunk = Skv // n_chunks
    assert chunk * n_chunks == Skv, (Skv, kv_chunk)

    qg = q.reshape(B, Sq, KVH, G, dk)

    def body(carry, i):
        acc, m, l = carry
        s0 = i * chunk
        if kv_expand is None:
            kc = lax.dynamic_slice_in_dim(k, s0, chunk, axis=1)
            vc = lax.dynamic_slice_in_dim(v, s0, chunk, axis=1)
        else:
            kc, vc = kv_expand(s0, chunk)
        pos_c = lax.dynamic_slice_in_dim(kv_positions, s0, chunk, axis=0)
        # scores: (B, KVH, G, Sq, C)
        s = jnp.einsum("bqhgd,bchd->bhgqc", qg, kc,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            mask = q_positions[:, None] >= pos_c[None, :]
            s = jnp.where(mask[None, None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqc,bchd->bhgqd", p.astype(vc.dtype), vc,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, KVH, G, Sq, dv), jnp.float32)
    m0 = jnp.full((B, KVH, G, Sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KVH, G, Sq), jnp.float32)
    (acc, m, l), _ = lax.scan(body, (acc0, m0, l0), jnp.arange(n_chunks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, dv)
    return out.astype(q.dtype)


def _t_col(t):
    """t scalar or (B,) -> column (1,1)/(B,1) for broadcasting with (B,S)."""
    t = jnp.asarray(t)
    return t[None, None] if t.ndim == 0 else t[:, None]


def decode_attention(q, k, v, *, t, kv_positions, softmax_scale=None):
    """Single-step attention against a (possibly seq-sharded) KV cache.

    q: (B, 1, H, dk); k: (B, S, KVH, dk); v: (B, S, KVH, dv); positions
    beyond ``t`` (exclusive; scalar or per-row (B,)) are masked.  Written
    globally — when the cache's S dim is sharded over 'model', the SPMD
    partitioner emits exactly the flash-decode partial-softmax + combine
    pattern (max/sum all-reduces).
    """
    B, _, H, dk = q.shape
    KVH = k.shape[2]
    G = H // KVH
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(dk)
    qg = q.reshape(B, KVH, G, dk)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    valid = (kv_positions[None, :] <= _t_col(t))[:, None, None, :]
    s = jnp.where(valid, s, _NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgs,bshd->bhgd", (p / l).astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, v.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def gqa_project_qkv(p, x, cfg, positions):
    """x: (B,S,D) -> q (B,S,H,dh), k,v (B,S,KV,dh) with rope applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    cos, sin = rope_tables(positions, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = annotate(q, "batch", "seq", "heads", None)
    k = annotate(k, "batch", "seq", "kv_heads", None)
    v = annotate(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def attn_block(p, x, cfg, *, positions, kv_chunk=1024):
    """Full-sequence (train/prefill) GQA attention; returns (out, (k, v))."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q, k, v = gqa_project_qkv(p, h, cfg, positions)
    o = chunked_attention(q, k, v, q_positions=positions,
                          kv_positions=positions, causal=True,
                          kv_chunk=kv_chunk)
    o = annotate(o, "batch", "seq", "heads", None)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return annotate(out, "batch", "seq", "embed"), (k, v)


def attn_decode(p, x, cache_kv, cfg, *, t, kv_positions):
    """One-token GQA attention against the cache.  x: (B,1,D).
    cache_kv: (k, v) with shape (B, S, KV, dh); returns out, (k, v) updated.
    """
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    pos = _t_col(t)                     # (1,1) or (B,1)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k1 = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v1 = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    cos, sin = rope_tables(pos, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k1 = apply_rope(k1, cos, sin)
    k, v = cache_kv
    k = cache_update(k, k1, t)
    v = cache_update(v, v1, t)
    o = decode_attention(q, k, v, t=t, kv_positions=kv_positions)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return annotate(out, "batch", None, "embed"), (k, v)


def cache_update(cache, new, t):
    """Write ``new`` (B, 1, ...) at sequence position ``t`` (scalar or (B,))
    of ``cache`` (B, S, ...) via one-hot blend — fully shardable on the S
    dim (a dynamic-update-slice at a traced index into a sharded dim
    degrades to gather/scatter under SPMD; the blend stays elementwise)."""
    S = cache.shape[1]
    oh = (jnp.arange(S)[None, :] == _t_col(t)).astype(cache.dtype)
    oh = oh.reshape(oh.shape[:2] + (1,) * (cache.ndim - 2))
    return cache * (1 - oh) + new.astype(cache.dtype) * oh


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_project_q(p, h, cfg):
    m = cfg.mla
    ql = rms_norm(jnp.einsum("bsd,dr->bsr", h, p["wq_a"]), p["q_ln"],
                  cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", ql, p["wq_b"])   # (B,S,H,nope+rope)
    return q


class _MLAExpand:
    """Lazy per-chunk K/V expansion from the cached latent (absorbed form is
    used in decode; prefill expands chunk-by-chunk inside the online-softmax
    scan so the (S, H, dk) tensors never exist at full length)."""

    def __init__(self, p, ckv, k_rope, cfg):
        self.p, self.ckv, self.k_rope, self.cfg = p, ckv, k_rope, cfg
        m = cfg.mla
        B, S = ckv.shape[0], ckv.shape[1]
        H = cfg.n_heads
        self.shape_info = (S, H, m.d_nope + m.d_rope, m.d_v)

    def __call__(self, s0, chunk):
        p, cfg = self.p, self.cfg
        m = cfg.mla
        cc = lax.dynamic_slice_in_dim(self.ckv, s0, chunk, axis=1)
        rc = lax.dynamic_slice_in_dim(self.k_rope, s0, chunk, axis=1)
        k_nope = jnp.einsum("bsr,rhk->bshk", cc, p["wk_b"])
        v = jnp.einsum("bsr,rhk->bshk", cc, p["wv_b"])
        H = cfg.n_heads
        k_rope = jnp.broadcast_to(rc[:, :, None, :],
                                  k_nope.shape[:3] + (m.d_rope,))
        k = jnp.concatenate([k_nope, k_rope.astype(k_nope.dtype)], axis=-1)
        return k, v


def mla_block(p, x, cfg, *, positions, kv_chunk=1024):
    """MLA train/prefill; returns (out, (c_kv, k_rope)) latent cache."""
    m = cfg.mla
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = mla_project_q(p, h, cfg)
    q_nope, q_rope = q[..., :m.d_nope], q[..., m.d_nope:]
    cos, sin = rope_tables(positions, m.d_rope, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q = annotate(q, "batch", "seq", "heads", None)

    kv = jnp.einsum("bsd,dr->bsr", h, p["wkv_a"])
    ckv = rms_norm(kv[..., :m.kv_lora], p["kv_ln"], cfg.norm_eps)
    k_rope = kv[..., m.kv_lora:]
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    ckv = annotate(ckv, "batch", "seq", "lora")

    expand = _MLAExpand(p, ckv, k_rope, cfg)
    scale = 1.0 / math.sqrt(m.d_nope + m.d_rope)
    o = chunked_attention(q, None, None, q_positions=positions,
                          kv_positions=positions, causal=True,
                          kv_chunk=kv_chunk, softmax_scale=scale,
                          kv_expand=expand)
    o = annotate(o, "batch", "seq", "heads", None)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return annotate(out, "batch", "seq", "embed"), (ckv, k_rope)


def mla_decode(p, x, cache, cfg, *, t, kv_positions):
    """Absorbed-matmul MLA decode: attention runs in the latent space; the
    per-head K/V are never expanded.  cache = (c_kv (B,S,r), k_rope (B,S,dr)).
    """
    m = cfg.mla
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = mla_project_q(p, h, cfg)                       # (B,1,H,nope+rope)
    q_nope, q_rope = q[..., :m.d_nope], q[..., m.d_nope:]
    pos = _t_col(t)
    cos, sin = rope_tables(pos, m.d_rope, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    kv = jnp.einsum("bsd,dr->bsr", h, p["wkv_a"])
    ckv1 = rms_norm(kv[..., :m.kv_lora], p["kv_ln"], cfg.norm_eps)
    kr1 = apply_rope(kv[..., None, m.kv_lora:], cos, sin)[:, :, 0, :]
    ckv, k_rope = cache
    ckv = cache_update(ckv, ckv1, t)
    k_rope = cache_update(k_rope, kr1, t)

    # absorb W_uk into q: q_lat (B,H,r) = q_nope . W_uk
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_b"])[:, 0]
    scale = 1.0 / math.sqrt(m.d_nope + m.d_rope)
    s = (jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.float32),
                    ckv.astype(jnp.float32))
         + jnp.einsum("bhk,bsk->bhs", q_rope[:, 0].astype(jnp.float32),
                      k_rope.astype(jnp.float32))) * scale
    valid = (kv_positions[None, :] <= _t_col(t))[:, None, :]
    s = jnp.where(valid, s, _NEG_INF)
    m_ = jnp.max(s, axis=-1, keepdims=True)
    pr = jnp.exp(s - m_)
    pr = pr / jnp.sum(pr, axis=-1, keepdims=True)
    o_lat = jnp.einsum("bhs,bsr->bhr", pr.astype(ckv.dtype), ckv)
    o = jnp.einsum("bhr,rhk->bhk", o_lat, p["wv_b"])   # absorb W_uv
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None]
    return annotate(out, "batch", None, "embed"), (ckv, k_rope)


# ---------------------------------------------------------------------------
# dense FFN
# ---------------------------------------------------------------------------

def ffn_block(p, x, cfg):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    g = jnp.einsum("bsd,df->bsf", h, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", h, p["w_up"])
    g = annotate(g, "batch", "seq", "ffn")
    u = annotate(u, "batch", "seq", "ffn")
    y = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p["w_down"])
    return annotate(y, "batch", "seq", "embed")
