"""Recurrent mixers: Mamba (selective SSM), mLSTM and sLSTM (xLSTM).

All three share ``chunked_scan``: an outer ``lax.scan`` over sequence chunks
whose body is checkpointed (so backward saves only chunk-boundary states)
and an inner ``lax.scan`` over steps.  This bounds both the live activation
set (one chunk's discretized tensors) and the autodiff residuals — the
memory-hierarchy adaptation of Mamba's fused-kernel insight (DESIGN.md §2):
on TPU we block for HBM/VMEM via scan structure instead of a CUDA kernel.

Per-channel recurrences are independent across the inner dimension, so the
'inner' logical axis shards over 'model' with zero cross-shard traffic in
the recurrent core.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.parallel.sharding import annotate, current_rules, is_axes_leaf
from .layers import rms_norm


def _manual_scan(scan_fn, arg_axes, out_axes, args):
    """Run ``scan_fn(*args)`` inside shard_map when rules are active.

    Why: the recurrent cores use shared weights (R, A) whose gradients
    contract over the batch-sharded dim; under plain SPMD the backward scan
    all-reduces that partial EVERY STEP (measured 2.3e11 B/dev on
    xlstm x train_4k).  Under shard_map, AD accumulates weight-gradient
    partials shard-locally and inserts one psum at the region boundary
    (EXPERIMENTS.md §Perf H1).

    ``arg_axes``/``out_axes``: logical-axes trees matching args/outputs
    (leaves are axis tuples).
    """
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return scan_fn(*args)
    import jax as _jax
    from jax.sharding import PartitionSpec as _P

    def spec_of(ax, leaf):
        return rules.spec(*ax, dims=leaf.shape)
    in_specs = _jax.tree.map(spec_of, tuple(arg_axes), tuple(args),
                             is_leaf=is_axes_leaf)
    out_shapes = _jax.eval_shape(scan_fn, *args)
    out_specs = _jax.tree.map(spec_of, out_axes, out_shapes,
                              is_leaf=is_axes_leaf)
    fn = compat.shard_map(scan_fn, mesh=rules.mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
    return fn(*args)


def chunked_scan(step_fn, carry, xs, *, chunk: int, checkpoint: bool = True):
    """scan(step_fn, carry, xs) with xs leaves shaped (S, ...), restructured
    as nc chunks of ``chunk`` steps; the chunk body is rematerialized in
    backward.  Returns (final_carry, ys) with ys leaves (S, ...)."""
    S = jax.tree_util.tree_leaves(xs)[0].shape[0]
    if S <= chunk:
        return lax.scan(step_fn, carry, xs)
    nc = S // chunk
    assert nc * chunk == S, (S, chunk)
    xs_c = jax.tree.map(
        lambda a: a.reshape((nc, chunk) + a.shape[1:]), xs)

    def chunk_body(c, x_chunk):
        return lax.scan(step_fn, c, x_chunk)

    if checkpoint:
        chunk_body = jax.checkpoint(
            chunk_body, policy=jax.checkpoint_policies.nothing_saveable)
    carry, ys_c = lax.scan(chunk_body, carry, xs_c)
    ys = jax.tree.map(
        lambda a: a.reshape((S,) + a.shape[2:]), ys_c)
    return carry, ys


# ---------------------------------------------------------------------------
# causal depthwise conv (shared by mamba/mlstm)
# ---------------------------------------------------------------------------

def causal_conv(x, w, state=None):
    """x: (B, S, C), w: (K, C) depthwise.  ``state``: (B, K-1, C) carried
    from the previous segment (decode); returns (y, new_state)."""
    B, S, C = x.shape
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = jnp.zeros_like(x)
    for i in range(K):  # K is 4: unrolled shifts beat conv_general here
        y = y + xp[:, i:i + S, :] * w[i]
    new_state = xp[:, S:, :] if K > 1 else state
    return y, new_state


# ---------------------------------------------------------------------------
# Mamba (selective SSM)
# ---------------------------------------------------------------------------

def _mamba_inner(p, xz, cfg, conv_state, ssm_state, *, chunk):
    """xz: (B, S, 2*di) from in_proj.  Returns (y (B,S,di), conv, ssm)."""
    di = cfg.d_inner
    N = cfg.d_state
    x, z = xz[..., :di], xz[..., di:]
    x, conv_state = causal_conv(x, p["conv_w"], conv_state)
    x = jax.nn.silu(x)
    x = annotate(x, "batch", "seq", "inner")

    dbc = jnp.einsum("bsc,cr->bsr", x, p["x_proj"])
    dtr = di // 16
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rc->bsc", dbc[..., :dtr], p["dt_w"]) + p["dt_b"])
    Bc = dbc[..., dtr:dtr + N]
    Cc = dbc[..., dtr + N:]
    A = -jnp.exp(p["a_log"].astype(jnp.float32))          # (di, N)

    # step over (S,)-leading tensors; per-chunk discretization only.
    # the recurrent core runs under shard_map (_manual_scan): A's gradient
    # then accumulates shard-locally instead of all-reducing per step.
    def scan_part(A_, ssm_state, x_s, dt_s, b_s, c_s):
        def step(h, inp):
            x_t, dt_t, b_t, c_t = inp                # (B,di),(B,di),(B,N)
            dA = jnp.exp(dt_t.astype(jnp.float32)[..., None] * A_)
            dBx = (dt_t * x_t).astype(jnp.float32)[..., None] * \
                b_t.astype(jnp.float32)[:, None, :]
            h = h * dA + dBx
            y_t = jnp.einsum("bcn,bn->bc", h, c_t.astype(jnp.float32))
            return h, y_t.astype(x_t.dtype)
        return chunked_scan(step, ssm_state, (x_s, dt_s, b_s, c_s),
                            chunk=chunk)

    xs = (x.transpose(1, 0, 2), dt.transpose(1, 0, 2),
          Bc.transpose(1, 0, 2), Cc.transpose(1, 0, 2))
    b_ax = ("batch",)
    ssm_state, ys = _manual_scan(
        scan_part,
        (("inner", "state"), ("batch", "inner", "state"),
         (None, "batch", "inner"), (None, "batch", "inner"),
         (None, "batch", None), (None, "batch", None)),
        (("batch", "inner", "state"), (None, "batch", "inner")),
        (A, ssm_state) + xs)
    y = ys.transpose(1, 0, 2) + x * p["d"]
    y = y * jax.nn.silu(z)
    return annotate(y, "batch", "seq", "inner"), conv_state, ssm_state


def mamba_block(p, x, cfg, *, chunk=256, conv_state=None, ssm_state=None):
    """Full mamba block.  Returns (out, (conv_state, ssm_state))."""
    B = x.shape[0]
    di, N = cfg.d_inner, cfg.d_state
    if ssm_state is None:
        ssm_state = jnp.zeros((B, di, N), jnp.float32)
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    xz = jnp.einsum("bsd,dc->bsc", h, p["in_proj"])
    xz = annotate(xz, "batch", "seq", "inner")
    y, conv_state, ssm_state = _mamba_inner(
        p, xz, cfg, conv_state, ssm_state, chunk=chunk)
    out = jnp.einsum("bsc,cd->bsd", y, p["out_proj"])
    return annotate(out, "batch", "seq", "embed"), (conv_state, ssm_state)


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory block, recurrent-chunked form)
# ---------------------------------------------------------------------------

def mlstm_block(p, x, cfg, *, chunk=128, conv_state=None, state=None,
                mode: str = "chunkwise"):
    """Returns (out, (conv_state, (C, n, m))).

    State: C (B, nh, dv, dk) matrix memory, n (B, nh, dk) normalizer,
    m (B, nh) log-space stabilizer.  ``mode``: 'chunkwise' (matmul-shaped,
    default for S>1) or 'recurrent' (the oracle; always used for S=1)."""
    B, S, D = x.shape
    di = cfg.d_inner
    nh = cfg.n_heads
    dh = di // nh
    if state is None:
        state = (jnp.zeros((B, nh, dh, dh), jnp.float32),
                 jnp.zeros((B, nh, dh), jnp.float32),
                 jnp.full((B, nh), -1e30, jnp.float32))
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    xz = jnp.einsum("bsd,dc->bsc", h, p["up"])
    xi, z = xz[..., :di], xz[..., di:]
    xi, conv_state = causal_conv(xi, p["conv_w"], conv_state)
    xi = jax.nn.silu(xi)
    xi = annotate(xi, "batch", "seq", "inner")

    q = jnp.einsum("bsc,ce->bse", xi, p["wq"]).reshape(B, S, nh, dh)
    k = jnp.einsum("bsc,ce->bse", xi, p["wk"]).reshape(B, S, nh, dh)
    v = jnp.einsum("bsc,ce->bse", xi, p["wv"]).reshape(B, S, nh, dh)
    # shard ONLY the v-dim (C's rows): q/k stay replicated on dh so the
    # recurrence's q.k contraction and the C/n updates are all shard-local
    # (a sharded k-dim costs one all-reduce PER RECURRENCE STEP — measured
    # 2.3e11 B/dev on train_4k; see EXPERIMENTS.md §Perf H1)
    q = annotate(q, "batch", "seq", None, None)
    k = annotate(k, "batch", "seq", None, None)
    v = annotate(v, "batch", "seq", None, "head_ff")
    gif = jnp.einsum("bsc,cg->bsg", xi, p["wif"]) + p["b_if"]
    ig, fg = gif[..., :nh], gif[..., nh:]
    scale = 1.0 / math.sqrt(dh)

    if mode == "chunkwise" and S > 1:
        state, y4 = _mlstm_chunkwise(q, k, v, ig, fg, state,
                                     chunk=chunk, scale=scale)
        y = y4.reshape(B, S, di)
        y = annotate(y, "batch", "seq", "inner")
        y = y * jax.nn.silu(z)
        out = jnp.einsum("bsc,cd->bsd", y, p["down"])
        return annotate(out, "batch", "seq", "embed"), (conv_state, state)

    def scan_part(state, q_s, k_s, v_s, i_s, f_s):
        def step(carry, inp):
            C, n, m = carry
            q_t, k_t, v_t, i_t, f_t = inp
            i_t = i_t.astype(jnp.float32)
            logf = -jax.nn.softplus(-f_t.astype(jnp.float32))
            m_new = jnp.maximum(logf + m, i_t)
            fe = jnp.exp(logf + m - m_new)
            ie = jnp.exp(i_t - m_new)
            kf = k_t.astype(jnp.float32) * scale
            C = C * fe[..., None, None] + \
                ie[..., None, None] * v_t.astype(jnp.float32)[..., None] * \
                kf[:, :, None, :]
            n = n * fe[..., None] + ie[..., None] * kf
            qy = jnp.einsum("bhvk,bhk->bhv", C, q_t.astype(jnp.float32))
            denom = jnp.maximum(
                jnp.abs(jnp.einsum("bhk,bhk->bh", n,
                                   q_t.astype(jnp.float32))),
                jnp.exp(-m_new))[..., None]
            y_t = qy / denom
            return (C, n, m_new), y_t.astype(q_t.dtype)
        return chunked_scan(step, state, (q_s, k_s, v_s, i_s, f_s),
                            chunk=chunk)

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), ig.transpose(1, 0, 2),
          fg.transpose(1, 0, 2))
    st_ax = (("batch", None, "head_ff", None), ("batch", None, None),
             ("batch", None))
    state, ys = _manual_scan(
        scan_part,
        (st_ax,
         (None, "batch", None, None), (None, "batch", None, None),
         (None, "batch", None, "head_ff"),
         (None, "batch", None), (None, "batch", None)),
        (st_ax, (None, "batch", None, "head_ff")),
        (state,) + xs)
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, di)
    y = annotate(y, "batch", "seq", "inner")
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsc,cd->bsd", y, p["down"])
    return annotate(out, "batch", "seq", "embed"), (conv_state, state)


def _mlstm_chunkwise(q, k, v, ig, fg, state, *, chunk: int, scale: float):
    """Chunkwise-parallel mLSTM (beyond-paper; EXPERIMENTS.md §Perf H2-k).

    Exact reformulation of the recurrent form: with a_t = cumsum(logsig f),
    b_s = i_s - a_s and stabilizer m_t = a_t + mm_t where
    mm_t = max(m_in, cummax b), every intra-chunk weight collapses to
    exp(b_s - mm_t)·(q_t·k_s) — two (L x L) masked matmuls and two state
    products per chunk instead of L sequential outer products: MXU-shaped
    compute, state carried once per chunk (HBM carry traffic / L).

    q,k,v: (B,S,nh,dh); ig,fg: (B,S,nh); state=(C,n,m) as in mlstm_block.
    Returns (state, y (B,S,nh,dh)).
    """
    B, S, nh, dh = q.shape
    L = min(chunk, S)
    while S % L:
        L -= 1
    nc = S // L

    def to_chunks(x):
        return x.reshape((B, nc, L) + x.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    ic, fc = to_chunks(ig.astype(jnp.float32)), \
        to_chunks(fg.astype(jnp.float32))

    def chunk_body(carry, xs):
        C, n, m_in = carry                       # (B,h,dv,dk),(B,h,dk),(B,h)
        q_, k_, v_, i_, f_ = xs                  # (B,L,h,...)
        logf = -jax.nn.softplus(-f_)             # (B,L,h)
        a = jnp.cumsum(logf, axis=1)
        b = i_ - a
        mm = jnp.maximum(jax.lax.cummax(b, axis=1), m_in[:, None])
        qf = q_.astype(jnp.float32)
        kf = k_.astype(jnp.float32) * scale
        vf = v_.astype(jnp.float32)

        sqk = jnp.einsum("blhd,bshd->bhls", qf, kf)          # (B,h,L,L)
        b_bhs = b.transpose(0, 2, 1)                          # (B,h,S)
        mm_bht = mm.transpose(0, 2, 1)                        # (B,h,T)
        # dec[b,h,t,s] = exp(b_s - mm_t); mask s<=t
        dec = jnp.exp(b_bhs[:, :, None, :] - mm_bht[:, :, :, None])
        mask = jnp.tril(jnp.ones((L, L), bool))
        Wt = jnp.where(mask[None, None], sqk * dec, 0.0)
        intra = jnp.einsum("bhts,bshd->bthd", Wt, vf)

        inter_scale = jnp.exp(m_in[:, None] - mm)            # (B,L,h)
        inter = jnp.einsum("bhvk,blhk->blhv", C, qf) * \
            inter_scale[..., None]

        Nw = jnp.where(mask[None, None], dec, 0.0)           # (B,h,t,s)
        n_t = jnp.einsum("bhts,bshk->bthk", Nw, kf) + \
            n[:, None] * inter_scale[..., None]
        qn = jnp.einsum("blhk,blhk->blh", qf, n_t)
        m_t = a + mm                                          # absolute
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_t))[..., None]
        y = ((inter + intra) / denom).astype(q_.dtype)

        mm_L = mm[:, -1]
        wS = jnp.exp(b - mm_L[:, None])                       # (B,L,h)
        C_out = jnp.einsum("blh,blhv,blhk->bhvk", wS, vf, kf) + \
            jnp.exp(m_in - mm_L)[..., None, None] * C
        n_out = jnp.einsum("blh,blhk->bhk", wS, kf) + \
            jnp.exp(m_in - mm_L)[..., None] * n
        m_out = a[:, -1] + mm_L
        return (C_out, n_out, m_out), y

    state, ys = lax.scan(chunk_body, state, (qc, kc, vc, ic, fc))
    y = ys.swapaxes(0, 1).reshape(B, S, nh, dh)
    return state, y


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar-memory block)
# ---------------------------------------------------------------------------

def slstm_block(p, x, cfg, *, chunk=128, state=None):
    """Strictly sequential scalar-memory LSTM with exponential gating and
    per-head block-diagonal recurrence.  Returns (out, state);
    state = (c, n, h, m) each (B, D) [(B, nh) for m]."""
    B, S, D = x.shape
    nh = cfg.n_heads
    dh = D // nh
    di = cfg.d_inner
    if state is None:
        state = (jnp.zeros((B, D), jnp.float32),
                 jnp.zeros((B, D), jnp.float32),
                 jnp.zeros((B, D), jnp.float32),
                 jnp.full((B, nh), -1e30, jnp.float32))
    xh = rms_norm(x, p["ln"], cfg.norm_eps)
    wx = jnp.einsum("bsd,dg->bsg", xh, p["w"]) + p["b"]     # (B,S,4D)

    def scan_part(r_, state, wx_s):
        def step(carry, wx_t):
            c, n, h, m = carry
            hh = h.reshape(-1, nh, dh)
            rg = jnp.einsum("bhk,hkg->bhg", hh, r_).reshape(h.shape[0],
                                                            4 * D)
            g = (wx_t.astype(jnp.float32) + rg)
            zt = jnp.tanh(g[..., :D])
            it = g[..., D:2 * D].reshape(-1, nh, dh).mean(-1)
            ft = g[..., 2 * D:3 * D].reshape(-1, nh, dh).mean(-1)
            ot = jax.nn.sigmoid(g[..., 3 * D:])
            logf = -jax.nn.softplus(-ft)
            m_new = jnp.maximum(logf + m, it)
            fe = jnp.exp(logf + m - m_new)[..., None]
            ie = jnp.exp(it - m_new)[..., None]
            fe = jnp.broadcast_to(fe, it.shape + (dh,)).reshape(h.shape)
            ie = jnp.broadcast_to(ie, it.shape + (dh,)).reshape(h.shape)
            c_new = fe * c + ie * zt
            n_new = fe * n + ie
            h_new = ot * c_new / jnp.maximum(n_new, 1.0)
            return (c_new, n_new, h_new, m_new), h_new.astype(wx_t.dtype)
        return chunked_scan(step, state, wx_s, chunk=chunk)

    st_ax = (("batch", None), ("batch", None), ("batch", None),
             ("batch", None))
    state, ys = _manual_scan(
        scan_part,
        ((None, None, None), st_ax, (None, "batch", None)),
        (st_ax, (None, "batch", None)),
        (p["r"], state, wx.transpose(1, 0, 2)))
    h_seq = ys.transpose(1, 0, 2)
    # per-block projection FFN (d_ff=0 archs carry their own up/down)
    u = jnp.einsum("bsd,dc->bsc", h_seq, p["up"])   # (B,S,2*di) GLU
    u = annotate(u, "batch", "seq", "inner")
    out = jnp.einsum("bsc,cd->bsd", jax.nn.silu(u[..., :di]) * u[..., di:],
                     p["down"])
    return annotate(out, "batch", "seq", "embed"), state
