"""models — pure-JAX LM substrate for the 10 assigned architectures.

Scan-over-layers model definitions consuming ``configs.ArchConfig``;
sharding enters only through ``parallel.sharding.annotate`` logical-axis
constraints, so the same code serves single-device smoke tests and the
512-device dry-run.
"""

from .model import Model, init_params, param_axes

__all__ = ["Model", "init_params", "param_axes"]
