"""parallel — mesh/sharding rules, pipeline option, gradient compression."""

from .sharding import (ShardingRules, axis_rules, annotate, logical_spec,
                       current_rules, RULE_VARIANTS, make_rules)

__all__ = ["ShardingRules", "axis_rules", "annotate", "logical_spec",
           "current_rules", "RULE_VARIANTS", "make_rules"]
