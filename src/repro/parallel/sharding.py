"""Logical-axis sharding rules (t5x-style), the framework's single source of
sharding truth.

Model code annotates activations with *logical* axis names
(``annotate(x, 'batch', 'seq', 'embed')``); parameter initializers attach
logical axes per weight.  A ``ShardingRules`` table maps logical names to
mesh axes.  The mapping is what the autotuner tunes (DESIGN.md §4): rule
variants are points of the configuration space the paper's technique
searches.

Divisibility fallback: if a dimension is not divisible by the product of its
assigned mesh axes, trailing mesh axes are dropped until it is — so the same
rule table serves every (arch x shape) cell (e.g. ``long_500k``'s batch=1
simply loses its 'data' assignment instead of failing to lower).
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import AxisType, get_abstract_mesh

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> mesh axis (or tuple of mesh axes, major first)."""

    name: str
    table: Dict[str, MeshAxes]
    mesh: Optional[Mesh] = None

    def mesh_axes(self, logical: str) -> Tuple[str, ...]:
        v = self.table.get(logical)
        if v is None:
            return ()
        if isinstance(v, str):
            return (v,)
        return tuple(v)

    def spec(self, *logical: Optional[str],
             dims: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for a tensor whose dims carry the given logical
        names (None = replicated dim). ``dims`` enables the divisibility
        fallback; pass the concrete shape when available."""
        used = set()
        out = []
        for i, name in enumerate(logical):
            if name is None:
                out.append(None)
                continue
            axes = [a for a in self.mesh_axes(name) if a not in used]
            if dims is not None and self.mesh is not None:
                axes = _fit_axes(axes, int(dims[i]), self.mesh)
            if not axes:
                out.append(None)
            elif len(axes) == 1:
                out.append(axes[0])
                used.add(axes[0])
            else:
                out.append(tuple(axes))
                used.update(axes)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def with_mesh(self, mesh: Mesh) -> "ShardingRules":
        # drop assignments to axes the mesh does not have (e.g. 'pod' on the
        # single-pod mesh)
        axis_names = set(mesh.axis_names)
        table = {}
        for k, v in self.table.items():
            axes = (v,) if isinstance(v, str) else tuple(v or ())
            axes = tuple(a for a in axes if a in axis_names)
            table[k] = axes if axes else None
        return ShardingRules(self.name, table, mesh)

    def override(self, **kw: MeshAxes) -> "ShardingRules":
        t = dict(self.table)
        t.update(kw)
        return ShardingRules(self.name, t, self.mesh)


def _fit_axes(axes, dim, mesh):
    """Drop trailing mesh axes until the dim is divisible by their product."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    while axes:
        prod = int(np.prod([sizes[a] for a in axes]))
        if prod and dim % prod == 0:
            return axes
        axes = axes[:-1]
    return []


# ---------------------------------------------------------------------------
# rule variants — the sharding dimension of the tuning space
# ---------------------------------------------------------------------------

def make_rules(variant: str = "cp") -> ShardingRules:
    """Build one of the named rule variants (mesh attached later).

    Logical axes used by the model code:
      batch, seq        activations (tokens)
      kv_seq            KV-cache sequence dim (decode)
      embed             d_model
      heads, kv_heads   attention heads
      ffn               feed-forward hidden
      inner             ssm/xlstm inner width
      vocab             embedding/output vocabulary
      expert            MoE expert dim
      lora              MLA latent dims
      fsdp_embed        weight d_model dim for FSDP sweeps
    """
    if variant == "cp":
        # context parallelism: activations sharded batch->data, seq->model;
        # weights Megatron-sharded on ffn/vocab/experts over model and
        # FSDP-sharded on embed over data.
        table = {
            "batch": ("pod", "data"), "seq": "model", "kv_seq": "model",
            "embed": None, "heads": None, "kv_heads": None,
            "ffn": "model", "inner": "model", "vocab": "model",
            "expert": "model", "lora": "data",
            "fsdp_embed": "data", "state": None,
            "tokens": ("pod", "data", "model"),
            "exp_cap": ("pod", "data"), "head_ff": "model",
            "heads_w": "model",
        }
    elif variant == "dp":
        # pure data parallelism (+FSDP weights): batch over everything.
        table = {
            "batch": ("pod", "data", "model"), "seq": None, "kv_seq": None,
            "embed": None, "heads": None, "kv_heads": None,
            "ffn": None, "inner": None, "vocab": None,
            "expert": None, "lora": ("data", "model"),
            "fsdp_embed": ("data", "model"), "state": None,
            "tokens": ("pod", "data", "model"),
            "exp_cap": ("pod", "data", "model"), "head_ff": None,
            "heads_w": None,
        }
    elif variant == "tp":
        # Megatron head-parallel attention + sharded ffn; batch->data only.
        # Arch-dependent: requires n_heads % model == 0 (fallback drops it).
        table = {
            "batch": ("pod", "data"), "seq": None, "kv_seq": None,
            "embed": None, "heads": "model", "kv_heads": "model",
            "ffn": "model", "inner": "model", "vocab": "model",
            "expert": "model", "lora": "data",
            "fsdp_embed": "data", "state": None,
            "tokens": ("pod", "data"),
            "exp_cap": ("pod", "data"), "head_ff": "model",
            "heads_w": "model",
        }
    elif variant == "cp_fsdp":
        # cp + aggressive FSDP: every weight embed dim sharded over data,
        # activations identical to cp.
        base = make_rules("cp").table
        table = dict(base)
        table["embed"] = None
        table["fsdp_embed"] = "data"
    else:
        raise ValueError(f"unknown sharding variant {variant!r}")
    return ShardingRules(variant, table)


RULE_VARIANTS = ("cp", "dp", "tp", "cp_fsdp")


# ---------------------------------------------------------------------------
# thread-local active rules + annotate()
# ---------------------------------------------------------------------------

_tls = threading.local()


def current_rules() -> Optional[ShardingRules]:
    return getattr(_tls, "rules", None)


@contextmanager
def axis_rules(rules: Optional[ShardingRules]):
    prev = getattr(_tls, "rules", None)
    _tls.rules = rules
    try:
        yield rules
    finally:
        _tls.rules = prev


def logical_spec(shape: Sequence[int], *logical: Optional[str]) -> P:
    """PartitionSpec under the active rules (empty spec when none active)."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return P()
    return rules.spec(*logical, dims=shape)


def is_axes_leaf(x) -> bool:
    """True for a logical-axes tuple like ('layers', 'embed', None)."""
    return isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x)


def map_axes(fn, axes_tree, *trees):
    """tree_map where the axes tree's leaves are logical-axes tuples."""
    import jax as _jax
    return _jax.tree.map(fn, axes_tree, *trees, is_leaf=is_axes_leaf)


def annotate(x, *logical: Optional[str]):
    """with_sharding_constraint under the active rules; no-op otherwise.

    Model code is written against logical names only — this is the only
    function through which activation shardings enter the jaxpr.  Inside a
    partial-manual shard_map region (pipeline parallelism over 'pod') the
    constraint is resolved against the CONTEXT abstract mesh, whose manual
    axes must not appear in the spec (the pipeline strips them from its
    rule table).
    """
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    spec = rules.spec(*logical, dims=x.shape)
    ctx = get_abstract_mesh()
    try:
        manual = ctx is not None and getattr(ctx, "shape_tuple", ()) and \
            any(t == AxisType.Manual
                for t in getattr(ctx, "axis_types", ()))
    except Exception:
        manual = False
    if manual:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(ctx, spec))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))
