"""Gradient compression collectives.

Two pieces:

- ``simulate_int8_roundtrip`` — blockwise int8 quantize/dequantize applied to
  already-reduced gradients.  Numerically identical to what a compressed
  wire format loses; used by the train step's ``grad_compression='int8'``
  flag and by the error-feedback wrapper.  Pure elementwise — lowers on any
  mesh.

- ``ring_allreduce_int8`` — an explicit shard_map ring reduce-scatter +
  all-gather whose wire payload is int8 blocks (+ f32 scales/block): the
  collective-bytes term of the roofline drops ~4x vs f32.  Requantization
  happens per hop (values are accumulated in f32, re-encoded to int8), which
  is the standard trade of compressed rings.  Used on the cross-pod axis.

- ``ErrorFeedback`` — residual accumulation so that compression error is
  re-injected next step (Karimireddy et al.); keeps convergence at int8.
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat

BLOCK = 256


def _pad_to(x, m):
    n = x.size
    pad = (-n) % m
    if pad:
        x = jnp.concatenate([x.reshape(-1), jnp.zeros((pad,), x.dtype)])
    return x.reshape(-1), pad


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    """x (any shape) -> (q int8 (nb, BLOCK), scales f32 (nb,), pad)."""
    flat, pad = _pad_to(x.astype(jnp.float32), BLOCK)
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127) \
        .astype(jnp.int8)
    return q, scale, pad


def dequantize_int8(q, scale, pad, shape, dtype):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape).astype(dtype)


def simulate_int8_roundtrip(x: jnp.ndarray) -> jnp.ndarray:
    if x.ndim == 0:
        return x
    q, s, pad = quantize_int8(x)
    return dequantize_int8(q, s, pad, x.shape, x.dtype)


class ErrorFeedback:
    """e_{t+1} = g_t + e_t - C(g_t + e_t); apply returns C(g+e)."""

    @staticmethod
    def init(params):
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    @staticmethod
    def apply(grads, residual):
        def one(g, e):
            tot = g.astype(jnp.float32) + e
            c = simulate_int8_roundtrip(tot)
            return c.astype(g.dtype), tot - c
        out = jax.tree.map(one, grads, residual)
        g2 = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda t: isinstance(t, tuple))
        e2 = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
        return g2, e2


# ---------------------------------------------------------------------------
# explicit compressed ring (shard_map) — cross-pod gradient reduction
# ---------------------------------------------------------------------------

def _dyn_row(a, i):
    return lax.dynamic_slice_in_dim(a, i, 1, axis=0)[0]


def _set_row(a, i, v):
    return lax.dynamic_update_slice_in_dim(a, v[None], i, axis=0)


def ring_allreduce_int8(stacked: jnp.ndarray, mesh: Mesh, axis: str):
    """All-reduce per-shard contributions over ``axis`` with int8 wire.

    ``stacked``: (n, m) where row i is shard i's contribution, sharded
    ``P(axis)``.  Returns (n, m) where every row equals the sum — i.e. the
    reduced gradient is available on every shard.  Ring reduce-scatter +
    ring all-gather; every hop's payload is int8 blocks + f32 scales
    (wire bytes ~ m/4 vs an f32 ring's m), requantizing partial sums per
    hop (the standard compressed-ring trade-off).
    """
    n = mesh.shape[axis]
    if n == 1:
        return stacked
    perm = [(i, (i + 1) % n) for i in range(n)]

    def ring(local):
        x = local[0]                                   # (m,) this shard
        flat, pad = _pad_to(x.astype(jnp.float32), BLOCK * n)
        chunks = flat.reshape(n, -1)                   # n ring chunks
        r = lax.axis_index(axis)

        # reduce-scatter: after n-1 hops rank r owns chunk (r+1) % n
        for i in range(n - 1):
            send_idx = (r - i) % n
            recv_idx = (r - i - 1) % n
            q, s, p = quantize_int8(_dyn_row(chunks, send_idx))
            q = lax.ppermute(q, axis, perm)
            s = lax.ppermute(s, axis, perm)
            recv = dequantize_int8(q, s, p, (chunks.shape[1],), jnp.float32)
            chunks = _set_row(chunks, recv_idx,
                              _dyn_row(chunks, recv_idx) + recv)
        own_idx = (r + 1) % n
        q, s, p = quantize_int8(_dyn_row(chunks, own_idx))
        own = dequantize_int8(q, s, p, (chunks.shape[1],), jnp.float32)

        # all-gather: circulate the owned chunk n-1 hops
        out = _set_row(jnp.zeros_like(chunks), own_idx, own)
        for i in range(n - 1):
            q = lax.ppermute(q, axis, perm)
            s = lax.ppermute(s, axis, perm)
            piece = dequantize_int8(q, s, p, (chunks.shape[1],), jnp.float32)
            arrived_owner = (r - i - 1) % n            # rank whose chunk this is
            out = _set_row(out, (arrived_owner + 1) % n, piece)
        flat_out = out.reshape(-1)
        if pad:
            flat_out = flat_out[:-pad]
        return flat_out.reshape(x.shape).astype(x.dtype)[None]

    other_none = [None] * (stacked.ndim - 1)
    return compat.shard_map(
        ring, mesh=mesh, in_specs=P(axis, *other_none),
        out_specs=P(axis, *other_none), check_vma=False)(stacked)
