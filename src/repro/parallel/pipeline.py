"""Pipeline parallelism over the 'pod' axis (GPipe-style, selectable).

The layer stack (n_periods of scan-stacked params) is split into
``n_stages = |pod|`` contiguous stages; microbatches flow through stages
with boundary activations moved by ``ppermute``.  The schedule is the
classic (n_mb + n_stages - 1)-tick loop: stage s works on microbatch
(t - s) at tick t; the bubble fraction is (n_stages-1)/(n_mb+n_stages-1).

Implementation: ``shard_map`` manual over 'pod' only — 'data'/'model' stay
automatic, so the regular sharded layer code (logical-axis constraints on
the auto axes) runs unchanged inside each stage.  Backward flows through
the scan + ppermute transposes (reverse permutation) — no custom AD.

Embedding runs on stage 0, final-norm + head + loss on the last stage;
the scalar loss is broadcast back over 'pod'.
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat

from repro.models import layers as ML
from repro.models.model import Model
from repro.parallel.sharding import ShardingRules, axis_rules


def _split_stages(stacked, n_stages: int):
    """(P_, ...) stacked period params -> (n_stages, P_/n_stages, ...)."""
    def one(a):
        p = a.shape[0]
        assert p % n_stages == 0, (p, n_stages)
        return a.reshape((n_stages, p // n_stages) + a.shape[1:])
    return jax.tree.map(one, stacked)


def pipeline_loss(model: Model, rules: ShardingRules, params, batch, *,
                  n_mb: int = 4):
    """Cross-entropy loss with the layer stack pipelined over 'pod'.

    Equivalent (exactly) to model.loss when the pattern period divides
    evenly into |pod| stages; requires n_periods % |pod| == 0 and
    global_batch % n_mb == 0.
    """
    mesh = rules.mesh
    assert mesh is not None and "pod" in mesh.axis_names
    n_stages = mesh.shape["pod"]
    cfg = model.cfg
    P_ = cfg.n_periods
    assert P_ % n_stages == 0
    # inside the manual-'pod' region, constraints may only reference the
    # automatic axes: strip 'pod' from every rule entry
    table = {}
    for k, v in rules.table.items():
        axes = (v,) if isinstance(v, str) else tuple(v or ())
        axes = tuple(a for a in axes if a != "pod")
        table[k] = axes if axes else None
    rules = ShardingRules(rules.name + "-pipe", table, mesh)

    stage_stacks = [_split_stages(params[f"pos{i}"], n_stages)
                    for i in range(cfg.period)]
    other = {"embed": params["embed"], "final": params["final"]}
    if "head" in params:
        other["head"] = params["head"]

    def split_mb(x):
        b = x.shape[0]
        return x.reshape((n_mb, b // n_mb) + x.shape[1:])

    mbs = jax.tree.map(split_mb, batch)

    # manual over 'pod'; everything else automatic
    auto = frozenset(a for a in mesh.axis_names if a != "pod")
    n_ticks = n_mb + n_stages - 1

    def body(stage_params_in, other_p, mbs_local):
        s = lax.axis_index("pod")
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        # local stage slice: (1, P_/n_stages, ...) -> (P_/n_stages, ...)
        stage_params = jax.tree.map(lambda a: a[0], stage_params_in)

        def embed_mb(t):
            """Stage 0's input for tick t (dummy past the last mb)."""
            idx = jnp.clip(t, 0, n_mb - 1)
            mb = jax.tree.map(lambda a: a[idx], mbs_local)
            with axis_rules(rules):
                return model._embed(other_p, mb)

        def stage_fn(x):
            with axis_rules(rules):
                body_fn = model._period_body_fwd(
                    jnp.arange(x.shape[1]), False)
                x, _ = lax.scan(body_fn, x, stage_params)
            return x

        def loss_mb(x, t):
            idx = jnp.clip(t - (n_stages - 1), 0, n_mb - 1)
            labels = jax.tree.map(lambda a: a[idx], mbs_local)["labels"]
            with axis_rules(rules):
                h = ML.rms_norm(x, other_p["final"]["ln"], cfg.norm_eps)
                logits = model._head(other_p, h)
                if cfg.n_patches:
                    logits = logits[:, -labels.shape[1]:]
                lse = jax.scipy.special.logsumexp(logits, axis=-1)
                oh = jax.nn.one_hot(labels, cfg.vocab, dtype=logits.dtype)
                ce = jnp.mean(lse - jnp.sum(logits * oh, axis=-1))
            return ce

        x0 = embed_mb(jnp.int32(0))

        def tick(carry, t):
            buf, loss_acc = carry
            # stage 0 injects microbatch t; others consume the buffer
            inj = embed_mb(t)
            x_in = jnp.where(s == 0, inj, buf)
            x_out = stage_fn(x_in)
            # last stage computes loss for valid ticks
            valid = (t >= n_stages - 1) & (t - (n_stages - 1) < n_mb)
            ce = loss_mb(x_out, t)
            loss_acc = loss_acc + jnp.where(
                (s == n_stages - 1) & valid, ce, 0.0)
            buf = lax.ppermute(x_out, "pod", perm)
            return (buf, loss_acc), None

        (buf, loss_acc), _ = lax.scan(
            tick, (jnp.zeros_like(x0), jnp.zeros((), jnp.float32)),
            jnp.arange(n_ticks))
        # the loss lives on the last stage: share it with everyone
        return lax.psum(loss_acc, "pod") / n_mb

    in_specs = (
        jax.tree.map(lambda a: P("pod"), stage_stacks),
        jax.tree.map(lambda a: P(), other),
        jax.tree.map(lambda a: P(), mbs),
    )
    fn = compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=P(), check_vma=False,
                       axis_names={"pod"})
    return fn(stage_stacks, other, mbs)
