"""Beyond-paper features: (a) racing search driven by the paper's own CIs,
(b) per-family input-size extrapolation (paper §VIII future work), wired to
the CANDMC study where the shrinking trailing matrix makes per-signature
modeling weakest.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core.models import Extrapolator
from repro.core.policies import policy
from repro.core.tuner import Autotuner
from repro.linalg.studies import STUDIES, candmc_qr_study

from .common import fmt_table, save_rows


def bench_racing(fast=True):
    """Racing vs exhaustive on the Capital study: same winner, less cost."""
    rows = []
    for study_name in ("capital-cholesky", "slate-cholesky"):
        study = STUDIES[study_name]("ci")
        ex = Autotuner(study, policy("online", tolerance=0.25), trials=3,
                       seed=0)
        rep_ex = ex.tune()
        study2 = STUDIES[study_name]("ci")
        rc = Autotuner(study2, policy("online", tolerance=0.25), trials=1,
                       seed=0)
        rep_rc = rc.tune_racing(max_rounds=4 if fast else 8)
        exhaustive_iters = 3 * len(study.configs)
        rows.append({
            "study": study_name,
            "exhaustive_best": rep_ex.true_best.name,
            "racing_best": rep_rc.best,
            "agree": rep_rc.best == rep_ex.chosen.name
            or rep_rc.best == rep_ex.true_best.name,
            "exhaustive_iters": exhaustive_iters,
            "racing_iters": rep_rc.total_iterations,
            "iter_reduction": exhaustive_iters / max(
                rep_rc.total_iterations, 1),
        })
    print("\n== racing search (beyond paper) ==")
    print(fmt_table(rows, ("study", "exhaustive_best", "racing_best",
                           "agree", "exhaustive_iters", "racing_iters",
                           "iter_reduction")))
    save_rows("racing", rows)
    return rows


def bench_extrapolation(fast=True):
    """Fit t ~ a*flops + b*bytes + c per op family on CANDMC's kernels;
    validate on held-out (larger) signatures."""
    study = candmc_qr_study("ci")
    tuner = Autotuner(study, policy("conditional", tolerance=0.25),
                      trials=2, seed=0)
    rt, critter = tuner.runtime, tuner.critter
    # collect statistics from two full executions of the first config
    prog = study.configs[0].make_program(tuner.world)
    for _ in range(2):
        rt.run(prog, force_execute=True, update_stats=True)
    kbar = critter.pooled_kbar()

    rows = []
    fams = {}
    for sig, stats in kbar.items():
        if stats.n >= 2:
            fams.setdefault((sig.kind, sig.name), []).append((sig, stats))
    for fam, entries in sorted(fams.items()):
        if len(entries) < 5:
            continue
        # hold out the largest-flops signature, fit on the rest
        from repro.core.signatures import flops_of, bytes_of
        entries = sorted(entries, key=lambda e: flops_of(e[0])
                         + bytes_of(e[0]))
        held_sig, held_stats = entries[-1]
        ex = Extrapolator(min_signatures=4, max_rel_err=1.0)
        ex.refit(dict(entries[:-1]))
        pred = ex.predict(held_sig)
        if pred is None:
            continue
        t_hat, unc = pred
        rows.append({
            "family": f"{fam[0]}:{fam[1]}",
            "n_fit_sigs": len(entries) - 1,
            "held_out": str(held_sig),
            "true_ms": held_stats.mean * 1e3,
            "pred_ms": t_hat * 1e3,
            "rel_err": abs(t_hat - held_stats.mean) / held_stats.mean,
            "model_unc": unc,
        })
    print("\n== input-size extrapolation (paper §VIII future work) ==")
    print(fmt_table(rows, ("family", "n_fit_sigs", "true_ms", "pred_ms",
                           "rel_err", "model_unc")))
    good = [r for r in rows if r["rel_err"] < 0.5]
    print(f"  {len(good)}/{len(rows)} families extrapolate the held-out "
          f"(largest) signature within 50%")
    save_rows("extrapolation", rows)
    return rows


def bench_extrapolate_policy(fast=True):
    """End-to-end effect of policy(extrapolate=True) on CANDMC — the study
    whose shrinking trailing matrix defeats per-signature modeling."""
    rows = []
    for tol in ((0.25,) if fast else (0.5, 0.25, 0.125)):
        for extra in (False, True):
            study = candmc_qr_study("ci")
            rep = Autotuner(study,
                            policy("online", tolerance=tol,
                                   extrapolate=extra),
                            trials=3, seed=0).tune()
            rows.append({"tolerance": tol, "extrapolate": extra,
                         "speedup": rep.speedup,
                         "mean_error": rep.mean_error,
                         "optimum_quality": rep.optimum_quality})
    print("\n== extrapolate policy on CANDMC (end to end) ==")
    print(fmt_table(rows, ("tolerance", "extrapolate", "speedup",
                           "mean_error", "optimum_quality")))
    save_rows("extrapolate_policy", rows)
    return rows


def run(fast=True):
    r1 = bench_racing(fast)
    r2 = bench_extrapolation(fast)
    r3 = bench_extrapolate_policy(fast)
    return r1 + r2 + r3


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(fast=not args.full)


if __name__ == "__main__":
    main()
