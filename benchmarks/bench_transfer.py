"""Cold-vs-warm autotuning: cross-study statistics transfer on Capital.

Measures what ``repro.api.transfer`` buys on the paper's Capital Cholesky
study (the study whose kernels recur across configurations — the eager
policy's home turf, §VI.B):

1. **cold**  — a fresh eager study at the base tolerance, collecting its
   per-kernel statistics bank (saved under ``results/`` for reuse — e.g.
   warm-starting the minutes-to-hours SLATE@1024 / CANDMC@4096 paper-scale
   sweep points from a recorded CI-scale artifact);
2. **warm**  — the same study seeded with that bank: already-confident
   kernels start in the skip regime, so the study must select the SAME
   configuration while executing measurably fewer kernel invocations;
3. **warm-tight** — transfer across the tolerance grid: the base-tolerance
   bank seeding a tighter-tolerance study (the next sweep point), the
   common warm-start during a paper-protocol epsilon sweep.

Run: ``PYTHONPATH=src python -m benchmarks.bench_transfer``
(or through ``benchmarks.run --sections transfer``).
"""

from __future__ import annotations

import argparse
import os
import time
from typing import List, Optional

from repro.api import AutotuneSession, SimBackend
from repro.core.tuner import space_of_study
from repro.linalg.studies import STUDIES

from .common import ART, fmt_table, save_rows

COLS = ("run", "policy", "tolerance", "chosen", "executed", "skipped",
        "selective_time", "mean_error", "speedup", "bench_wall_s")


def _row(tag: str, result) -> dict:
    return {
        "run": tag, "policy": result.policy,
        "tolerance": result.tolerance, "chosen": result.chosen.name,
        "executed": sum(r.executed for r in result.records),
        "skipped": sum(r.skipped for r in result.records),
        "selective_time": result.selective_tuning_time,
        "mean_error": result.mean_error, "speedup": result.speedup,
        "bench_wall_s": round(result.wall_s, 1),
    }


def run(study: str = "capital-cholesky", scale: str = "ci",
        policy: str = "eager", tolerance: float = 0.25,
        tight_tolerance: float = 0.0625, trials: int = 3,
        discount: float = 0.5,
        bank_path: Optional[str] = None) -> List[dict]:
    space = space_of_study(STUDIES[study](scale))

    def session(**kw):
        return AutotuneSession(space, backend=SimBackend(), policy=policy,
                               trials=trials, **kw)

    t0 = time.time()
    cold = session(tolerance=tolerance, collect_stats=True).run()
    bank = cold.stats_bank()
    if bank_path is None:
        os.makedirs(ART, exist_ok=True)
        bank_path = os.path.join(ART, f"{study}-{scale}_stats_bank.json")
    bank.save(bank_path)
    print(f"cold study: {time.time() - t0:.1f}s, bank {len(bank)} kernels "
          f"-> {bank_path}")

    warm = session(tolerance=tolerance, prior=bank,
                   prior_discount=discount).run()
    warm_tight = session(tolerance=tight_tolerance, prior=bank,
                         prior_discount=discount).run()

    rows = [_row("cold", cold), _row("warm", warm),
            _row("warm-tight", warm_tight)]
    print(f"\n== transfer: {study} ({scale} scale, {policy}, "
          f"discount {discount}) ==")
    print(fmt_table(rows, COLS))

    same = warm.chosen.name == cold.chosen.name
    fewer = rows[1]["executed"] < rows[0]["executed"]
    print(f"\nwarm selects the cold winner: {same}; "
          f"executed {rows[0]['executed']} -> {rows[1]['executed']} "
          f"({'OK' if fewer else 'NO SAVINGS'}); selective time "
          f"{rows[0]['selective_time']:.3g}s -> "
          f"{rows[1]['selective_time']:.3g}s")
    if not (same and fewer):
        raise SystemExit("transfer acceptance failed: warm study must "
                         "keep the winner and execute fewer kernels")
    save_rows("transfer", rows)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--study", default="capital-cholesky",
                    choices=list(STUDIES))
    ap.add_argument("--scale", default="ci", choices=["ci", "paper"])
    ap.add_argument("--policy", default="eager")
    ap.add_argument("--tolerance", type=float, default=0.25)
    ap.add_argument("--tight", type=float, default=0.0625)
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--discount", type=float, default=0.5)
    ap.add_argument("--bank", default=None,
                    help="where to save the harvested statistics bank")
    args = ap.parse_args()
    run(study=args.study, scale=args.scale, policy=args.policy,
        tolerance=args.tolerance, tight_tolerance=args.tight,
        trials=args.trials, discount=args.discount, bank_path=args.bank)


if __name__ == "__main__":
    main()
