"""Paper-scale reproduction (Figs 4-5 protocol) at REAL processor counts.

Unlike ``bench_case_studies`` (CI scale: 64 ranks, reduced matrices), this
runs the §V.C configuration spaces on the actual virtual-machine
geometries — Capital Cholesky on 512 ranks, SLATE Cholesky on 1024,
CANDMC QR on 4096, SLATE QR on 256 — through the session API:
process-parallel across sweep points and checkpointed to
``results/paper_sweep_checkpoint.json`` so a long run survives
interruption and re-invocation only pays for missing points.

A full five-policy, six-tolerance grid over all four studies is hours of
CPU; the default grid is therefore the bounded subset recorded in
``results/paper_case_studies.json`` (Capital at two policies x two
tolerances — the study whose eager-vs-conditional contrast is the paper's
headline Fig 5 claim), and ``--studies/--policies/--eps`` widen it.

``--quick`` shrinks the grid to the nightly-CI slice (eager at tolerance
0.25, 2 trials); ``--bank PATH`` warm-starts every study of the sweep
from a recorded ``StatisticsBank`` (repro.api.transfer) — the nightly job
seeds from the CI-scale Capital bank recorded by ``bench_transfer``
(``results/capital-cholesky-ci_stats_bank.json``), exercising the
ROADMAP's warm-started paper-scale sweep end to end.

Sweeps run through ``repro.api.scheduler``: ``--share-stats`` streams
each completed sweep point's statistics bank into the shared prior of
points dispatched later (mid-sweep warm starts; ``--deterministic``
defers the sharing to checkpoint boundaries), and ``--scale mid`` runs
the beyond-Capital stepping-stone geometry (SLATE Cholesky on 256 real
ranks) whose warm-started artifact is recorded under
``results/paper_case_studies_mid.json``.
"""

from __future__ import annotations

import argparse
import os

from repro.linalg.studies import STUDIES

from .common import ART, fmt_table, save_rows, sweep_study

COLS = ("study", "policy", "tolerance", "speedup", "mean_error",
        "mean_comp_error", "optimum_quality", "chosen", "bench_wall_s")

DEFAULT_STUDIES = ("capital-cholesky",)
DEFAULT_POLICIES = ("conditional", "eager")
DEFAULT_EPS = (0.25, 0.0625)

QUICK_POLICIES = ("eager",)
QUICK_EPS = (0.25,)


def run(studies=DEFAULT_STUDIES, policies=DEFAULT_POLICIES,
        eps=DEFAULT_EPS, trials: int = 3, workers: int = 0,
        quick: bool = False, bank=None, discount: float = 0.5,
        scale: str = "paper", share_stats: bool = False,
        deterministic: bool = False, checkpoint=None):
    if quick:
        policies, eps, trials = QUICK_POLICIES, QUICK_EPS, min(trials, 2)
    prior = None
    if bank:
        from repro.api import StatisticsBank
        prior = StatisticsBank.load(bank)
        print(f"warm-starting from bank {bank} "
              f"({len(prior)} kernel signatures)")
    artifact = "paper_case_studies" if scale == "paper" \
        else f"paper_case_studies_{scale}"
    ck_name = artifact.replace("case_studies", "sweep") + "_checkpoint.json"
    all_rows = []
    for name in studies:
        ck = checkpoint or os.path.join(ART, ck_name)
        rows = sweep_study(STUDIES[name], eps=eps, policies=policies,
                           trials=trials, scale=scale, workers=workers,
                           checkpoint=ck, prior=prior,
                           prior_discount=discount,
                           share_stats=share_stats,
                           deterministic=deterministic)
        all_rows.extend(rows)
        print(f"\n== {name} ({scale.upper()} scale"
              f"{', quick' if quick else ''}"
              f"{', warm' if prior else ''}"
              f"{', shared' if share_stats else ''}) ==")
        print(fmt_table(rows, COLS))
    save_rows(artifact, all_rows)
    return all_rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--studies", nargs="*", default=list(DEFAULT_STUDIES),
                    choices=list(STUDIES))
    ap.add_argument("--policies", nargs="*",
                    default=list(DEFAULT_POLICIES))
    ap.add_argument("--eps", nargs="*", type=float,
                    default=list(DEFAULT_EPS))
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--workers", type=int, default=0,
                    help="0 = one per CPU")
    ap.add_argument("--quick", action="store_true",
                    help="nightly-CI slice: eager @ tol 0.25, 2 trials")
    ap.add_argument("--bank", default=None,
                    help="StatisticsBank JSON to warm-start the sweep "
                         "from (repro.api.transfer)")
    ap.add_argument("--discount", type=float, default=0.5,
                    help="evidence discount applied to --bank (1.0 keeps "
                         "full evidence: same-machine banks)")
    ap.add_argument("--scale", default="paper",
                    choices=["ci", "mid", "paper"],
                    help="study geometry (mid: SLATE Cholesky on 256 "
                         "ranks, the beyond-Capital artifact)")
    ap.add_argument("--share-stats", action="store_true",
                    help="stream completed points' statistics banks into "
                         "later points' priors (mid-sweep warm starts)")
    ap.add_argument("--deterministic", action="store_true",
                    help="with --share-stats: defer sharing to checkpoint "
                         "boundaries (scheduling-independent results)")
    ap.add_argument("--checkpoint", default=None,
                    help="sweep checkpoint path (default: per-scale file "
                         "under results/)")
    args = ap.parse_args()
    run(studies=args.studies, policies=args.policies, eps=args.eps,
        trials=args.trials, workers=args.workers, quick=args.quick,
        bank=args.bank, discount=args.discount, scale=args.scale,
        share_stats=args.share_stats, deterministic=args.deterministic,
        checkpoint=args.checkpoint)


if __name__ == "__main__":
    main()
