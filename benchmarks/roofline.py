"""§Roofline: the per-(arch x shape x mesh) three-term table, from the
dry-run artifacts in benchmarks/artifacts/.

    python -m benchmarks.roofline [--mesh single|multi|both] [--md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "artifacts")

COLS = ("arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
        "bound", "step_s", "mfu_frac", "useful", "live_GiB", "fits")


def load_rows(mesh="both", suffix=""):
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, f"*{suffix}.json"))):
        r = json.load(open(path))
        tagmesh = "multi" if len(r["mesh"]) == 3 else "single"
        if mesh != "both" and tagmesh != mesh:
            continue
        t = r["roofline"]
        n = r["chips"]
        # roofline fraction: useful model flops vs what the machine could do
        # in the step's roofline-limited time
        mfu = (r["model_flops_global"]
               / (n * 197e12 * max(t["step_s"], 1e-12)))
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": tagmesh,
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"], "bound": t["bound"],
            "step_s": t["step_s"], "mfu_frac": mfu,
            "useful": r["useful_flops_ratio"],
            "live_GiB": r["memory"].get("live_tpu_est_bytes", 0) / 2**30,
            "fits": r["memory"].get("fits_16g"),
        })
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both")
    ap.add_argument("--suffix", default="")
    args = ap.parse_args()
    rows = load_rows(args.mesh, args.suffix)
    from .common import fmt_table
    print(fmt_table(rows, COLS))
    by_bound = {}
    for r in rows:
        by_bound[r["bound"]] = by_bound.get(r["bound"], 0) + 1
    print(f"\n{len(rows)} cells; bound histogram: {by_bound}")
    worst = sorted(rows, key=lambda r: r["mfu_frac"])[:3]
    print("worst roofline fraction:",
          [(r['arch'], r['shape'], r['mesh'], round(r['mfu_frac'], 4))
           for r in worst])
    coll = sorted(rows, key=lambda r: -r["collective_s"])[:3]
    print("most collective-bound:",
          [(r['arch'], r['shape'], r['mesh'],
            round(r['collective_s'], 3)) for r in coll])
    return rows


if __name__ == "__main__":
    main()
