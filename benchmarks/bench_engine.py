"""Engine throughput benchmark: events/sec of the discrete-event hot path.

Measures the simulator itself (not the paper's speedup metrics): one full
execution plus several selective iterations of the SLATE Cholesky study
program at world sizes 16/64/256, reporting simulated events per wall-clock
second.  Emits ``BENCH_engine.json`` at the repository root so the perf
trajectory is tracked from PR 1 onward; ``scripts/check.sh`` gates a quick
run's warm throughput against the committed baseline (best-of-3 must reach
CHECK_RATIO, default 50% — coarse because the CI box swings 2-4x).

Usage:
    PYTHONPATH=src python -m benchmarks.bench_engine            # full sweep
    PYTHONPATH=src python -m benchmarks.bench_engine --quick    # ~10 s sanity
    PYTHONPATH=src python -m benchmarks.bench_engine --out path.json
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import time

from repro.core.critter import Critter
from repro.core.policies import policy
from repro.linalg import slate_cholesky
from repro.simmpi.comm import World
from repro.simmpi.costmodel import CostModel, KNL_STAMPEDE2
from repro.simmpi.runtime import Runtime

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_engine.json")

# world_size -> (pr, pc, n, tile): the ci-scale SLATE Cholesky geometry
# scaled so per-rank work stays comparable across world sizes.
GEOMETRIES = {
    16: (4, 4, 4096, 256),
    64: (8, 8, 8192, 256),
    256: (16, 16, 16384, 256),
}


def bench_study(world_size: int, *, pol: str = "online", tol: float = 0.25,
                selective_iters: int = 6, warmup: int = 2,
                seed: int = 0) -> dict:
    """One full (reference) execution followed by ``selective_iters``
    selective iterations — the tuner's per-configuration pattern.

    Two throughput metrics:

    - ``events_per_sec``       — all iterations, including the cold first
      run (generator execution, trace recording, full kernel sampling);
    - ``events_per_sec_warm``  — selective iterations after ``warmup``
      rounds: the steady-state interception hot path the tuner spends
      nearly all its time in, and the target of the engine optimization.
    """
    pr, pc, n, tile = GEOMETRIES[world_size]
    world = World(world_size)
    critter = Critter(world, policy(pol, tolerance=tol))
    cm = CostModel(KNL_STAMPEDE2, allocation=0, seed=seed)
    rt = Runtime(world, critter, cm.sample, seed=seed)
    prog = slate_cholesky.make_program(world, n=n, tile=tile, lookahead=1,
                                       pr=pr, pc=pc)
    runs = []
    total_events = 0
    total_wall = 0.0
    warm_events = 0
    warm_wall = 0.0
    for i in range(1 + selective_iters):
        force = i == 0
        t0 = time.perf_counter()
        res = rt.run(prog, force_execute=force)
        dt = time.perf_counter() - t0
        runs.append({"force_execute": force, "events": res.events,
                     "executed": res.executed, "skipped": res.skipped,
                     "wall_s": round(dt, 4),
                     "events_per_sec": round(res.events / dt, 1)})
        total_events += res.events
        total_wall += dt
        if i > warmup:
            warm_events += res.events
            warm_wall += dt
    return {
        "study": "slate-cholesky", "policy": pol, "tolerance": tol,
        "world_size": world_size, "n": n, "tile": tile, "lookahead": 1,
        "total_events": total_events, "total_wall_s": round(total_wall, 4),
        "events_per_sec": round(total_events / total_wall, 1),
        "events_per_sec_warm": round(warm_events / warm_wall, 1)
        if warm_wall > 0 else 0.0,
        "runs": runs,
    }


def run(world_sizes=(16, 64, 256), *, selective_iters: int = 6) -> dict:
    results = []
    for ws in world_sizes:
        r = bench_study(ws, selective_iters=selective_iters)
        print(f"world={ws:4d}  events={r['total_events']:9d}  "
              f"wall={r['total_wall_s']:8.3f}s  "
              f"events/sec={r['events_per_sec']:10.1f}  "
              f"warm={r['events_per_sec_warm']:10.1f}")
        results.append(r)
    return {
        "meta": {
            "benchmark": "engine-throughput",
            "python": platform.python_version(),
            "machine": platform.machine(),
        },
        "results": results,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="world 16+64 only, fewer iterations (~10 s)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    if args.quick:
        out = run(world_sizes=(16, 64), selective_iters=4)
    else:
        out = run()
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
