"""Engine throughput benchmark: events/sec of the discrete-event hot path.

Measures the simulator itself (not the paper's speedup metrics): one full
execution plus several selective iterations of the SLATE Cholesky study
program at world sizes 16/64/256, reporting simulated events per wall-clock
second.  Emits ``BENCH_engine.json`` at the repository root so the perf
trajectory is tracked from PR 1 onward; ``scripts/check.sh --stage engine``
gates a quick run's warm AND cold throughput against the committed baseline
(best-of-3 must reach CHECK_RATIO, default 50% — coarse because the CI box
swings 2-4x).

Throughput metrics per world size (PR 4 added the cold split; a fifth
field, ``events_per_sec_cold_scalar``, records the same-session
``trace_cache=False`` reference the batched-cold ratio is taken against):

- ``events_per_sec``              — all iterations;
- ``events_per_sec_warm``         — selective iterations after warmup: the
  steady-state interception hot path (PR-1 target);
- ``events_per_sec_cold``         — the first (recording + forced) run
  under the default cost model, whose straggler branch forces per-event
  scalar draws;
- ``events_per_sec_cold_batched`` — the same recording run with the
  straggler branch off, where the cold interpreter pre-draws every sample
  of the run in one vectorized call (PR-4 target).

PR 9 adds the compiled-warm split and the counter-RNG cold metric:

- ``events_per_sec_warm`` now measures the compiled warm program
  (segmented, vectorized replay — the default selective path);
- ``events_per_sec_warm_scalar`` — a same-session ``compiled=False``
  reference running the scalar event-program interpreter over the same
  protocol; ``warm_speedup_vs_scalar`` is their ratio (the CI gate's
  compiled-throughput signal);
- ``events_per_sec_cold_counter`` — the straggler-ON recording run under
  the counter-based (Philox-style) RNG discipline, whose mixed
  normal/uniform draws batch per segment (the PR-5 residual fix);
  ``cold_counter_speedup_vs_scalar`` compares it to the legacy per-event
  scalar fallback at the same straggler setting;
- ``compiled`` — warm-program segmentation metadata (segment counts,
  fused events, batch sizes), also emitted into check_results.json.

PR 10 adds the cross-task program-cache metric:

- ``events_per_sec_cold_cached`` — the batched cold run against a warmed
  on-disk ``ProgramCache``: artifact deserialization replaces the
  recording pass (the replay path every sweep task after the first with
  a given geometry takes); ``cold_cached_speedup_vs_batched`` is its
  ratio over the record-from-scratch cold run.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_engine            # full sweep
    PYTHONPATH=src python -m benchmarks.bench_engine --quick    # ~10 s sanity
    PYTHONPATH=src python -m benchmarks.bench_engine --verify   # cold-path,
                       # compiled-path, counter-RNG and program-cache
                       # bit-identity assertions, then exit
    PYTHONPATH=src python -m benchmarks.bench_engine --out path.json
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import time

from repro.core.critter import Critter
from repro.core.policies import policy
from repro.linalg import slate_cholesky
from repro.simmpi.comm import World
from repro.simmpi.costmodel import CostModel, KNL_STAMPEDE2
from repro.simmpi.runtime import (EV_BLOCK, EV_COLL, Runtime)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_engine.json")

# world_size -> (pr, pc, n, tile): the ci-scale SLATE Cholesky geometry
# scaled so per-rank work stays comparable across world sizes.
GEOMETRIES = {
    16: (4, 4, 4096, 256),
    64: (8, 8, 8192, 256),
    256: (16, 16, 16384, 256),
}


def _setup(world_size: int, *, pol: str, tol: float, seed: int,
           straggler_p=None, trace_cache: bool = True,
           compiled: bool = True, counter_rng: bool = False,
           program_cache=None):
    pr, pc, n, tile = GEOMETRIES[world_size]
    world = World(world_size)
    critter = Critter(world, policy(pol, tolerance=tol))
    kw = {} if straggler_p is None else {"straggler_p": straggler_p}
    cm = CostModel(KNL_STAMPEDE2, allocation=0, seed=seed,
                   counter_rng=counter_rng, **kw)
    rt = Runtime(world, critter, cm.sample, seed=seed,
                 trace_cache=trace_cache, compiled=compiled,
                 program_cache=program_cache)
    prog = slate_cholesky.make_program(world, n=n, tile=tile, lookahead=1,
                                       pr=pr, pc=pc)
    if program_cache is not None:
        from repro.simmpi.program import structural_fingerprint
        prog.program_key = structural_fingerprint(
            "bench-slate-cholesky", f"w{world_size}",
            {"n": n, "tile": tile, "lookahead": 1, "pr": pr, "pc": pc},
            world_size)
    return rt, prog


def bench_cold(world_size: int, *, pol: str = "online", tol: float = 0.25,
               seed: int = 0, straggler_p=0.0, trace_cache: bool = True,
               counter_rng: bool = False, program_cache=None) -> dict:
    """One recording (forced) run in isolation — the batched cold path
    when ``straggler_p == 0`` (vectorized pre-draw), the scalar-fallback
    cold path otherwise (unless ``counter_rng=True``, where the
    counter-based draw discipline batches mixed normal/uniform draws even
    with stragglers on), and with ``trace_cache=False`` the seed-style
    interleaved scalar pass that serves as the same-session reference the
    batched speedup is measured against (the shared CI box swings 2-4x
    between sessions, so only within-session ratios are stable).

    With ``program_cache`` (PR 10) the run consults the cross-task
    program cache keyed by the geometry's structural fingerprint: against
    a warmed cache the recording pass is replaced by artifact replay, so
    the wall measures deserialization + forced execution."""
    rt, prog = _setup(world_size, pol=pol, tol=tol, seed=seed,
                      straggler_p=straggler_p, trace_cache=trace_cache,
                      counter_rng=counter_rng, program_cache=program_cache)
    t0 = time.perf_counter()
    res = rt.run(prog, force_execute=True)
    dt = time.perf_counter() - t0
    return {"events": res.events, "wall_s": round(dt, 4),
            "events_per_sec": round(res.events / dt, 1),
            "straggler_p": straggler_p, "recordings": rt.recordings,
            "cache_hits": rt.cache_hits}


def _study_session(world_size: int, *, pol: str, tol: float, seed: int,
                   selective_iters: int, warmup: int,
                   compiled: bool) -> dict:
    """One tuner-pattern session (1 forced + ``selective_iters`` selective
    iterations) with per-iteration timings and the warm aggregate."""
    rt, prog = _setup(world_size, pol=pol, tol=tol, seed=seed,
                      compiled=compiled)
    runs = []
    total_events = 0
    total_wall = 0.0
    warm_events = 0
    warm_wall = 0.0
    for i in range(1 + selective_iters):
        force = i == 0
        t0 = time.perf_counter()
        res = rt.run(prog, force_execute=force)
        dt = time.perf_counter() - t0
        runs.append({"force_execute": force, "events": res.events,
                     "executed": res.executed, "skipped": res.skipped,
                     "wall_s": round(dt, 4),
                     "events_per_sec": round(res.events / dt, 1)})
        total_events += res.events
        total_wall += dt
        if i > warmup:
            warm_events += res.events
            warm_wall += dt
    return {
        "rt": rt, "prog": prog, "runs": runs,
        "total_events": total_events, "total_wall": total_wall,
        "warm_rate": round(warm_events / warm_wall, 1)
        if warm_wall > 0 else 0.0,
    }


def bench_study(world_size: int, *, pol: str = "online", tol: float = 0.25,
                selective_iters: int = 6, warmup: int = 2,
                seed: int = 0, cold_repeats: int = 3) -> dict:
    """One full (reference) execution followed by ``selective_iters``
    selective iterations — the tuner's per-configuration pattern — under
    the DEFAULT cost model (straggler branch on, so the cold run exercises
    the scalar-fallback draws), plus one isolated batched cold run
    (straggler branch off, vectorized pre-draw).

    PR 9: the selective iterations run through the compiled warm program
    (segmented vectorized replay) by default; a second, ``compiled=False``
    session over the same protocol provides the same-session scalar-warm
    reference the compiled speedup is taken against, and the straggler
    cold pair (counter-RNG batched vs legacy scalar-fallback) measures the
    PR-5 residual fix.

    PR 10: ``events_per_sec_cold_cached`` measures the batched cold run
    against a warmed on-disk program cache — the recording pass is
    replaced by artifact deserialization (the cross-task replay path a
    sweep worker takes on every task after the first with a given
    geometry)."""
    import shutil
    import tempfile

    from repro.simmpi.program import ProgramCache

    pr, pc, n, tile = GEOMETRIES[world_size]
    comp = _study_session(world_size, pol=pol, tol=tol, seed=seed,
                          selective_iters=selective_iters, warmup=warmup,
                          compiled=True)
    scal = _study_session(world_size, pol=pol, tol=tol, seed=seed,
                          selective_iters=selective_iters, warmup=warmup,
                          compiled=False)
    runs = comp["runs"]
    total_events = comp["total_events"]
    total_wall = comp["total_wall"]
    segmeta = comp["rt"].warm_meta(comp["prog"])
    # batched-vs-scalar cold pairs: alternate the variants and keep
    # min-wall of each so the pairing survives the box's second-scale
    # throughput swings (a single A-then-B measurement can land A in a
    # slow patch and B in a fast one, inverting the ratio).  Two pairs:
    # straggler-off batched pre-draw vs interleaved scalar (PR 4), and
    # straggler-ON counter-RNG batched vs legacy scalar fallback (PR 9 —
    # the PR-5 residual: mixed normal/uniform draws batched per segment).
    b_walls, s_walls, cb_walls, cs_walls, cc_walls = [], [], [], [], []
    n_events = 0
    cache_dir = tempfile.mkdtemp(prefix="bench-progcache-")
    try:
        cache = ProgramCache(cache_dir)
        # warm the cache: one untimed recording run stores the artifact
        bench_cold(world_size, pol=pol, tol=tol, seed=seed,
                   straggler_p=0.0, program_cache=cache)
        for _ in range(cold_repeats):
            b = bench_cold(world_size, pol=pol, tol=tol, seed=seed,
                           straggler_p=0.0)
            s = bench_cold(world_size, pol=pol, tol=tol, seed=seed,
                           straggler_p=0.0, trace_cache=False)
            cb = bench_cold(world_size, pol=pol, tol=tol, seed=seed,
                            straggler_p=0.002, counter_rng=True)
            cs = bench_cold(world_size, pol=pol, tol=tol, seed=seed,
                            straggler_p=0.002, counter_rng=False)
            # drop the in-memory entry so the hit pays the real artifact
            # deserialization a fresh sweep worker pays, not a dict lookup
            cache._mem.clear()
            cc = bench_cold(world_size, pol=pol, tol=tol, seed=seed,
                            straggler_p=0.0, program_cache=cache)
            assert cc["recordings"] == 0 and cc["cache_hits"] == 1, (
                f"cached cold run did not replay from the cache: {cc}")
            b_walls.append(b["wall_s"])
            s_walls.append(s["wall_s"])
            cb_walls.append(cb["wall_s"])
            cs_walls.append(cs["wall_s"])
            cc_walls.append(cc["wall_s"])
            n_events = b["events"]
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)
    batched = round(n_events / min(b_walls), 1)
    scalar = round(n_events / min(s_walls), 1)
    ctr_batched = round(n_events / min(cb_walls), 1)
    ctr_scalar = round(n_events / min(cs_walls), 1)
    cached = round(n_events / min(cc_walls), 1)
    return {
        "study": "slate-cholesky", "policy": pol, "tolerance": tol,
        "world_size": world_size, "n": n, "tile": tile, "lookahead": 1,
        "total_events": total_events, "total_wall_s": round(total_wall, 4),
        "events_per_sec": round(total_events / total_wall, 1),
        "events_per_sec_warm": comp["warm_rate"],
        "events_per_sec_warm_scalar": scal["warm_rate"],
        "warm_speedup_vs_scalar": round(
            comp["warm_rate"] / scal["warm_rate"], 2)
        if scal["warm_rate"] > 0 else 0.0,
        "events_per_sec_cold": runs[0]["events_per_sec"],
        "events_per_sec_cold_batched": batched,
        "events_per_sec_cold_scalar": scalar,
        "cold_speedup_vs_scalar": round(batched / scalar, 2),
        "events_per_sec_cold_counter": ctr_batched,
        "cold_counter_speedup_vs_scalar": round(ctr_batched / ctr_scalar, 2),
        "events_per_sec_cold_cached": cached,
        "cold_cached_speedup_vs_batched": round(cached / batched, 2),
        "compiled": segmeta,
        "runs": runs,
    }


# -------------------------------------------------------- cold-path verify

def _canonical_events(prog) -> list:
    """Event-program tuples with engine objects replaced by stable keys so
    programs recorded by different Runtime/World instances compare."""
    out = []
    for ev in prog.events:
        k = ev[0]
        if k == EV_BLOCK:
            out.append((k, ev[1], tuple(ev[2].sids)))
        elif k == EV_COLL:
            out.append((k, ev[1], ev[2].ranks))
        else:
            out.append(ev)
    return out


def _record_program(world_size: int, *, straggler_p, seed: int = 0):
    rt, prog = _setup(world_size, pol="online", tol=0.25, seed=seed,
                      straggler_p=straggler_p)
    rt.run(prog, force_execute=True)
    return _canonical_events(rt._traces[prog])


def verify_cold_path(world_size: int = 16) -> dict:
    """Assert the batched cold path is a pure optimization.

    1. The recorded event program is identical whether the cold run drew
       its samples batched (straggler off) or through the scalar fallback
       (straggler on): recording is structural, timing-independent.
    2. A batched cold run and an unbatched (``trace_cache=False``,
       interleaved scalar) cold run over the same cost model produce
       bit-identical reports and leave the sampler RNG in the same state.

    Returns a small summary dict; raises AssertionError on any mismatch.
    Wired into ``--verify``, ``scripts/check.sh --stage engine`` and
    ``tests/test_cold_path.py``.
    """
    ev_batched = _record_program(world_size, straggler_p=0.0)
    ev_scalar = _record_program(world_size, straggler_p=0.002)
    assert ev_batched == ev_scalar, (
        "batched and unbatched cold runs recorded different event programs")

    reports = []
    states = []
    for trace_cache in (True, False):
        rt, prog = _setup(world_size, pol="online", tol=0.25, seed=0,
                          straggler_p=0.0, trace_cache=trace_cache)
        res = rt.run(prog, force_execute=True)
        reports.append({f: getattr(res, f) for f in _REPORT_FIELDS})
        states.append(rt._rng.bit_generator.state)
    assert reports[0] == reports[1], (
        f"batched cold report diverged: {reports[0]} vs {reports[1]}")
    assert states[0] == states[1], (
        "batched cold run consumed a different RNG stream")
    return {"world_size": world_size, "events": len(ev_batched),
            "report": reports[0]}


# ---------------------------------------------------- compiled-path verify

_REPORT_FIELDS = ("predicted_time", "wall_time", "crit_comp", "crit_comm",
                  "measured_time", "max_measured_comp", "executed",
                  "skipped", "events")


def _engine_snapshot(critter) -> tuple:
    """Full rank-state fingerprint: statistics mirrors, counts, path
    profiles, per-rank clocks, global skip state and every Welford
    accumulator — byte-exact, so any drift in the compiled replay shows."""
    S = critter.state
    return (S.mean_arr.tobytes(), S.freq.tobytes(), S.seen.tobytes(),
            S.skip_ok.tobytes(), S.iter_exec.tobytes(), S.clock.tobytes(),
            S.path_exec.tobytes(), S.path_comm.tobytes(),
            S.goff.tobytes(), S.gmean.tobytes(),
            sorted(critter.global_off),
            sorted((r, sid, st.n, st.mean, st.m2, st.total, st.min_t,
                    st.max_t)
                   for r in range(S.n_ranks)
                   for sid, st in S.kbar[r].items()))


def _selective_trace(world_size: int, *, pol: str, straggler_p: float,
                     trace_cache: bool, compiled: bool,
                     iters: int = 3) -> list:
    """Forced run + ``iters`` selective iterations; returns per-iteration
    reports, per-iteration engine-state fingerprints and the final RNG
    bit-generator state."""
    rt, prog = _setup(world_size, pol=pol, tol=0.25, seed=0,
                      straggler_p=straggler_p, trace_cache=trace_cache,
                      compiled=compiled)
    trace = []
    for i in range(1 + iters):
        res = rt.run(prog, force_execute=(i == 0))
        trace.append(tuple(getattr(res, f) for f in _REPORT_FIELDS))
        trace.append(_engine_snapshot(rt.critter))
    trace.append(rt._rng.bit_generator.state)
    return trace


def verify_compiled_path(world_size: int = 16) -> dict:
    """Assert the compiled (segmented, vectorized-replay) warm program is
    bit-identical to the scalar engine.

    For each policy x straggler-branch combination the tuner protocol
    (forced run + 3 selective iterations) is run three ways — compiled
    warm program, scalar event-program interpreter (``compiled=False``)
    and the seed-style live engine (``trace_cache=False``) — and all
    three must agree on every iteration report field, the full engine
    state after every iteration (statistics, mean mirrors, counts, path
    profiles, clocks, Welford accumulators, global skip state) and the
    sampler RNG stream.  Raises AssertionError on any divergence.

    The full 5-policies x 3-studies matrix lives in
    ``tests/test_cold_path.py`` / ``tests/test_compiled_path.py``; this
    entry point is the quick in-process gate check.sh runs before timing.
    """
    checked = 0
    for pol in ("online", "eager"):
        for straggler_p in (0.0, 0.002):
            live = _selective_trace(world_size, pol=pol,
                                    straggler_p=straggler_p,
                                    trace_cache=False, compiled=True)
            scalar = _selective_trace(world_size, pol=pol,
                                      straggler_p=straggler_p,
                                      trace_cache=True, compiled=False)
            comp = _selective_trace(world_size, pol=pol,
                                    straggler_p=straggler_p,
                                    trace_cache=True, compiled=True)
            for i, (a, b, c) in enumerate(zip(live, scalar, comp)):
                assert a == c, (f"compiled path diverged from live engine "
                                f"({pol}, straggler={straggler_p}, "
                                f"trace step {i})")
                assert b == c, (f"compiled path diverged from scalar "
                                f"interpreter ({pol}, "
                                f"straggler={straggler_p}, trace step {i})")
            checked += 1
    rt, prog = _setup(world_size, pol="online", tol=0.25, seed=0)
    meta = rt.warm_meta(prog)
    assert meta["segments"] > 0 and meta["fused_events"] > 0, (
        f"warm program recorded no fused segments: {meta}")
    return {"world_size": world_size, "configs": checked,
            "compiled": meta}


def verify_counter_rng(world_size: int = 16) -> dict:
    """Assert the counter-based (Philox-style) draw discipline is a pure
    optimization: (1) per-event scalar draws and per-segment batched
    draws over the same counter range are bit-identical, including the
    straggler branch; (2) with ``counter_rng=True`` the batched cold path
    and the live engine produce identical reports and leave the draw
    cursor at the same index (the counter-mode analogue of the
    bit-generator state check); (3) selective iterations agree too."""
    import numpy as np
    from repro.core.signatures import Signature

    # (1) scalar sample() vs sample_block() over the same counter range,
    # straggler_p high enough that the straggler branch fires in-batch
    sigs = [Signature("comp", "potrf", (256,)),
            Signature("comp", "trsm", (256, 256)),
            Signature("comp", "gemm", (256, 256, 256)),
            Signature("comp", "syrk", (256, 256)),
            Signature("comm", "bcast", (131072, 16, 1))] * 40
    cm_a = CostModel(KNL_STAMPEDE2, allocation=0, seed=7,
                     straggler_p=0.05, counter_rng=True)
    cm_b = CostModel(KNL_STAMPEDE2, allocation=0, seed=7,
                     straggler_p=0.05, counter_rng=True)
    rng = np.random.default_rng(0)  # untouched in counter mode
    scalar_ts = [cm_a.sample(sig, rng) for sig in sigs]
    block_ts = cm_b.sample_block(sigs)
    assert block_ts is not None, "sample_block inactive in counter mode"
    assert scalar_ts == block_ts.tolist(), (
        "counter-RNG scalar and batched draws diverged")
    assert cm_a.draw_index == cm_b.draw_index == 3 * len(sigs), (
        f"draw cursors diverged: {cm_a.draw_index} vs {cm_b.draw_index}")

    # (2)+(3) batched cold + compiled selective vs live, counter mode,
    # straggler branch ON (the PR-5 residual configuration)
    cursors = []
    traces = []
    pr, pc, n, tile = GEOMETRIES[world_size]
    for trace_cache in (True, False):
        w = World(world_size)
        c = Critter(w, policy("online", tolerance=0.25))
        cm = CostModel(KNL_STAMPEDE2, allocation=0, seed=0,
                       straggler_p=0.002, counter_rng=True)
        rt = Runtime(w, c, cm.sample, seed=0, trace_cache=trace_cache)
        prog = slate_cholesky.make_program(w, n=n, tile=tile, lookahead=1,
                                           pr=pr, pc=pc)
        trace = []
        for i in range(3):
            res = rt.run(prog, force_execute=(i == 0))
            trace.append(tuple(getattr(res, f) for f in _REPORT_FIELDS))
            trace.append(_engine_snapshot(c))
        traces.append(trace)
        cursors.append(cm.draw_index)
    for i, (a, b) in enumerate(zip(traces[0], traces[1])):
        assert a == b, f"counter-RNG cold/warm diverged at trace step {i}"
    assert cursors[0] == cursors[1], (
        f"counter-RNG draw cursors diverged: {cursors}")
    return {"world_size": world_size, "draws": cursors[0],
            "scalar_block_parity": len(sigs)}


def verify_program_cache(world_size: int = 16) -> dict:
    """Assert a program-cache hit is a pure optimization: the tuner
    protocol (forced run + 2 selective iterations) run three ways — cache
    miss (records + stores), cache hit against the warmed store (replays
    the deserialized artifact, zero recordings) and no cache at all —
    must agree on every iteration report, the full engine state after
    every iteration and the sampler RNG stream, and the replayed event
    program must be structurally identical to the recorded one.

    The full 5-policies x 3-studies x straggler matrix lives in
    ``tests/test_program_cache.py``; this entry point is the quick
    in-process gate ``check.sh --stage engine`` runs before timing."""
    from repro.simmpi.program import ProgramCache

    cache = ProgramCache()
    traces, events, recordings = [], [], []
    for use_cache in ("miss", "hit", "off"):
        rt, prog = _setup(world_size, pol="online", tol=0.25, seed=0,
                          straggler_p=0.002,
                          program_cache=cache if use_cache != "off"
                          else None)
        trace = []
        for i in range(3):
            res = rt.run(prog, force_execute=(i == 0))
            trace.append(tuple(getattr(res, f) for f in _REPORT_FIELDS))
            trace.append(_engine_snapshot(rt.critter))
        trace.append(rt._rng.bit_generator.state)
        traces.append(trace)
        events.append(_canonical_events(rt._get_program(prog)))
        recordings.append(rt.recordings)
    assert recordings == [1, 0, 1], (
        f"cache hit did not skip recording: {recordings}")
    assert cache.hits == 1 and cache.misses == 1, (
        f"unexpected cache traffic: {cache.stats()}")
    for i, (a, b) in enumerate(zip(traces[0], traces[1])):
        assert a == b, f"cache-hit replay diverged at trace step {i}"
    for i, (a, b) in enumerate(zip(traces[0], traces[2])):
        assert a == b, f"cache-miss run diverged from uncached at step {i}"
    assert events[0] == events[1] == events[2], (
        "replayed event program is not structurally identical")
    return {"world_size": world_size, "events": len(events[0]),
            "store": cache.stats()}


_RATE_FIELDS = ("events_per_sec", "events_per_sec_warm",
                "events_per_sec_warm_scalar",
                "events_per_sec_cold", "events_per_sec_cold_batched",
                "events_per_sec_cold_scalar",
                "events_per_sec_cold_counter",
                "events_per_sec_cold_cached")
_RATIO_FIELDS = ("warm_speedup_vs_scalar", "cold_speedup_vs_scalar",
                 "cold_counter_speedup_vs_scalar",
                 "cold_cached_speedup_vs_batched")


def run(world_sizes=(16, 64, 256), *, selective_iters: int = 6,
        best_of: int = 1) -> dict:
    """``best_of > 1`` repeats each world size's study and keeps the
    per-metric maxima (runs list from the best-warm repeat): the shared CI
    box swings 2-4x between moments, and best-of-N is the same noise
    reduction check.sh applies to its gate."""
    results = []
    for ws in world_sizes:
        reps = [bench_study(ws, selective_iters=selective_iters)
                for _ in range(best_of)]
        r = max(reps, key=lambda x: x["events_per_sec_warm"])
        for f in _RATE_FIELDS + _RATIO_FIELDS:
            r[f] = max(rep[f] for rep in reps)
        print(f"world={ws:4d}  events={r['total_events']:9d}  "
              f"wall={r['total_wall_s']:8.3f}s  "
              f"events/sec={r['events_per_sec']:10.1f}  "
              f"warm={r['events_per_sec_warm']:10.1f}  "
              f"(vs scalar {r['warm_speedup_vs_scalar']:.2f}x)  "
              f"cold={r['events_per_sec_cold']:9.1f}  "
              f"cold_batched={r['events_per_sec_cold_batched']:9.1f}  "
              f"(vs scalar {r['cold_speedup_vs_scalar']:.2f}x)  "
              f"cold_counter={r['events_per_sec_cold_counter']:9.1f}  "
              f"(vs scalar {r['cold_counter_speedup_vs_scalar']:.2f}x)  "
              f"cold_cached={r['events_per_sec_cold_cached']:9.1f}  "
              f"(vs batched {r['cold_cached_speedup_vs_batched']:.2f}x)")
        seg = r["compiled"]
        print(f"            compiled: {seg['segments']} segments, "
              f"{seg['fused_events']} fused events, "
              f"mean batch {seg['mean_batch']}, max {seg['max_batch']}")
        results.append(r)
    return {
        "meta": {
            "benchmark": "engine-throughput",
            "python": platform.python_version(),
            "machine": platform.machine(),
            "best_of": best_of,
        },
        "results": results,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="world 16+64 only, fewer iterations (~10 s)")
    ap.add_argument("--verify", action="store_true",
                    help="run the cold-path identity assertions and exit")
    ap.add_argument("--best-of", type=int, default=1,
                    help="repeat each world size N times, keep per-metric "
                         "maxima (noise reduction on shared boxes)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    if args.verify:
        summary = verify_cold_path()
        print(f"cold-path verify OK: {summary['events']} events, "
              f"report {summary['report']}")
        summary = verify_compiled_path()
        print(f"compiled-path verify OK: {summary['configs']} configs "
              f"bit-identical, compiled meta {summary['compiled']}")
        summary = verify_counter_rng()
        print(f"counter-RNG verify OK: {summary['draws']} draws, "
              f"scalar/block parity over "
              f"{summary['scalar_block_parity']} signatures")
        summary = verify_program_cache()
        print(f"program-cache verify OK: {summary['events']} events "
              f"replayed bit-identical, store {summary['store']}")
        return
    if args.quick:
        out = run(world_sizes=(16, 64), selective_iters=4,
                  best_of=args.best_of)
    else:
        out = run(best_of=args.best_of)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
