"""Engine throughput benchmark: events/sec of the discrete-event hot path.

Measures the simulator itself (not the paper's speedup metrics): one full
execution plus several selective iterations of the SLATE Cholesky study
program at world sizes 16/64/256, reporting simulated events per wall-clock
second.  Emits ``BENCH_engine.json`` at the repository root so the perf
trajectory is tracked from PR 1 onward; ``scripts/check.sh --stage engine``
gates a quick run's warm AND cold throughput against the committed baseline
(best-of-3 must reach CHECK_RATIO, default 50% — coarse because the CI box
swings 2-4x).

Throughput metrics per world size (PR 4 added the cold split; a fifth
field, ``events_per_sec_cold_scalar``, records the same-session
``trace_cache=False`` reference the batched-cold ratio is taken against):

- ``events_per_sec``              — all iterations;
- ``events_per_sec_warm``         — selective iterations after warmup: the
  steady-state interception hot path (PR-1 target);
- ``events_per_sec_cold``         — the first (recording + forced) run
  under the default cost model, whose straggler branch forces per-event
  scalar draws;
- ``events_per_sec_cold_batched`` — the same recording run with the
  straggler branch off, where the cold interpreter pre-draws every sample
  of the run in one vectorized call (PR-4 target).

Usage:
    PYTHONPATH=src python -m benchmarks.bench_engine            # full sweep
    PYTHONPATH=src python -m benchmarks.bench_engine --quick    # ~10 s sanity
    PYTHONPATH=src python -m benchmarks.bench_engine --verify   # cold-path
                       # event-program/bit-identity assertions, then exit
    PYTHONPATH=src python -m benchmarks.bench_engine --out path.json
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import time

from repro.core.critter import Critter
from repro.core.policies import policy
from repro.linalg import slate_cholesky
from repro.simmpi.comm import World
from repro.simmpi.costmodel import CostModel, KNL_STAMPEDE2
from repro.simmpi.runtime import (EV_BLOCK, EV_COLL, Runtime)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_engine.json")

# world_size -> (pr, pc, n, tile): the ci-scale SLATE Cholesky geometry
# scaled so per-rank work stays comparable across world sizes.
GEOMETRIES = {
    16: (4, 4, 4096, 256),
    64: (8, 8, 8192, 256),
    256: (16, 16, 16384, 256),
}


def _setup(world_size: int, *, pol: str, tol: float, seed: int,
           straggler_p=None, trace_cache: bool = True):
    pr, pc, n, tile = GEOMETRIES[world_size]
    world = World(world_size)
    critter = Critter(world, policy(pol, tolerance=tol))
    kw = {} if straggler_p is None else {"straggler_p": straggler_p}
    cm = CostModel(KNL_STAMPEDE2, allocation=0, seed=seed, **kw)
    rt = Runtime(world, critter, cm.sample, seed=seed,
                 trace_cache=trace_cache)
    prog = slate_cholesky.make_program(world, n=n, tile=tile, lookahead=1,
                                       pr=pr, pc=pc)
    return rt, prog


def bench_cold(world_size: int, *, pol: str = "online", tol: float = 0.25,
               seed: int = 0, straggler_p=0.0,
               trace_cache: bool = True) -> dict:
    """One recording (forced) run in isolation — the batched cold path
    when ``straggler_p == 0`` (vectorized pre-draw), the scalar-fallback
    cold path otherwise, and with ``trace_cache=False`` the seed-style
    interleaved scalar pass that serves as the same-session reference the
    batched speedup is measured against (the shared CI box swings 2-4x
    between sessions, so only within-session ratios are stable)."""
    rt, prog = _setup(world_size, pol=pol, tol=tol, seed=seed,
                      straggler_p=straggler_p, trace_cache=trace_cache)
    t0 = time.perf_counter()
    res = rt.run(prog, force_execute=True)
    dt = time.perf_counter() - t0
    return {"events": res.events, "wall_s": round(dt, 4),
            "events_per_sec": round(res.events / dt, 1),
            "straggler_p": straggler_p}


def bench_study(world_size: int, *, pol: str = "online", tol: float = 0.25,
                selective_iters: int = 6, warmup: int = 2,
                seed: int = 0, cold_repeats: int = 3) -> dict:
    """One full (reference) execution followed by ``selective_iters``
    selective iterations — the tuner's per-configuration pattern — under
    the DEFAULT cost model (straggler branch on, so the cold run exercises
    the scalar-fallback draws), plus one isolated batched cold run
    (straggler branch off, vectorized pre-draw)."""
    pr, pc, n, tile = GEOMETRIES[world_size]
    rt, prog = _setup(world_size, pol=pol, tol=tol, seed=seed)
    runs = []
    total_events = 0
    total_wall = 0.0
    warm_events = 0
    warm_wall = 0.0
    for i in range(1 + selective_iters):
        force = i == 0
        t0 = time.perf_counter()
        res = rt.run(prog, force_execute=force)
        dt = time.perf_counter() - t0
        runs.append({"force_execute": force, "events": res.events,
                     "executed": res.executed, "skipped": res.skipped,
                     "wall_s": round(dt, 4),
                     "events_per_sec": round(res.events / dt, 1)})
        total_events += res.events
        total_wall += dt
        if i > warmup:
            warm_events += res.events
            warm_wall += dt
    # batched-vs-scalar cold pair: alternate the two and keep min-wall of
    # each so the pairing survives the box's second-scale throughput
    # swings (a single A-then-B measurement can land A in a slow patch
    # and B in a fast one, inverting the ratio)
    b_walls, s_walls = [], []
    n_events = 0
    for _ in range(cold_repeats):
        b = bench_cold(world_size, pol=pol, tol=tol, seed=seed,
                       straggler_p=0.0)
        s = bench_cold(world_size, pol=pol, tol=tol, seed=seed,
                       straggler_p=0.0, trace_cache=False)
        b_walls.append(b["wall_s"])
        s_walls.append(s["wall_s"])
        n_events = b["events"]
    batched = {"events_per_sec": round(n_events / min(b_walls), 1)}
    scalar = {"events_per_sec": round(n_events / min(s_walls), 1)}
    return {
        "study": "slate-cholesky", "policy": pol, "tolerance": tol,
        "world_size": world_size, "n": n, "tile": tile, "lookahead": 1,
        "total_events": total_events, "total_wall_s": round(total_wall, 4),
        "events_per_sec": round(total_events / total_wall, 1),
        "events_per_sec_warm": round(warm_events / warm_wall, 1)
        if warm_wall > 0 else 0.0,
        "events_per_sec_cold": runs[0]["events_per_sec"],
        "events_per_sec_cold_batched": batched["events_per_sec"],
        "events_per_sec_cold_scalar": scalar["events_per_sec"],
        "cold_speedup_vs_scalar": round(
            batched["events_per_sec"] / scalar["events_per_sec"], 2),
        "runs": runs,
    }


# -------------------------------------------------------- cold-path verify

def _canonical_events(prog) -> list:
    """Event-program tuples with engine objects replaced by stable keys so
    programs recorded by different Runtime/World instances compare."""
    out = []
    for ev in prog.events:
        k = ev[0]
        if k == EV_BLOCK:
            out.append((k, ev[1], tuple(ev[2].sids)))
        elif k == EV_COLL:
            out.append((k, ev[1], ev[2].ranks))
        else:
            out.append(ev)
    return out


def _record_program(world_size: int, *, straggler_p, seed: int = 0):
    rt, prog = _setup(world_size, pol="online", tol=0.25, seed=seed,
                      straggler_p=straggler_p)
    rt.run(prog, force_execute=True)
    return _canonical_events(rt._traces[prog])


def verify_cold_path(world_size: int = 16) -> dict:
    """Assert the batched cold path is a pure optimization.

    1. The recorded event program is identical whether the cold run drew
       its samples batched (straggler off) or through the scalar fallback
       (straggler on): recording is structural, timing-independent.
    2. A batched cold run and an unbatched (``trace_cache=False``,
       interleaved scalar) cold run over the same cost model produce
       bit-identical reports and leave the sampler RNG in the same state.

    Returns a small summary dict; raises AssertionError on any mismatch.
    Wired into ``--verify``, ``scripts/check.sh --stage engine`` and
    ``tests/test_cold_path.py``.
    """
    ev_batched = _record_program(world_size, straggler_p=0.0)
    ev_scalar = _record_program(world_size, straggler_p=0.002)
    assert ev_batched == ev_scalar, (
        "batched and unbatched cold runs recorded different event programs")

    fields = ("predicted_time", "wall_time", "crit_comp", "crit_comm",
              "measured_time", "max_measured_comp", "executed", "skipped",
              "events")
    reports = []
    states = []
    for trace_cache in (True, False):
        rt, prog = _setup(world_size, pol="online", tol=0.25, seed=0,
                          straggler_p=0.0, trace_cache=trace_cache)
        res = rt.run(prog, force_execute=True)
        reports.append({f: getattr(res, f) for f in fields})
        states.append(rt._rng.bit_generator.state)
    assert reports[0] == reports[1], (
        f"batched cold report diverged: {reports[0]} vs {reports[1]}")
    assert states[0] == states[1], (
        "batched cold run consumed a different RNG stream")
    return {"world_size": world_size, "events": len(ev_batched),
            "report": reports[0]}


_RATE_FIELDS = ("events_per_sec", "events_per_sec_warm",
                "events_per_sec_cold", "events_per_sec_cold_batched",
                "events_per_sec_cold_scalar")


def run(world_sizes=(16, 64, 256), *, selective_iters: int = 6,
        best_of: int = 1) -> dict:
    """``best_of > 1`` repeats each world size's study and keeps the
    per-metric maxima (runs list from the best-warm repeat): the shared CI
    box swings 2-4x between moments, and best-of-N is the same noise
    reduction check.sh applies to its gate."""
    results = []
    for ws in world_sizes:
        reps = [bench_study(ws, selective_iters=selective_iters)
                for _ in range(best_of)]
        r = max(reps, key=lambda x: x["events_per_sec_warm"])
        for f in _RATE_FIELDS:
            r[f] = max(rep[f] for rep in reps)
        r["cold_speedup_vs_scalar"] = max(rep["cold_speedup_vs_scalar"]
                                          for rep in reps)
        print(f"world={ws:4d}  events={r['total_events']:9d}  "
              f"wall={r['total_wall_s']:8.3f}s  "
              f"events/sec={r['events_per_sec']:10.1f}  "
              f"warm={r['events_per_sec_warm']:10.1f}  "
              f"cold={r['events_per_sec_cold']:9.1f}  "
              f"cold_batched={r['events_per_sec_cold_batched']:9.1f}  "
              f"(vs scalar {r['cold_speedup_vs_scalar']:.2f}x)")
        results.append(r)
    return {
        "meta": {
            "benchmark": "engine-throughput",
            "python": platform.python_version(),
            "machine": platform.machine(),
            "best_of": best_of,
        },
        "results": results,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="world 16+64 only, fewer iterations (~10 s)")
    ap.add_argument("--verify", action="store_true",
                    help="run the cold-path identity assertions and exit")
    ap.add_argument("--best-of", type=int, default=1,
                    help="repeat each world size N times, keep per-metric "
                         "maxima (noise reduction on shared boxes)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    if args.verify:
        summary = verify_cold_path()
        print(f"cold-path verify OK: {summary['events']} events, "
              f"report {summary['report']}")
        return
    if args.quick:
        out = run(world_sizes=(16, 64), selective_iters=4,
                  best_of=args.best_of)
    else:
        out = run(best_of=args.best_of)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
