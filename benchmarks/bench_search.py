"""Model-guided search: grid coverage vs winner quality.

Sweeps the ``model_guided`` driver's measurement budget (``top_k``
survivors handed to racing) against the exhaustive reference on the
CI-scale paper grids and records, per budget, how much of the grid was
actually measured and how close the landed winner is to the true optimum
(``quality = t_best / t_winner`` over the exhaustive per-point times).

Two grids, two bank provenances:

- **capital-cholesky** seeds the copula from the committed transfer
  artifact (``results/capital-cholesky-ci_stats_bank.json``) — the
  cross-session warm start the PR-8 acceptance gate pins;
- **slate-cholesky** self-harvests its bank from the exhaustive
  reference run, the "tune once, model forever" loop for a study with
  no recorded history.

Run: ``PYTHONPATH=src python -m benchmarks.bench_search``
(or through ``benchmarks.run --sections search``).
"""

from __future__ import annotations

import argparse
import os
import time
from typing import List, Optional, Sequence

from repro.api import AutotuneSession, SimBackend, StatisticsBank
from repro.core.tuner import space_of_study
from repro.linalg.studies import STUDIES

from .common import ART, fmt_table, save_rows

COLS = ("study", "run", "top_k", "dispatched", "coverage", "winner",
        "matches", "quality", "pruned", "bench_wall_s")


def _study_rows(study: str, scale: str, top_ks: Sequence[int],
                bank: Optional[StatisticsBank], *, policy: str,
                tolerance: float, trials: int, seed: int) -> List[dict]:
    space = space_of_study(STUDIES[study](scale))

    def session(**kw):
        return AutotuneSession(space, backend=SimBackend(), policy=policy,
                               tolerance=tolerance, trials=trials, **kw)

    full = session(search="exhaustive",
                   collect_stats=bank is None).run()
    times = {r.name: r.predicted for r in full.records}
    t_best = min(times.values())
    if bank is None:
        bank = full.stats_bank()        # self-harvested reference bank
        provenance = "self-harvested"
    else:
        provenance = "committed artifact"
    rows = [{
        "study": study, "run": "exhaustive", "top_k": len(space),
        "dispatched": len(space), "coverage": 1.0,
        "winner": full.chosen.name, "matches": True, "quality": 1.0,
        "pruned": 0, "bench_wall_s": round(full.wall_s, 1),
    }]
    print(f"{study}: exhaustive reference over {len(space)} points, "
          f"winner {full.chosen.name!r}, bank {len(bank)} kernels "
          f"({provenance})")

    for k in top_ks:
        guided = session(
            search="model_guided",
            search_options={"banks": [bank], "seed": seed, "top_k": k,
                            "max_coverage": 1.0}).run()
        winner = guided.extra["best"]
        rows.append({
            "study": study, "run": "model-guided", "top_k": k,
            "dispatched": len(guided.extra["dispatched"]),
            "coverage": guided.extra["coverage"],
            "winner": winner, "matches": winner == full.chosen.name,
            "quality": t_best / times[winner],
            "pruned": len(guided.extra["roofline_pruned"]),
            "bench_wall_s": round(guided.wall_s, 1),
        })
    return rows


def run(scale: str = "ci", top_ks: Sequence[int] = (1, 2, 4, 8),
        policy: str = "eager", tolerance: float = 0.25,
        trials: int = 2, seed: int = 0) -> List[dict]:
    t0 = time.time()
    committed = os.path.join(ART, "capital-cholesky-ci_stats_bank.json")
    rows = _study_rows(
        "capital-cholesky", scale, top_ks,
        StatisticsBank.load(committed) if os.path.exists(committed)
        else None,
        policy=policy, tolerance=tolerance, trials=trials, seed=seed)
    rows += _study_rows("slate-cholesky", scale, top_ks, None,
                        policy=policy, tolerance=tolerance, trials=trials,
                        seed=seed)

    print(f"\n== model-guided search: coverage vs winner quality "
          f"({scale} scale, {policy} @ {tolerance}) ==")
    print(fmt_table(rows, COLS))

    # acceptance: at every budget the winner must stay within 1% of the
    # exhaustive optimum — the sampler may measure less, never choose worse
    bad = [(r["study"], r["top_k"]) for r in rows if r["quality"] < 0.99]
    if bad:
        raise SystemExit("search acceptance failed: winner quality "
                         f"< 0.99 at {bad}")
    least = min((r for r in rows if r["run"] == "model-guided"),
                key=lambda r: r["coverage"])
    print(f"\nleanest budget: {least['study']} top_k={least['top_k']} "
          f"measured {least['coverage']:.1%} of the grid at quality "
          f"{least['quality']:.3f}")
    print(f"total wall: {time.time() - t0:.1f}s")
    save_rows("search", rows)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="ci", choices=["ci", "paper"])
    ap.add_argument("--top-ks", type=int, nargs="*", default=[1, 2, 4, 8])
    ap.add_argument("--policy", default="eager")
    ap.add_argument("--tolerance", type=float, default=0.25)
    ap.add_argument("--trials", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(scale=args.scale, top_ks=args.top_ks, policy=args.policy,
        tolerance=args.tolerance, trials=args.trials, seed=args.seed)


if __name__ == "__main__":
    main()
