"""Paper Figures 4 and 5: autotuning speedup + prediction error for the
four dense-factorization case studies, per policy x confidence tolerance.

Reproduced claims checked (printed as PASS/FAIL at the end):
  C1  speedup grows as the tolerance loosens (every study, every policy)
  C2  eager >> conditional for the bulk-synchronous Capital study
  C3  mean prediction error decreases systematically with epsilon
  C4  the chosen configuration achieves >= 99% of the optimum's performance
  C5  CANDMC: overall speedup modest even when kernel-time speedup is large
      (many distinct signatures from the shrinking trailing matrix)
"""

from __future__ import annotations

import argparse
from collections import defaultdict

import numpy as np

from repro.linalg.studies import STUDIES

from .common import EPS_FAST, EPS_FULL, fmt_table, save_rows, sweep_study

COLS = ("study", "policy", "tolerance", "speedup", "mean_error",
        "mean_comp_error", "optimum_quality")


def run(fast: bool = True, studies=None, policies=None, workers: int = 1):
    eps = EPS_FAST if fast else EPS_FULL
    studies = studies or list(STUDIES)
    policies = policies or ("conditional", "local", "online", "apriori",
                            "eager")
    all_rows = []
    for name in studies:
        rows = sweep_study(STUDIES[name], eps=eps, policies=policies,
                           trials=3 if fast else 5, workers=workers)
        all_rows.extend(rows)
        print(f"\n== {name} (CI scale) ==")
        print(fmt_table(rows, COLS))
    save_rows("case_studies", all_rows)
    _check_claims(all_rows)
    return all_rows


def _check_claims(rows):
    by = defaultdict(dict)
    for r in rows:
        by[(r["study"], r["policy"])][r["tolerance"]] = r

    def claim(name, ok, detail=""):
        print(f"  [{'PASS' if ok else 'FAIL'}] {name} {detail}")

    print("\n== paper-claim validation ==")
    # C1: speedup monotone-ish in tolerance (allow small noise)
    ok1 = True
    for (study, pol), pts in by.items():
        tols = sorted(pts)
        sp = [pts[t]["speedup"] for t in tols]
        if sp[-1] < sp[0] * 0.95:        # loosest should beat tightest
            ok1 = False
    claim("C1 speedup grows with tolerance", ok1)
    # C2: eager >> conditional on capital
    cap = [s for s, _ in by if "capital" in s]
    if cap:
        s = cap[0]
        loosest = max(t for t in by[(s, "eager")])
        r_e = by[(s, "eager")][loosest]["speedup"]
        r_c = by[(s, "conditional")][loosest]["speedup"]
        claim("C2 eager >> conditional (capital)", r_e > 2 * r_c,
              f"eager {r_e:.1f}x vs conditional {r_c:.1f}x")
    # C3: error decreases with epsilon
    ok3 = 0
    tot3 = 0
    for (study, pol), pts in by.items():
        tols = sorted(pts)
        if len(tols) >= 2:
            tot3 += 1
            if pts[tols[0]]["mean_error"] <= pts[tols[-1]]["mean_error"] \
                    + 0.05:
                ok3 += 1
    claim("C3 error decreases with epsilon",
          ok3 >= 0.8 * tot3, f"({ok3}/{tot3} policy-study series)")
    # C4: optimum quality
    q = [r["optimum_quality"] for r in rows]
    claim("C4 chosen config >= 99% of optimum",
          float(np.mean([x >= 0.99 for x in q])) >= 0.9,
          f"(mean quality {np.mean(q):.4f})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--studies", nargs="*", default=None)
    ap.add_argument("--workers", type=int, default=1)
    args = ap.parse_args()
    run(fast=not args.full, studies=args.studies, workers=args.workers)


if __name__ == "__main__":
    main()
