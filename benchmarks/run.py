"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Sections:
  1. paper case studies (Figs 4-5 protocol, CI scale)
  2. beyond-paper: racing + extrapolation
  3. LM autotune (the technique on our framework, measured)
  4. roofline table from the dry-run artifacts (if present)

``--full`` widens epsilon sweeps and architectures.
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--sections", nargs="*",
                    default=["case", "beyond", "lm", "roofline"])
    args = ap.parse_args(argv)
    fast = not args.full
    t0 = time.time()

    if "case" in args.sections:
        from . import bench_case_studies
        bench_case_studies.run(fast=fast)
    if "beyond" in args.sections:
        from . import bench_beyond_paper
        bench_beyond_paper.run(fast=fast)
    if "lm" in args.sections:
        from . import bench_lm_autotune
        bench_lm_autotune.run(fast=fast)
    if "roofline" in args.sections:
        try:
            from . import roofline
            sys.argv = ["roofline"]
            roofline.main()
        except Exception as e:
            print(f"[roofline] skipped: {e}")
    print(f"\nbenchmarks done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
