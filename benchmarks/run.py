"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Sections:
  1. paper case studies (Figs 4-5 protocol, CI scale)
  2. beyond-paper: racing + extrapolation
  3. LM autotune (the technique on our framework, measured)
  4. cold-vs-warm statistics transfer on Capital (bench_transfer)
  5. model-guided search: coverage vs winner quality (bench_search)
  6. roofline table from the dry-run artifacts (if present)

``--full`` widens epsilon sweeps and architectures.  ``--paper`` adds the
paper-scale sweep (real processor counts, checkpointed + process-parallel
via the session API; see ``bench_paper``); ``--quick`` shrinks it to the
nightly-CI slice and ``--bank PATH`` warm-starts it from a recorded
``StatisticsBank`` (the nightly job seeds from
``results/capital-cholesky-ci_stats_bank.json``).  ``--workers N``
parallelizes the sim-study sweeps (N=0: one per CPU).
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--paper", action="store_true",
                    help="also run the paper-scale sweep at real "
                         "processor counts (slow; resumable)")
    ap.add_argument("--workers", type=int, default=None,
                    help="process-parallel sweep workers (0 = per CPU; "
                         "default: per CPU for --paper, serial otherwise)")
    ap.add_argument("--quick", action="store_true",
                    help="with --paper: the nightly-CI slice "
                         "(eager @ tol 0.25, 2 trials)")
    ap.add_argument("--bank", default=None,
                    help="with --paper: StatisticsBank JSON warm-starting "
                         "the sweep")
    ap.add_argument("--sections", nargs="*",
                    default=["case", "beyond", "lm", "transfer",
                             "search", "roofline"])
    args = ap.parse_args(argv)
    fast = not args.full
    workers = args.workers if args.workers is not None \
        else (0 if args.paper else 1)
    t0 = time.time()

    if args.paper:
        from . import bench_paper
        bench_paper.run(workers=workers, quick=args.quick, bank=args.bank)
    if "case" in args.sections:
        from . import bench_case_studies
        bench_case_studies.run(fast=fast, workers=workers)
    if "beyond" in args.sections:
        from . import bench_beyond_paper
        bench_beyond_paper.run(fast=fast)
    if "lm" in args.sections:
        from . import bench_lm_autotune
        bench_lm_autotune.run(fast=fast)
    if "transfer" in args.sections:
        from . import bench_transfer
        bench_transfer.run(trials=2 if fast else 3)
    if "search" in args.sections:
        from . import bench_search
        bench_search.run(top_ks=[1, 2, 4] if fast else [1, 2, 4, 8])
    if "roofline" in args.sections:
        try:
            from . import roofline
            sys.argv = ["roofline"]
            roofline.main()
        except Exception as e:
            print(f"[roofline] skipped: {e}")
    print(f"\nbenchmarks done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
