"""Shared benchmark utilities: epsilon sweeps, tables, JSON dumps.

Sweeps run through ``repro.api.AutotuneSession`` — ``workers=N`` forks one
process per in-flight sweep point (bit-identical to serial, merged in grid
order) and ``checkpoint=path`` makes long sweeps resumable.
"""

from __future__ import annotations

import json
import os
from typing import List, Optional, Sequence

from repro.api import AutotuneSession, SimBackend, StudyResult
from repro.core.policies import POLICIES
from repro.core.tuner import space_of_study

ART = os.path.join(os.path.dirname(__file__), "results")

EPS_FULL = (1.0, 0.5, 0.25, 0.125, 0.0625, 0.03125)
EPS_FAST = (1.0, 0.25, 0.0625)


def sweep_session(make_study, *, trials: int = 3, scale: str = "ci",
                  prior=None,
                  prior_discount: float = 0.5) -> AutotuneSession:
    """Session over a paper study; ``make_study(scale)`` is one of
    ``repro.linalg.studies.STUDIES``.  ``prior`` is a ``StatisticsBank``
    warm-starting every study of the sweep (repro.api.transfer);
    ``prior_discount=1.0`` keeps its full evidence (same-machine,
    same-cost-model banks need no widening)."""
    return AutotuneSession(space_of_study(make_study(scale)),
                           backend=SimBackend(), trials=trials,
                           prior=prior, prior_discount=prior_discount)


def sweep_study(make_study, *, policies: Sequence[str] = POLICIES,
                eps: Sequence[float] = EPS_FAST, trials: int = 3,
                seeds: Sequence[int] = (0,), allocations=(0,),
                scale: str = "ci", workers: int = 1,
                checkpoint: Optional[str] = None,
                prior=None, prior_discount: float = 0.5,
                share_stats: bool = False,
                deterministic: bool = False,
                executor=None) -> List[dict]:
    """The paper's measurement protocol (§VI.A): for each policy x epsilon
    (x allocation), run the full exhaustive autotune and record speedup,
    mean prediction error, optimum quality.  ``workers=0`` means one per
    CPU; ``share_stats``/``deterministic``/``executor`` pass through to
    ``AutotuneSession.sweep`` (mid-sweep statistics sharing; remote
    workers)."""
    if workers <= 0:
        # floor of 2 so single-core boxes still go through the fork pool
        # (bit-identical to serial) instead of silently degenerating
        workers = max(os.cpu_count() or 1, 2)
    session = sweep_session(make_study, trials=trials, scale=scale,
                            prior=prior, prior_discount=prior_discount)
    results = session.sweep(policies=policies, tolerances=eps, seeds=seeds,
                            allocations=allocations, workers=workers,
                            checkpoint=checkpoint, share_stats=share_stats,
                            deterministic=deterministic, executor=executor)
    return [result_row(r) for r in results]


def result_row(r: StudyResult) -> dict:
    row = r.row()
    row.update(seed=r.seed, allocation=r.allocation,
               chosen=r.chosen.name,
               bench_wall_s=round(r.wall_s, 1))
    return row


def fmt_table(rows: List[dict], cols: Sequence[str], *,
              floatfmt: str = "{:.3g}") -> str:
    widths = {c: max(len(c), *(len(_fmt(r.get(c), floatfmt))
                               for r in rows)) for c in cols}
    head = " | ".join(c.ljust(widths[c]) for c in cols)
    sep = "-|-".join("-" * widths[c] for c in cols)
    body = "\n".join(
        " | ".join(_fmt(r.get(c), floatfmt).ljust(widths[c]) for c in cols)
        for r in rows)
    return f"{head}\n{sep}\n{body}"


def _fmt(v, floatfmt):
    if isinstance(v, float):
        return floatfmt.format(v)
    return str(v)


def save_rows(name: str, rows: List[dict]):
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, name + ".json"), "w") as f:
        json.dump(rows, f, indent=1)
