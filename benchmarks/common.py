"""Shared benchmark utilities: epsilon sweeps, tables, JSON dumps."""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Sequence

from repro.core.policies import POLICIES, policy
from repro.core.tuner import Autotuner, Study

ART = os.path.join(os.path.dirname(__file__), "results")

EPS_FULL = (1.0, 0.5, 0.25, 0.125, 0.0625, 0.03125)
EPS_FAST = (1.0, 0.25, 0.0625)


def sweep_study(make_study, *, policies: Sequence[str] = POLICIES,
                eps: Sequence[float] = EPS_FAST, trials: int = 3,
                seeds: Sequence[int] = (0,), allocations=(0,),
                scale: str = "ci") -> List[dict]:
    """The paper's measurement protocol (§VI.A): for each policy x epsilon
    (x allocation), run the full exhaustive autotune and record speedup,
    mean prediction error, optimum quality."""
    rows = []
    for pol in policies:
        for e in eps:
            for seed in seeds:
                for alloc in allocations:
                    study = make_study(scale)
                    tuner = Autotuner(study, policy(pol, tolerance=e),
                                      trials=trials, seed=seed,
                                      allocation=alloc)
                    t0 = time.time()
                    rep = tuner.tune()
                    row = rep.row()
                    row.update(seed=seed, allocation=alloc,
                               bench_wall_s=round(time.time() - t0, 1))
                    rows.append(row)
    return rows


def fmt_table(rows: List[dict], cols: Sequence[str], *,
              floatfmt: str = "{:.3g}") -> str:
    widths = {c: max(len(c), *(len(_fmt(r.get(c), floatfmt))
                               for r in rows)) for c in cols}
    head = " | ".join(c.ljust(widths[c]) for c in cols)
    sep = "-|-".join("-" * widths[c] for c in cols)
    body = "\n".join(
        " | ".join(_fmt(r.get(c), floatfmt).ljust(widths[c]) for c in cols)
        for r in rows)
    return f"{head}\n{sep}\n{body}"


def _fmt(v, floatfmt):
    if isinstance(v, float):
        return floatfmt.format(v)
    return str(v)


def save_rows(name: str, rows: List[dict]):
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, name + ".json"), "w") as f:
        json.dump(rows, f, indent=1)
