"""The paper's technique on OUR framework: selective wall-clock autotuning
of LM step-function configurations (reduced archs, real CPU timing).

For each policy x tolerance: exhaustively benchmark the StepKnobs space
with SelectiveTimer; report autotuning speedup (vs full re-timing), mean
prediction error vs a directly-prior full execution, and whether the chosen
configuration matches the full-execution optimum.
"""

from __future__ import annotations

import argparse
import time
from collections import defaultdict

import numpy as np

from repro.core.policies import policy
from repro.tune import LMStudy, SelectiveTimer, lm_config_space

from .common import fmt_table, save_rows


def run_arch(arch: str, *, policies=("conditional", "local", "eager"),
             eps=(0.5, 0.25, 0.1), iters=3, max_configs=8, seed=0):
    study = LMStudy(arch, batch=2, seq=32, seed=seed)
    space = lm_config_space(study.cfg)[:max_configs]
    rows = []
    for pol in policies:
        for e in eps:
            timer = SelectiveTimer(policy(pol, tolerance=e, min_samples=3))
            full_time = 0.0
            sel_time = 0.0
            preds, fulls = [], []
            for kn in space:
                if not timer.policy.persistent_models:
                    timer.reset_models()
                pred, full, cost = study.run_config(kn, timer, iters=iters)
                preds.append(pred)
                fulls.append(full)
                full_time += full * iters
                sel_time += cost
            errs = [abs(p - f) / f for p, f in zip(preds, fulls)]
            best_pred = int(np.argmin(preds))
            best_full = int(np.argmin(fulls))
            rows.append({
                "arch": arch, "policy": pol, "tolerance": e,
                "speedup": full_time / max(sel_time, 1e-12),
                "mean_error": float(np.mean(errs)),
                "optimum_match": space[best_pred].name
                == space[best_full].name,
                "chosen": space[best_pred].name,
            })
    return rows


def run(fast=True, archs=None):
    archs = archs or (["smollm-135m"] if fast
                      else ["smollm-135m", "phi3.5-moe-42b-a6.6b",
                            "xlstm-125m"])
    all_rows = []
    for arch in archs:
        rows = run_arch(arch, eps=(0.5, 0.1) if fast else (0.5, 0.25, 0.1))
        all_rows.extend(rows)
        print(f"\n== LM autotune: {arch} (reduced, measured) ==")
        print(fmt_table(rows, ("policy", "tolerance", "speedup",
                               "mean_error", "optimum_match", "chosen")))
    save_rows("lm_autotune", all_rows)
    return all_rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--archs", nargs="*", default=None)
    args = ap.parse_args()
    run(fast=not args.full, archs=args.archs)


if __name__ == "__main__":
    main()
