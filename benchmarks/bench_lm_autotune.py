"""The paper's technique on OUR framework: selective wall-clock autotuning
of LM step-function configurations (reduced archs, real CPU timing).

For each policy x tolerance: exhaustively benchmark the StepKnobs space
with SelectiveTimer; report autotuning speedup (vs full re-timing), mean
prediction error vs a directly-prior full execution, and whether the chosen
configuration matches the full-execution optimum.
"""

from __future__ import annotations

import argparse

from repro.api import AutotuneSession, WallClockBackend
from repro.tune import LMStudy

from .common import fmt_table, save_rows


def run_arch(arch: str, *, policies=("conditional", "local", "eager"),
             eps=(0.5, 0.25, 0.1), iters=3, max_configs=8, seed=0):
    study = LMStudy(arch, batch=2, seq=32, seed=seed)
    session = AutotuneSession(study.search_space(max_configs),
                              backend=WallClockBackend(study.kernels_of),
                              trials=iters, min_samples=3)
    # wall-clock measurements stay serial: forked workers would contend
    # for the CPU and corrupt each other's timings
    results = session.sweep(policies=list(policies), tolerances=list(eps))
    rows = []
    for r in results:
        rows.append({
            "arch": arch, "policy": r.policy, "tolerance": r.tolerance,
            "speedup": r.speedup,
            "mean_error": r.mean_error,
            "optimum_match": r.chosen.name == r.true_best.name,
            "chosen": r.chosen.name,
        })
    return rows


def run(fast=True, archs=None):
    archs = archs or (["smollm-135m"] if fast
                      else ["smollm-135m", "phi3.5-moe-42b-a6.6b",
                            "xlstm-125m"])
    all_rows = []
    for arch in archs:
        rows = run_arch(arch, eps=(0.5, 0.1) if fast else (0.5, 0.25, 0.1))
        all_rows.extend(rows)
        print(f"\n== LM autotune: {arch} (reduced, measured) ==")
        print(fmt_table(rows, ("policy", "tolerance", "speedup",
                               "mean_error", "optimum_match", "chosen")))
    save_rows("lm_autotune", all_rows)
    return all_rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--archs", nargs="*", default=None)
    args = ap.parse_args()
    run(fast=not args.full, archs=args.archs)


if __name__ == "__main__":
    main()
