"""The paper's technique on OUR framework: selective wall-clock autotuning
of LM step-function configurations (reduced archs, real CPU timing).

For each policy x tolerance: exhaustively benchmark the StepKnobs space
with SelectiveTimer; report autotuning speedup (vs full re-timing), mean
prediction error vs a directly-prior full execution, and whether the chosen
configuration matches the full-execution optimum.  A racing section then
runs the same space through wall-clock successive elimination
(``LMStudy.race``: each round one selective trial per survivor, prune on
CI separation) and reports the winner and its measured cost next to the
exhaustive study's — the search-space-pruning composition the paper
suggests, on real timings.
"""

from __future__ import annotations

import argparse

from repro.tune import LMStudy

from .common import fmt_table, save_rows


def run_arch(arch: str, *, policies=("conditional", "local", "eager"),
             eps=(0.5, 0.25, 0.1), iters=3, max_configs=8, seed=0,
             race_tolerance=0.25):
    study = LMStudy(arch, batch=2, seq=32, seed=seed)
    session = study.session(max_configs=max_configs, trials=iters,
                            min_samples=3)
    # wall-clock measurements stay serial: the scheduler keeps
    # non-parallel_safe backends on the in-process executor (forked
    # workers would contend for the CPU and corrupt each other's timings)
    results = session.sweep(policies=list(policies), tolerances=list(eps))
    rows = []
    for r in results:
        rows.append({
            "arch": arch, "policy": r.policy, "tolerance": r.tolerance,
            "speedup": r.speedup,
            "mean_error": r.mean_error,
            "optimum_match": r.chosen.name == r.true_best.name,
            "chosen": r.chosen.name,
        })
    # racing: wall-clock successive elimination over the same space
    raced = study.race(tolerance=race_tolerance, max_configs=max_configs,
                       min_samples=3)
    exhaustive_cost = min(r.selective_tuning_time for r in results)
    # racing has no full-execution reference of its own: judge its winner
    # against the exhaustive studies' full-execution optima (per-study
    # true_best; a set because wall-clock noise can flip near-ties)
    optima = {r.true_best.name for r in results}
    rows.append({
        "arch": arch, "policy": f"racing/{raced.policy}",
        "tolerance": raced.tolerance, "speedup": None,
        "mean_error": None,
        "optimum_match": raced.extra["best"] in optima,
        "chosen": raced.extra["best"],
        "racing_cost_s": raced.extra["cost"],
        "racing_iterations": raced.extra["total_iterations"],
        "exhaustive_cost_s": exhaustive_cost,
    })
    return rows


def run(fast=True, archs=None):
    archs = archs or (["smollm-135m"] if fast
                      else ["smollm-135m", "phi3.5-moe-42b-a6.6b",
                            "xlstm-125m"])
    all_rows = []
    for arch in archs:
        rows = run_arch(arch, eps=(0.5, 0.1) if fast else (0.5, 0.25, 0.1))
        all_rows.extend(rows)
        print(f"\n== LM autotune: {arch} (reduced, measured) ==")
        print(fmt_table(rows, ("policy", "tolerance", "speedup",
                               "mean_error", "optimum_match", "chosen")))
        race_row = rows[-1]
        print(f"racing winner {race_row['chosen']!r} in "
              f"{race_row['racing_iterations']} iterations, "
              f"{race_row['racing_cost_s']:.3g}s measured "
              f"(exhaustive best-policy cost "
              f"{race_row['exhaustive_cost_s']:.3g}s)")
    save_rows("lm_autotune", all_rows)
    return all_rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--archs", nargs="*", default=None)
    args = ap.parse_args()
    run(fast=not args.full, archs=args.archs)


if __name__ == "__main__":
    main()
