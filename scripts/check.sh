#!/usr/bin/env bash
# Smoke check: tier-1 tests + a quick engine-throughput sanity run that
# fails on a sustained warm-events/sec regression vs the committed
# BENCH_engine.json.
#
# The CI container is multi-tenant and its throughput swings 2-4x between
# runs, so the gate is deliberately coarse: best-of-3 quick runs at
# world_size=64 (the acceptance geometry; world 16 is too small to time
# reliably) must reach CHECK_RATIO (default 0.5) of the committed warm
# baseline.  A real engine regression (the seed engine is ~7x below the
# baseline) still fails decisively.
#
# Usage:  bash scripts/check.sh [--skip-tests]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" != "--skip-tests" ]]; then
    echo "== tier-1 tests =="
    python -m pytest -x -q
fi

echo "== engine throughput sanity (quick, best of 3) =="
python - <<'EOF'
import json
import os
import sys

sys.path.insert(0, os.getcwd())
from benchmarks.bench_engine import bench_study

RATIO = float(os.environ.get("CHECK_RATIO", "0.5"))

with open("BENCH_engine.json") as f:
    base = {r["world_size"]: r for r in json.load(f)["results"]}
ref = base[64]["events_per_sec_warm"]

best = 0.0
for attempt in range(3):
    r = bench_study(64, selective_iters=4)
    got = r["events_per_sec_warm"]
    best = max(best, got)
    print(f"  attempt {attempt + 1}: warm events/sec {got:12.1f} "
          f"(baseline {ref:.1f}, ratio {got / ref:.2f})")
    if best >= RATIO * ref:
        break

if best < RATIO * ref:
    print(f"FAIL: best warm throughput {best:.1f} < "
          f"{RATIO:.0%} of baseline {ref:.1f}")
    sys.exit(1)
print(f"OK: best warm throughput {best:.1f} >= {RATIO:.0%} of "
      f"baseline {ref:.1f}")
EOF

echo "== session-API smoke (serial vs 2-worker sweep) =="
python - <<'EOF'
import sys

from repro.api import AutotuneSession, ConfigPoint, SearchSpace, SimBackend
from repro.linalg import slate_cholesky

space = SearchSpace(name="smoke-slate", world_size=16, points=[
    ConfigPoint(name="t64-la1", params={"tile": 64},
                payload=lambda w: slate_cholesky.make_program(
                    w, n=512, tile=64, lookahead=1, pr=4, pc=4)),
    ConfigPoint(name="t128-la0", params={"tile": 128},
                payload=lambda w: slate_cholesky.make_program(
                    w, n=512, tile=128, lookahead=0, pr=4, pc=4)),
])

def sweep(workers):
    session = AutotuneSession(space, backend=SimBackend(), trials=2)
    return session.sweep(policies=["conditional", "eager"],
                         tolerances=[0.25], workers=workers)

def strip(r):
    d = r.to_json()
    d.pop("wall_s")
    return d

serial = sweep(1)
forked = sweep(2)
if [strip(r) for r in serial] != [strip(r) for r in forked]:
    print("FAIL: 2-worker sweep diverged from the serial run")
    sys.exit(1)
for r in serial:
    if not (r.speedup > 0 and len(r.records) == 2):
        print(f"FAIL: degenerate study result {r.row()}")
        sys.exit(1)
print(f"OK: session API serial == 2-worker "
      f"({[round(r.speedup, 2) for r in serial]} speedups)")
EOF

echo "== transfer smoke (cold -> bank -> warm) =="
python - <<'EOF'
import sys

sys.path.insert(0, "tests")
from repro.api import AutotuneSession, SimBackend, StatisticsBank
from repro.core.tuner import space_of_study
from golden_runner import _studies

space = space_of_study(_studies()[1])       # tiny Capital study, world 8

def session(**kw):
    return AutotuneSession(space, backend=SimBackend(), policy="eager",
                           tolerance=0.25, trials=2, **kw)

cold = session(collect_stats=True).run()
bank = cold.stats_bank()
if not bank:
    print("FAIL: cold study harvested an empty statistics bank")
    sys.exit(1)
# the bank must survive a JSON round trip before it seeds anything
bank = StatisticsBank.from_json(bank.to_json())
warm = session(prior=bank).run()
cold_exec = sum(r.executed for r in cold.records)
warm_exec = sum(r.executed for r in warm.records)
if warm.chosen.name != cold.chosen.name:
    print(f"FAIL: warm study chose {warm.chosen.name!r}, "
          f"cold chose {cold.chosen.name!r}")
    sys.exit(1)
if warm_exec >= cold_exec:
    print(f"FAIL: warm study executed {warm_exec} kernel invocations "
          f"(cold: {cold_exec}) — transfer bought nothing")
    sys.exit(1)
print(f"OK: warm run kept winner {cold.chosen.name!r}, executed "
      f"{cold_exec} -> {warm_exec} kernel invocations")
EOF

echo "== hypothesis property-suite guard =="
# the core-stats property tests are optional-dep-guarded; if hypothesis IS
# available they must actually run — a skip then means the guard rotted.
if python -c "import hypothesis" 2>/dev/null; then
    out=$(python -m pytest tests/test_core_stats.py -q -rs) || {
        echo "$out"; exit 1; }
    echo "$out" | tail -n 3
    if printf '%s' "$out" | grep -qi "skipped"; then
        echo "FAIL: hypothesis is installed but the core-stats property"
        echo "      suite skipped tests anyway:"
        printf '%s\n' "$out" | grep -i skip
        exit 1
    fi
    echo "OK: property suite ran under hypothesis with no skips"
else
    echo "hypothesis not installed: hypothesis-driven cases skip by design"
    echo "(the seeded-fallback property tests still run in tier-1)"
fi
