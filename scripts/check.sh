#!/usr/bin/env bash
# Repo gate: tier-1 tests + engine-throughput sanity + session-API smoke +
# scheduler (fork + localhost-remote-worker) smoke + transfer smoke +
# chaos (supervised fleet with fault injection) smoke + always-on tuning
# daemon smoke + model-guided search gate (<10% grid coverage, exhaustive
# winner) + hypothesis property-suite guard.
#
# Usage:
#   bash scripts/check.sh                      # all stages
#   bash scripts/check.sh --stage engine       # one stage (CI parallelism)
#   bash scripts/check.sh --skip-tests         # legacy: all but tests
#   bash scripts/check.sh --out results.json   # summary path
#
# Stages: tests, engine, session, scheduler, transfer, chaos, daemon,
# search, hypothesis.
#
# Every invocation writes a per-stage JSON summary (exit code, wall
# seconds, measured throughput ratios where applicable) to
# check_results.json so CI can parallelize stages and upload artifacts.
#
# The CI container is multi-tenant and its throughput swings 2-4x between
# runs, so the engine gate is deliberately coarse: best-of-3 quick runs at
# world_size=64 (the acceptance geometry; world 16 is too small to time
# reliably) must reach CHECK_RATIO (default 0.5) of the committed warm AND
# batched-cold baselines in BENCH_engine.json.  A real engine regression
# (the seed engine is ~7x below the warm baseline, the scalar cold path
# ~2x below the cold one) still fails decisively.  PR 9 adds a
# box-noise-immune signal on top: the compiled warm program's SAME-SESSION
# speedup over the scalar interpreter must stay >= max(1.0, CHECK_RATIO x
# the committed warm_speedup_vs_scalar), and the engine stage verifies
# compiled-path + counter-RNG bit-identity before timing anything.  PR 10
# adds program-cache replay identity to those verifies, and the scheduler
# stage asserts the remote worker's sweep-scoped program cache actually
# replays across tasks (>= 1 hit, exactly one recording per geometry),
# emitting the hit/miss ratio into check_results.json.
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

STAGE="all"
OUT="check_results.json"
while [[ $# -gt 0 ]]; do
    case "$1" in
        --stage) STAGE="$2"; shift 2 ;;
        --out) OUT="$2"; shift 2 ;;
        --skip-tests) STAGE="no-tests"; shift ;;
        *) echo "unknown argument: $1" >&2; exit 2 ;;
    esac
done

SUMMARY_ROWS=()
OVERALL=0

record_stage() {
    # record_stage <name> <exit> <wall> <extra-json-fragment>
    local extra="${4:-}"
    [[ -n "$extra" ]] && extra=", $extra"
    SUMMARY_ROWS+=("{\"stage\": \"$1\", \"exit_code\": $2, \"wall_s\": $3$extra}")
    [[ "$2" -ne 0 ]] && OVERALL=1
    return 0
}

run_stage() {
    # run_stage <name> <fn> — time the stage fn, capture its exit code and
    # any RATIO_JSON line it prints (machine-readable stage extras)
    local name="$1" fn="$2" t0 t1 ec out wall extra
    echo "== stage: $name =="
    t0=$(python -c 'import time; print(f"{time.time():.3f}")')
    out="$("$fn" 2>&1)"; ec=$?
    t1=$(python -c 'import time; print(f"{time.time():.3f}")')
    wall=$(python -c "print(f'{$t1 - $t0:.1f}')")
    printf '%s\n' "$out" | grep -v '^RATIO_JSON '
    extra="$(printf '%s\n' "$out" | sed -n 's/^RATIO_JSON //p' | tail -n 1)"
    record_stage "$name" "$ec" "$wall" "$extra"
    if [[ $ec -eq 0 ]]; then
        echo "-- $name OK (${wall}s)"
    else
        echo "-- $name FAILED (exit $ec, ${wall}s)"
    fi
}

stage_tests() {
    python -m pytest -x -q
}

stage_engine() {
    python - <<'EOF'
import json
import os
import sys

sys.path.insert(0, os.getcwd())
from benchmarks.bench_engine import (bench_study, verify_cold_path,
                                     verify_compiled_path,
                                     verify_counter_rng,
                                     verify_program_cache)

RATIO = float(os.environ.get("CHECK_RATIO", "0.5"))

summary = verify_cold_path(16)
print(f"cold-path identity OK ({summary['events']} events)")
summary = verify_compiled_path(16)
seg = summary["compiled"]
print(f"compiled-path identity OK ({summary['configs']} policy x "
      f"straggler configs; {seg['segments']} segments, "
      f"{seg['fused_events']} fused events)")
summary = verify_counter_rng(16)
print(f"counter-RNG identity OK ({summary['draws']} draws)")
summary = verify_program_cache(16)
print(f"program-cache identity OK ({summary['events']} events replayed "
      f"bit-identical; store {summary['store']})")

with open("BENCH_engine.json") as f:
    base = {r["world_size"]: r for r in json.load(f)["results"]}
ref_warm = base[64]["events_per_sec_warm"]
ref_cold = base[64].get("events_per_sec_cold_batched")
ref_speedup = base[64].get("warm_speedup_vs_scalar")
if not ref_cold or not ref_speedup:
    print("FAIL: committed BENCH_engine.json lacks the "
          "events_per_sec_cold_batched / warm_speedup_vs_scalar "
          "baselines at world 64 — regenerate it with "
          "`python -m benchmarks.bench_engine` (PR-9+ format)")
    sys.exit(1)

best_warm = 0.0
best_cold = 0.0
best_speedup = 0.0
seg = None
for attempt in range(3):
    r = bench_study(64, selective_iters=4, cold_repeats=1)
    best_warm = max(best_warm, r["events_per_sec_warm"])
    best_cold = max(best_cold, r["events_per_sec_cold_batched"])
    best_speedup = max(best_speedup, r["warm_speedup_vs_scalar"])
    seg = r["compiled"]
    print(f"  attempt {attempt + 1}: warm events/sec "
          f"{r['events_per_sec_warm']:12.1f} (ratio "
          f"{r['events_per_sec_warm'] / ref_warm:.2f}, "
          f"{r['warm_speedup_vs_scalar']:.2f}x vs scalar warm), "
          f"cold_batched {r['events_per_sec_cold_batched']:12.1f} (ratio "
          f"{r['events_per_sec_cold_batched'] / ref_cold:.2f})")
    if (best_warm >= RATIO * ref_warm and best_cold >= RATIO * ref_cold
            and best_speedup >= max(1.0, RATIO * ref_speedup)):
        break

print(f"RATIO_JSON \"warm_ratio\": {best_warm / ref_warm:.3f}, "
      f"\"cold_ratio\": {best_cold / ref_cold:.3f}, "
      f"\"compiled_speedup\": {best_speedup:.3f}, "
      f"\"check_ratio\": {RATIO}, "
      f"\"segments\": {seg['segments']}, "
      f"\"fused_events\": {seg['fused_events']}, "
      f"\"mean_batch\": {seg['mean_batch']}, "
      f"\"max_batch\": {seg['max_batch']}")
fail = False
if best_warm < RATIO * ref_warm:
    print(f"FAIL: best warm throughput {best_warm:.1f} < "
          f"{RATIO:.0%} of baseline {ref_warm:.1f}")
    fail = True
if best_cold < RATIO * ref_cold:
    print(f"FAIL: best batched-cold throughput {best_cold:.1f} < "
          f"{RATIO:.0%} of baseline {ref_cold:.1f}")
    fail = True
# the compiled-vs-scalar warm speedup is a SAME-SESSION ratio, immune to
# the box's absolute-throughput swings: the compiled replay must never be
# slower than the scalar interpreter, and must hold CHECK_RATIO of the
# committed speedup baseline
floor = max(1.0, RATIO * ref_speedup)
if best_speedup < floor:
    print(f"FAIL: compiled warm speedup {best_speedup:.2f}x < "
          f"{floor:.2f}x (baseline {ref_speedup:.2f}x at ratio {RATIO})")
    fail = True
if fail:
    sys.exit(1)
print(f"OK: warm {best_warm:.1f}, batched cold {best_cold:.1f} >= "
      f"{RATIO:.0%} of baselines ({ref_warm:.1f} / {ref_cold:.1f}); "
      f"compiled speedup {best_speedup:.2f}x >= {floor:.2f}x")
EOF
}

stage_session() {
    python - <<'EOF'
import sys

from repro.api import AutotuneSession, ConfigPoint, SearchSpace, SimBackend
from repro.linalg import slate_cholesky

space = SearchSpace(name="smoke-slate", world_size=16, points=[
    ConfigPoint(name="t64-la1", params={"tile": 64},
                payload=lambda w: slate_cholesky.make_program(
                    w, n=512, tile=64, lookahead=1, pr=4, pc=4)),
    ConfigPoint(name="t128-la0", params={"tile": 128},
                payload=lambda w: slate_cholesky.make_program(
                    w, n=512, tile=128, lookahead=0, pr=4, pc=4)),
])

def sweep(workers):
    session = AutotuneSession(space, backend=SimBackend(), trials=2)
    return session.sweep(policies=["conditional", "eager"],
                         tolerances=[0.25], workers=workers)

def strip(r):
    d = r.to_json()
    d.pop("wall_s")
    return d

serial = sweep(1)
forked = sweep(2)
if [strip(r) for r in serial] != [strip(r) for r in forked]:
    print("FAIL: 2-worker sweep diverged from the serial run")
    sys.exit(1)
for r in serial:
    if not (r.speedup > 0 and len(r.records) == 2):
        print(f"FAIL: degenerate study result {r.row()}")
        sys.exit(1)
print(f"OK: session API serial == 2-worker "
      f"({[round(r.speedup, 2) for r in serial]} speedups)")
EOF
}

stage_scheduler() {
    # the repro.api.scheduler smoke: a deterministic fork-executor sweep
    # must be bit-identical to the serial driver, and a localhost remote
    # worker (python -m repro.api.worker over a TCP socket) must produce
    # the same results as the serial run (the sim backend is
    # seeded-deterministic across processes).
    PYTHONPATH="src:tests${PYTHONPATH:+:$PYTHONPATH}" python - <<'EOF'
import os
import re
import subprocess
import sys

sys.path.insert(0, "tests")
from repro.api import AutotuneSession, RemoteExecutor, SimBackend
from repro.api.scheduler import fork_available
from golden_runner import golden_space

space = golden_space(1)            # tiny Capital study, world 8


def sess():
    return AutotuneSession(space, backend=SimBackend(), trials=2)


def strip(r):
    d = r.to_json()
    d.pop("wall_s")
    # remote workers keep a sweep-scoped program cache; replay is
    # bit-identical, only the provenance counters differ from serial
    d.get("extra", {}).pop("program_cache", None)
    return d


kw = dict(policies=["conditional", "eager"], tolerances=[0.25])
serial = [strip(r) for r in sess().sweep(workers=1, **kw)]

if fork_available():
    det = [strip(r) for r in sess().sweep(
        workers=2, share_stats=True, deterministic=True, **kw)]
    if det != serial:
        print("FAIL: deterministic 2-worker scheduler sweep diverged "
              "from the serial driver")
        sys.exit(1)
    print("fork executor OK: deterministic shared sweep == serial")
else:
    print("no os.fork: fork-executor smoke skipped")

worker = subprocess.Popen(
    [sys.executable, "-m", "repro.api.worker",
     "--spec", "golden_runner:golden_space",
     "--spec-args", '{"index": 1}', "--port", "0", "--once"],
    stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
    env=dict(os.environ))
try:
    line = worker.stdout.readline()
    m = re.match(r"WORKER_READY (\S+) (\d+)", line)
    if not m:
        print(f"FAIL: worker did not come up: {line!r}\n"
              f"{worker.stderr.read()}")
        sys.exit(1)
    addr = f"{m.group(1)}:{m.group(2)}"
    raw = sess().sweep(
        executor=RemoteExecutor(
            [addr], expect={"space": space.name,
                            "n_points": len(space)}), **kw)
    remote = [strip(r) for r in raw]
finally:
    worker.terminate()
    worker.wait(timeout=10)
if remote != serial:
    print("FAIL: localhost remote-worker sweep diverged from serial")
    sys.exit(1)
# the worker's sweep-scoped program cache must have replayed at least one
# recorded program across tasks: the first task records every geometry,
# every later task on the same worker is a pure cache hit
pc = [r.extra.get("program_cache") for r in raw]
if any(c is None for c in pc):
    print("FAIL: remote results carry no program_cache provenance")
    sys.exit(1)
hits = sum(c["hits"] for c in pc)
misses = sum(c["misses"] for c in pc)
recordings = sum(c["recordings"] for c in pc)
if hits < 1:
    print(f"FAIL: remote worker recorded every task from scratch "
          f"(hits={hits}, misses={misses}, recordings={recordings}) — "
          f"the cross-task program cache never replayed")
    sys.exit(1)
if recordings != len(space):
    print(f"FAIL: {recordings} recordings for {len(space)} unique "
          f"geometries across {len(raw)} tasks — expected exactly one "
          f"recording per geometry")
    sys.exit(1)
print(f"remote worker OK: {len(remote)} sweep points over {addr} "
      f"== serial; program cache {hits} hit(s) / {misses} miss(es), "
      f"{recordings} recording(s) for {len(space)} geometries")
print(f'RATIO_JSON "scheduler_points": {len(remote)}, '
      f'"remote_workers": 1, '
      f'"program_cache_hits": {hits}, '
      f'"program_cache_misses": {misses}, '
      f'"program_cache_hit_ratio": {hits / (hits + misses):.3f}, '
      f'"program_recordings": {recordings}')
EOF
}

stage_chaos() {
    # fault-tolerance smoke: a listening RemoteExecutor fed by a supervised
    # 2-worker connect-mode fleet, where a FaultPlan kills one worker on
    # its first task.  The supervisor must restart it, the executor must
    # re-admit it, the killed task must be retried — and the merged sweep
    # must stay bit-identical to the serial driver.
    PYTHONPATH="src:tests${PYTHONPATH:+:$PYTHONPATH}" python - <<'EOF'
import os
import sys
import tempfile

sys.path.insert(0, "tests")
from repro.api import (AutotuneSession, RemoteExecutor, SimBackend,
                       WorkerPool, WorkerSpec)
from golden_runner import golden_space

space = golden_space(1)            # tiny Capital study, world 8


def sess():
    return AutotuneSession(space, backend=SimBackend(), trials=2)


def strip(r):
    d = r.to_json()
    d.pop("wall_s", None)
    d.get("extra", {}).pop("recovery", None)
    d.get("extra", {}).pop("program_cache", None)
    return d


kw = dict(policies=["conditional", "eager"], tolerances=[0.25])
serial = [strip(r) for r in sess().sweep(workers=1, **kw)]

ex = RemoteExecutor(listen="127.0.0.1:0", join_timeout=60,
                    task_timeout=300, expect={"space": space.name})
marker = os.path.join(tempfile.mkdtemp(prefix="repro-chaos-"), "kill")
spec = dict(spec="golden_runner:golden_space", spec_args={"index": 1},
            connect=ex.listen_address)
specs = [WorkerSpec(faults={"kill_after": 1, "marker": marker}, **spec),
         WorkerSpec(**spec)]
session = sess()
with WorkerPool(specs, restart_backoff=0.1) as pool:
    got = session.sweep(executor=ex, max_retries=3, **kw)
    if [strip(r) for r in got] != serial:
        print("FAIL: chaos sweep diverged from the serial driver")
        sys.exit(1)
    if not os.path.exists(marker):
        print("FAIL: the FaultPlan kill never fired")
        sys.exit(1)
    recovered = [r for r in got if "recovery" in r.extra]
    if not recovered:
        print("FAIL: no sweep point carries recovery provenance")
        sys.exit(1)
    restarts = pool.restarts()
names = {e["event"] for e in session.last_sweep_events}
for must in ("worker_joined", "worker_lost", "task_retry"):
    if must not in names:
        print(f"FAIL: no {must} event in the sweep journal ({names})")
        sys.exit(1)
print(f"chaos OK: worker killed mid-task, {restarts} supervisor "
      f"restart(s), {len(recovered)} point(s) recovered, sweep == serial")
print(f'RATIO_JSON "chaos_points": {len(got)}, '
      f'"worker_restarts": {restarts}')
EOF
}

stage_transfer() {
    python - <<'EOF'
import sys

sys.path.insert(0, "tests")
from repro.api import AutotuneSession, SimBackend, StatisticsBank
from repro.core.tuner import space_of_study
from golden_runner import _studies

space = space_of_study(_studies()[1])       # tiny Capital study, world 8

def session(**kw):
    return AutotuneSession(space, backend=SimBackend(), policy="eager",
                           tolerance=0.25, trials=2, **kw)

cold = session(collect_stats=True).run()
bank = cold.stats_bank()
if not bank:
    print("FAIL: cold study harvested an empty statistics bank")
    sys.exit(1)
# the bank must survive a JSON round trip before it seeds anything
bank = StatisticsBank.from_json(bank.to_json())
warm = session(prior=bank).run()
cold_exec = sum(r.executed for r in cold.records)
warm_exec = sum(r.executed for r in warm.records)
if warm.chosen.name != cold.chosen.name:
    print(f"FAIL: warm study chose {warm.chosen.name!r}, "
          f"cold chose {cold.chosen.name!r}")
    sys.exit(1)
if warm_exec >= cold_exec:
    print(f"FAIL: warm study executed {warm_exec} kernel invocations "
          f"(cold: {cold_exec}) — transfer bought nothing")
    sys.exit(1)
print(f"OK: warm run kept winner {cold.chosen.name!r}, executed "
      f"{cold_exec} -> {warm_exec} kernel invocations")
EOF
}

stage_daemon() {
    # always-on tuning daemon smoke: simulated traffic over three request
    # shapes on the reduced smollm config.  Later shapes must warm-start
    # from the fleet store, steady-state serving must re-run ZERO banked
    # kernels cold, and the injected kernel-cost shift must be detected
    # and re-tuned in the background while serving continues.
    python - <<'EOF'
import sys

from repro.serve.tuner import run_daemon_demo

s = run_daemon_demo(rounds=4, drift_rounds=10)
c, steady = s["counters"], s["steady_state_counters"]
if c["warm_starts"] < 1:
    print(f"FAIL: no shape warm-started from the fleet store ({c})")
    sys.exit(1)
if steady["cold_banked_exec"] != 0:
    print(f"FAIL: steady-state serving re-executed "
          f"{steady['cold_banked_exec']} banked kernel(s) cold")
    sys.exit(1)
bad = {k: v for k, v in s["second_tuned_serves"].items()
       if v is None or v["executed"] != 0}
if bad:
    print(f"FAIL: second tuned serves ran kernels: {bad}")
    sys.exit(1)
if not s["drift_detected"] or s["retunes"] < 1:
    print(f"FAIL: injected cost shift not recovered (drift="
          f"{s['drift_detected']}, retunes={s['retunes']})")
    sys.exit(1)
if s["served_while_retuning"] < 1:
    print("FAIL: serving stopped during the background re-tune")
    sys.exit(1)
names = {e["event"] for e in s["events"]}
for must in ("tune_complete", "drift_detected", "retune_complete"):
    if must not in names:
        print(f"FAIL: no {must} event in the daemon journal ({names})")
        sys.exit(1)
r = s["ratios"]
print(f"daemon OK: {s['shapes']} shapes, warm starts "
      f"{c['warm_starts']}, hit ratio {r['hit_ratio']:.2f}, "
      f"{s['retunes']} re-tune(s) after drift, served "
      f"{s['served_while_retuning']} step(s) mid-re-tune")
print(f'RATIO_JSON "hit_ratio": {r["hit_ratio"]:.3f}, '
      f'"warm_start_ratio": {r["warm_start_ratio"]:.3f}, '
      f'"daemon_retunes": {s["retunes"]}')
EOF
}

stage_search() {
    # model-guided driver gate, the PR-8 acceptance numbers: on the
    # committed Capital ci grid the copula sampler + roofline prefilter
    # must land the exhaustive winner at optimum quality >= 0.99 while
    # measuring < 10% of the grid.
    python - <<'EOF'
import sys

from repro.api import AutotuneSession, SimBackend, StatisticsBank
from repro.linalg.studies import search_space

space = search_space("capital-cholesky", scale="ci")
bank = StatisticsBank.load(
    "benchmarks/results/capital-cholesky-ci_stats_bank.json")

def session(**kw):
    return AutotuneSession(space, backend=SimBackend(), policy="eager",
                           tolerance=0.25, trials=2, **kw)

full = session(search="exhaustive").run()
times = {r.name: r.predicted for r in full.records}
guided = session(search="model_guided",
                 search_options={"banks": [bank], "seed": 0}).run()
cov = guided.extra["coverage"]
winner = guided.extra["best"]
if cov >= 0.10:
    print(f"FAIL: model_guided measured {cov:.1%} of the grid (>= 10%)")
    sys.exit(1)
if winner is None or winner not in times:
    print(f"FAIL: model_guided produced no rankable winner ({winner!r})")
    sys.exit(1)
quality = min(times.values()) / times[winner]
if winner != full.chosen.name:
    print(f"FAIL: model_guided chose {winner!r}, exhaustive chose "
          f"{full.chosen.name!r} (quality {quality:.3f})")
    sys.exit(1)
if quality < 0.99:
    print(f"FAIL: winner quality {quality:.3f} < 0.99")
    sys.exit(1)
s = guided.extra["sampler"]
print(f"search OK: winner {winner!r} == exhaustive, coverage {cov:.1%} "
      f"({len(guided.extra['dispatched'])}/{len(space)} points), "
      f"quality {quality:.3f}, rho={s['rho']:.2f}, "
      f"{s['model_keys']} model keys")
print(f'RATIO_JSON "search_coverage": {cov:.4f}, '
      f'"winner_quality": {quality:.4f}, '
      f'"search_dispatched": {len(guided.extra["dispatched"])}')
EOF
}

stage_hypothesis() {
    # the core-stats and copula property tests are optional-dep-guarded;
    # if hypothesis IS available they must actually run — a skip means
    # the guard rotted.
    if python -c "import hypothesis" 2>/dev/null; then
        local out
        out=$(python -m pytest tests/test_core_stats.py \
                  tests/test_transfer.py -q -rs) || {
            echo "$out"; return 1; }
        echo "$out" | tail -n 3
        if printf '%s' "$out" | grep -qi "skipped"; then
            echo "FAIL: hypothesis is installed but the property"
            echo "      suites skipped tests anyway:"
            printf '%s\n' "$out" | grep -i skip
            return 1
        fi
        echo "OK: property suite ran under hypothesis with no skips"
    else
        echo "hypothesis not installed: hypothesis-driven cases skip by design"
        echo "(the seeded-fallback property tests still run in tier-1)"
    fi
}

case "$STAGE" in
    all)      STAGES=(tests engine session scheduler transfer chaos daemon search hypothesis) ;;
    no-tests) STAGES=(engine session scheduler transfer chaos daemon search hypothesis) ;;
    tests|engine|session|scheduler|transfer|chaos|daemon|search|hypothesis) STAGES=("$STAGE") ;;
    *) echo "unknown stage: $STAGE (tests|engine|session|scheduler|transfer|chaos|daemon|search|hypothesis)" >&2
       exit 2 ;;
esac

for s in "${STAGES[@]}"; do
    run_stage "$s" "stage_$s"
done

# assemble the summary in python: the rows are already JSON fragments, and
# joining them portably (BSD sed has no \n in replacements) is python's job
if CHECK_ROWS="$(printf '%s\n' "${SUMMARY_ROWS[@]}")" python -c '
import json, os, sys
rows = [json.loads(line) for line in os.environ["CHECK_ROWS"].splitlines()
        if line.strip()]
with open(sys.argv[1], "w") as f:
    json.dump({"stages": rows, "exit_code": int(sys.argv[2])}, f, indent=1)
    f.write("\n")
' "$OUT" "$OVERALL"; then
    echo "wrote $OUT"
else
    echo "ERROR: failed to write $OUT" >&2
    OVERALL=1
fi
exit "$OVERALL"
