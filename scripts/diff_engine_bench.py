#!/usr/bin/env python
"""Nightly regression diff for the engine-throughput artifact.

Compares tonight's ``BENCH_engine.json`` against the previous night's
(downloaded from the last successful nightly's ``nightly-bench``
artifact) and fails on throughput regression beyond tolerance.

Unlike the paper-sweep diff (``diff_paper_results.py``), throughput is a
wall-clock measurement on a shared hosted runner, so the gate is
one-sided and coarse: a field fails only if tonight's best-of-N rate
drops below ``(1 - tol) * previous`` (default tol 0.20, i.e. a >20%
regression).  Improvements and noise-level wobble pass.  The
within-session ratio fields (``warm_speedup_vs_scalar``,
``cold_speedup_vs_scalar``, ...) are immune to the runner's
absolute-throughput swings but not to timing granularity — the
world-16 cold runs are tens of milliseconds, so their ratios are
warm-up-dominated — and get their own looser ``--tol-ratio`` (default
0.35) and are only gated at world sizes >= 64 (the acceptance
geometries).

Rows are matched on world size; sizes present on only one side are
notes, not failures (geometry growth is fine; a previous artifact in a
pre-PR-9 format without the compiled fields just skips those fields).
Exit codes: 0 clean, 1 regression, 2 usage/IO.  A missing previous
artifact (first night, expired retention) exits 0 with a note.

Usage::

    python scripts/diff_engine_bench.py PREV.json CURR.json [--tol 0.20]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

# best-of-N throughput fields gated one-sidedly (higher is better)
RATE_FIELDS = (
    "events_per_sec",
    "events_per_sec_warm",
    "events_per_sec_cold",
    "events_per_sec_cold_batched",
    "events_per_sec_cold_counter",
    "events_per_sec_cold_cached",
)
# within-session speedup ratios: box-noise-immune, same one-sided gate,
# but only at world sizes >= RATIO_MIN_WORLD (smaller geometries finish
# in tens of milliseconds and their ratios are warm-up artifacts)
RATIO_FIELDS = (
    "warm_speedup_vs_scalar",
    "cold_speedup_vs_scalar",
    "cold_counter_speedup_vs_scalar",
    "cold_cached_speedup_vs_batched",
)
RATIO_MIN_WORLD = 64


def _load(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    rows = doc.get("results")
    if not isinstance(rows, list):
        raise ValueError(f"{path}: expected an engine-bench document "
                         f"with a 'results' list")
    return {r["world_size"]: r for r in rows}


def diff(prev: dict, curr: dict, *, tol: float, tol_ratio: float):
    """Returns (failures, notes) as lists of human-readable strings."""
    failures, notes = [], []
    for ws in sorted(set(prev) | set(curr)):
        if ws not in curr:
            notes.append(f"world {ws}: dropped from tonight's sweep")
            continue
        if ws not in prev:
            notes.append(f"world {ws}: new geometry (no baseline)")
            continue
        p, c = prev[ws], curr[ws]
        for field in RATE_FIELDS + RATIO_FIELDS:
            if field in RATIO_FIELDS and ws < RATIO_MIN_WORLD:
                continue
            t = tol_ratio if field in RATIO_FIELDS else tol
            pv, cv = p.get(field), c.get(field)
            if not isinstance(pv, (int, float)) or pv <= 0:
                notes.append(f"world {ws}: no {field} baseline "
                             f"(older artifact format?)")
                continue
            if not isinstance(cv, (int, float)):
                failures.append(f"world {ws}: {field} missing from "
                                f"tonight's artifact")
                continue
            if cv < (1.0 - t) * pv:
                failures.append(
                    f"world {ws}: {field} regressed {pv:.1f} -> {cv:.1f} "
                    f"({cv / pv - 1.0:+.1%} < -{t:.0%})")
    return failures, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("prev", help="previous night's BENCH_engine.json")
    ap.add_argument("curr", help="tonight's BENCH_engine.json")
    ap.add_argument("--tol", type=float, default=0.20,
                    help="max relative throughput drop (default 20%%)")
    ap.add_argument("--tol-ratio", type=float, default=0.35,
                    help="max relative drop for the within-session "
                         "speedup-ratio fields (default 35%%: the "
                         "small-geometry runs are tens of milliseconds, "
                         "so their ratios wobble harder than the rates)")
    args = ap.parse_args(argv)

    if not os.path.exists(args.prev):
        print(f"no previous artifact at {args.prev}: nothing to diff "
              f"(first night?)")
        return 0
    try:
        prev, curr = _load(args.prev), _load(args.curr)
    except (OSError, ValueError, KeyError) as e:
        print(f"ERROR: {e}", file=sys.stderr)
        return 2

    failures, notes = diff(prev, curr, tol=args.tol,
                           tol_ratio=args.tol_ratio)
    for n in notes:
        print(f"note: {n}")
    if failures:
        print(f"FAIL: {len(failures)} throughput regression(s) vs "
              f"{args.prev}:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"OK: {len(curr)} world size(s) within {args.tol:.0%} of "
          f"{args.prev}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
