#!/usr/bin/env python
"""Nightly regression diff for the recorded paper-sweep artifact.

Compares tonight's ``benchmarks/results/paper_case_studies.json`` (rows
from ``benchmarks.bench_paper``: one per (study, policy, tolerance, seed,
allocation) with speedup, prediction quality, optimum quality, and the
selected configuration) against the previous night's artifact and fails
on drift beyond tolerance:

- the *selected configuration* must not change at all (the sweep is
  seeded-deterministic; a different winner means the protocol moved);
- ``speedup`` may drift by at most ``--tol-speedup`` (relative);
- ``mean_error`` by at most ``--tol-error`` (absolute);
- ``optimum_quality`` by at most ``--tol-quality`` (absolute).

Rows are matched on (study, policy, tolerance, seed, allocation); rows
present on only one side are reported (new grid points are fine, silently
*lost* ones fail).  Exit codes: 0 clean, 1 drift, 2 usage/IO.  A missing
previous artifact (first night, expired artifact retention) exits 0 with
a note — there is nothing to diff against.

Usage::

    python scripts/diff_paper_results.py PREV.json CURR.json \\
        [--tol-speedup 0.5] [--tol-error 0.05] [--tol-quality 0.05]
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys


def _key(row: dict) -> tuple:
    return (row.get("study"), row.get("policy"), row.get("tolerance"),
            row.get("seed", 0), row.get("allocation", 0))


def _load(path: str):
    with open(path) as f:
        rows = json.load(f)
    if not isinstance(rows, list):
        raise ValueError(f"{path}: expected a list of sweep rows")
    return {_key(r): r for r in rows}


def _num(v):
    # rows cross json.dump with NaN allowed; tolerate missing/NaN uniformly
    return v if isinstance(v, (int, float)) else math.nan


def diff(prev: dict, curr: dict, *, tol_speedup: float, tol_error: float,
         tol_quality: float):
    """Returns (failures, notes) as lists of human-readable strings."""
    failures, notes = [], []
    for key in sorted(set(prev) | set(curr), key=str):
        name = "/".join(str(k) for k in key)
        if key not in curr:
            failures.append(f"{name}: row disappeared from tonight's "
                            f"artifact")
            continue
        if key not in prev:
            notes.append(f"{name}: new row (no baseline)")
            continue
        p, c = prev[key], curr[key]
        if p.get("chosen") is None or c.get("chosen") is None:
            # pre-PR-5 artifacts carry no selected-config column; drift
            # tracking for it starts once both sides record one
            notes.append(f"{name}: no selected-config baseline")
        elif p["chosen"] != c["chosen"]:
            failures.append(
                f"{name}: selected configuration changed "
                f"{p['chosen']!r} -> {c['chosen']!r}")
        ps, cs = _num(p.get("speedup")), _num(c.get("speedup"))
        if math.isfinite(ps) and math.isfinite(cs) and ps > 0:
            rel = abs(cs - ps) / ps
            if rel > tol_speedup:
                failures.append(
                    f"{name}: speedup drifted {ps:.3g} -> {cs:.3g} "
                    f"({rel:.1%} > {tol_speedup:.0%})")
        for field, tol in (("mean_error", tol_error),
                           ("optimum_quality", tol_quality)):
            pv, cv = _num(p.get(field)), _num(c.get(field))
            if math.isfinite(pv) and math.isfinite(cv) \
                    and abs(cv - pv) > tol:
                failures.append(
                    f"{name}: {field} drifted {pv:.4g} -> {cv:.4g} "
                    f"(|delta| > {tol})")
    return failures, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("prev", help="previous night's paper_case_studies.json")
    ap.add_argument("curr", help="tonight's paper_case_studies.json")
    ap.add_argument("--tol-speedup", type=float, default=0.5,
                    help="max relative speedup drift (default 50%%: the "
                         "speedup itself is wall-clock-free, but racing/"
                         "NaN rows and grid growth keep this coarse)")
    ap.add_argument("--tol-error", type=float, default=0.05,
                    help="max absolute mean_error drift")
    ap.add_argument("--tol-quality", type=float, default=0.05,
                    help="max absolute optimum_quality drift")
    args = ap.parse_args(argv)

    if not os.path.exists(args.prev):
        print(f"no previous artifact at {args.prev}: nothing to diff "
              f"(first night?)")
        return 0
    try:
        prev, curr = _load(args.prev), _load(args.curr)
    except (OSError, ValueError) as e:
        print(f"ERROR: {e}", file=sys.stderr)
        return 2

    failures, notes = diff(prev, curr, tol_speedup=args.tol_speedup,
                           tol_error=args.tol_error,
                           tol_quality=args.tol_quality)
    for n in notes:
        print(f"note: {n}")
    if failures:
        print(f"FAIL: {len(failures)} regression(s) vs {args.prev}:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"OK: {len(curr)} rows within tolerance of {args.prev}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
