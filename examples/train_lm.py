"""Train a reduced LM end-to-end with the full stack, then demonstrate
fault tolerance: checkpoint, simulate a crash, resume bit-identically.

    PYTHONPATH=src python examples/train_lm.py [arch]
"""

import shutil
import sys
import tempfile

from repro.launch.train import main as train_main


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "smollm-135m"
    d = tempfile.mkdtemp(prefix="ck_")
    common = ["--arch", arch, "--reduced", "--batch", "4", "--seq", "64",
              "--ckpt-dir", d, "--ckpt-every", "25", "--log-every", "25",
              "--lr", "3e-3"]
    print(f"== phase 1: train 50 steps of reduced {arch} ==")
    train_main(common + ["--steps", "50"])
    print("\n== simulated crash; phase 2 resumes from step 50 and "
          "continues to 100 ==")
    train_main(common + ["--steps", "100"])
    shutil.rmtree(d, ignore_errors=True)


if __name__ == "__main__":
    main()
