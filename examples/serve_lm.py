"""Serve a reduced LM with the slot-based continuous-batching engine.

    PYTHONPATH=src python examples/serve_lm.py [arch]
"""

import sys

from repro.launch.serve import main as serve_main


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "smollm-135m"
    serve_main(["--arch", arch, "--reduced", "--requests", "12",
                "--batch", "4", "--max-new", "16", "--temperature", "0.8"])


if __name__ == "__main__":
    main()
