"""Serve a reduced LM with the slot-based continuous-batching engine.

    PYTHONPATH=src python examples/serve_lm.py [arch]

With ``--daemon``, drive simulated traffic through the always-on tuning
daemon instead: shape misses open background studies, recurring shapes
serve from tuned winners with banked kernels skipped, and an injected
kernel-cost shift exercises the drift -> re-tune path while serving
continues (see README "Serving with always-on tuning").

    PYTHONPATH=src python examples/serve_lm.py --daemon
"""

import sys

from repro.launch.serve import main as serve_main


def main():
    argv = sys.argv[1:]
    if "--daemon" in argv:
        argv.remove("--daemon")
        arch = argv[0] if argv else "smollm-135m"
        serve_main(["--arch", arch, "--daemon"])
        return
    arch = argv[0] if argv else "smollm-135m"
    serve_main(["--arch", arch, "--reduced", "--requests", "12",
                "--batch", "4", "--max-new", "16", "--temperature", "0.8"])


if __name__ == "__main__":
    main()
