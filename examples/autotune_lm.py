"""The paper's technique on the LM framework itself: selectively-timed
autotuning of step-function configurations (real wall-clock, reduced arch).

    PYTHONPATH=src python examples/autotune_lm.py [arch]
"""

import sys

import numpy as np

from repro.core.policies import policy
from repro.tune import LMStudy, SelectiveTimer, lm_config_space


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "smollm-135m"
    study = LMStudy(arch, batch=2, seq=32)
    space = lm_config_space(study.cfg)[:6]
    timer = SelectiveTimer(policy("eager", tolerance=0.3, min_samples=3))
    print(f"autotuning {len(space)} step configurations of reduced {arch} "
          f"(eager policy, tol 0.3)\n")
    tot_full = tot_cost = 0.0
    preds = []
    for kn in space:
        pred, full, cost = study.run_config(kn, timer, iters=3)
        tot_full += full * 3
        tot_cost += cost
        preds.append(pred)
        print(f"  {kn.name:28s} predicted {pred * 1e3:7.1f} ms "
              f"(full ref {full * 1e3:7.1f} ms)")
    best = int(np.argmin(preds))
    print(f"\nchosen config: {space[best].name}")
    print(f"autotuning speedup vs full re-timing: "
          f"{tot_full / max(tot_cost, 1e-12):.2f}x")


if __name__ == "__main__":
    main()
