"""The paper's technique on the LM framework itself: selectively-timed
autotuning of step-function configurations (real wall-clock, reduced
arch), through the session API with the wall-clock backend.

    PYTHONPATH=src python examples/autotune_lm.py [arch]
"""

import sys

from repro.api import AutotuneSession, WallClockBackend
from repro.tune import LMStudy


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "smollm-135m"
    study = LMStudy(arch, batch=2, seq=32)
    session = AutotuneSession(study.search_space(max_configs=6),
                              backend=WallClockBackend(study.kernels_of),
                              policy="eager", tolerance=0.3,
                              min_samples=3, trials=3)
    print(f"autotuning {len(session.space)} step configurations of "
          f"reduced {arch} (eager policy, tol 0.3)\n")
    result = session.run()
    for rec in result.records:
        print(f"  {rec.name:28s} predicted {rec.predicted * 1e3:7.1f} ms "
              f"(full ref {rec.full_time * 1e3:7.1f} ms)")
    print(f"\nchosen config: {result.chosen.name}")
    print(f"autotuning speedup vs full re-timing: {result.speedup:.2f}x")


if __name__ == "__main__":
    main()
