"""Quickstart: the paper's approximate autotuning, end to end.

Autotunes Capital's recursive 3D Cholesky (15 configurations: block size x
base-case strategy) on the virtual 64-rank machine, comparing full
execution against the paper's five selective-execution policies at one
confidence tolerance.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

from repro.core.policies import POLICIES, policy
from repro.core.tuner import Autotuner
from repro.linalg.studies import capital_cholesky_study


def main():
    tol = 0.25
    print(f"autotuning Capital Cholesky (15 configs, 64 virtual ranks), "
          f"tolerance {tol}\n")
    print(f"{'policy':13s} {'speedup':>8s} {'mean err':>9s} "
          f"{'optimum?':>9s} {'wall s':>7s}")
    for pol in POLICIES:
        study = capital_cholesky_study("ci")
        t0 = time.time()
        rep = Autotuner(study, policy(pol, tolerance=tol),
                        trials=3, seed=0).tune()
        print(f"{pol:13s} {rep.speedup:8.2f} {rep.mean_error:9.3f} "
              f"{rep.optimum_quality:9.3f} {time.time() - t0:7.1f}")
    print("\nspeedup   = full-execution tuning time / selective tuning time")
    print("mean err  = |predicted - measured| / measured, averaged")
    print("optimum?  = runtime of truly-best config / chosen config")


if __name__ == "__main__":
    main()
